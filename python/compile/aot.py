# AOT compile path: lower every module the Rust coordinator needs to HLO
# *text* and write artifacts/{manifest.json, params.bin}.
#
# HLO text — NOT lowered.compile().serialize() — is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
# xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate binds)
# rejects; the text parser reassigns ids and round-trips cleanly. See
# /opt/xla-example/README.md.
#
# Python runs ONCE here (`make artifacts`); it is never on the training path.

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(fn, specs):
    # keep_unused=True: jit would otherwise prune parameters whose *value*
    # is unused (e.g. a bias that only contributes a shape to its gradient),
    # desynchronizing the compiled program arity from the manifest.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def spec_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": "f32"}


def block_module_set(cfg: configs.NetConfig, stage: int):
    """(suffix, fn, input specs, output specs) for one stage's ODE block."""
    b, hw, c = cfg.batch, cfg.stage_hw(stage), cfg.channels[stage]
    z = f32((b, hw, hw, c))
    theta_shapes = configs.block_param_shapes(cfg, stage)
    theta = [f32(s) for _, s in theta_shapes]
    theta_names = [n for n, _ in theta_shapes]
    arch, nt = cfg.arch, cfg.nt

    def iospec(ins, outs):
        return ([spec_entry(n, s) for n, s in ins], [spec_entry(n, s) for n, s in outs])

    mods = []
    for solver in configs.SOLVERS[arch]:
        mods.append(
            (f"{solver}_fwd", model.block_fwd(arch, solver, nt),
             *iospec([("z", z)] + list(zip(theta_names, theta)), [("z1", z)]))
        )
        mods.append(
            (f"{solver}_vjp", model.block_vjp(arch, solver, nt),
             *iospec([("z", z)] + list(zip(theta_names, theta)) + [("g", z)],
                     [("gz", z)] + [(f"g_{n}", s) for n, s in zip(theta_names, theta)]))
        )
        mods.append(
            (f"{solver}_node", model.block_node(arch, solver, nt),
             *iospec([("z1", z)] + list(zip(theta_names, theta)) + [("g", z)],
                     [("gz", z)] + [(f"g_{n}", s) for n, s in zip(theta_names, theta)]
                     + [("z0_rec", z)]))
        )
        mods.append(
            (f"{solver}_step_fwd", model.block_step_fwd(arch, solver, nt),
             *iospec([("z", z)] + list(zip(theta_names, theta)), [("z1", z)]))
        )
        mods.append(
            (f"{solver}_step_vjp", model.block_step_vjp(arch, solver, nt),
             *iospec([("z", z)] + list(zip(theta_names, theta)) + [("g", z)],
                     [("gz", z)] + [(f"g_{n}", s) for n, s in zip(theta_names, theta)]))
        )
    # OTD study is Euler-only (§IV analyzes the Euler inconsistency).
    mods.append(
        ("euler_otd", model.block_otd(arch, "euler", nt),
         *iospec([("z", z)] + list(zip(theta_names, theta)) + [("g", z)],
                 [("gz", z)] + [(f"g_{n}", s) for n, s in zip(theta_names, theta)]))
    )
    # RK45: forward + [8]-gradient (the divergent configuration of Figs 3-5).
    mods.append(
        ("rk45_fwd", model.block_fwd(arch, "rk45", nt),
         *iospec([("z", z)] + list(zip(theta_names, theta)), [("z1", z)]))
    )
    mods.append(
        ("rk45_node", model.block_node(arch, "rk45", nt),
         *iospec([("z1", z)] + list(zip(theta_names, theta)) + [("g", z)],
                 [("gz", z)] + [(f"g_{n}", s) for n, s in zip(theta_names, theta)]
                 + [("z0_rec", z)]))
    )

    out = []
    for suffix, fn, ins, outs in mods:
        name = f"block_{arch}_s{stage}_{suffix}"
        argspecs = [f32(tuple(i["shape"])) for i in ins]
        out.append((name, fn, argspecs, ins, outs))
    return out


def shared_module_set(cfg: configs.NetConfig, num_classes_list):
    """Stem / transitions / heads (shared across solvers)."""
    b, img = cfg.batch, cfg.image
    c = cfg.channels
    mods = []

    x = f32((b, img, img, cfg.in_channels))
    z0 = f32((b, img, img, c[0]))
    sw, sb = f32((3, 3, cfg.in_channels, c[0])), f32((c[0],))
    mods.append(("stem_fwd", model.stem_fwd_fn, [x, sw, sb],
                 [spec_entry("x", x), spec_entry("w", sw), spec_entry("b", sb)],
                 [spec_entry("z0", z0)]))
    mods.append(("stem_vjp", model.stem_vjp_fn, [x, sw, sb, z0],
                 [spec_entry("x", x), spec_entry("w", sw), spec_entry("b", sb),
                  spec_entry("g", z0)],
                 [spec_entry("gw", sw), spec_entry("gb", sb)]))

    for s in range(cfg.stages - 1):
        hw = cfg.stage_hw(s)
        zin = f32((b, hw, hw, c[s]))
        zout = f32((b, hw // 2, hw // 2, c[s + 1]))
        tw, tb = f32((3, 3, c[s], c[s + 1])), f32((c[s + 1],))
        mods.append((f"trans{s}_fwd", model.trans_fwd_fn, [zin, tw, tb],
                     [spec_entry("z", zin), spec_entry("w", tw), spec_entry("b", tb)],
                     [spec_entry("z1", zout)]))
        mods.append((f"trans{s}_vjp", model.trans_vjp_fn, [zin, tw, tb, zout],
                     [spec_entry("z", zin), spec_entry("w", tw), spec_entry("b", tb),
                      spec_entry("g", zout)],
                     [spec_entry("gz", zin), spec_entry("gw", tw), spec_entry("gb", tb)]))

    hw_last = cfg.stage_hw(cfg.stages - 1)
    zl = f32((b, hw_last, hw_last, c[-1]))
    for ncls in num_classes_list:
        hww, hb = f32((c[-1], ncls)), f32((ncls,))
        y = f32((b,))
        scalar = f32(())
        mods.append((f"head{ncls}_loss_grad", model.head_loss_grad_fn, [zl, hww, hb, y],
                     [spec_entry("z", zl), spec_entry("w", hww), spec_entry("b", hb),
                      spec_entry("labels", y)],
                     [spec_entry("loss", scalar), spec_entry("correct", scalar),
                      spec_entry("gz", zl), spec_entry("gw", hww), spec_entry("gb", hb)]))
        mods.append((f"head{ncls}_eval", model.head_eval_fn, [zl, hww, hb, y],
                     [spec_entry("z", zl), spec_entry("w", hww), spec_entry("b", hb),
                      spec_entry("labels", y)],
                     [spec_entry("loss", scalar), spec_entry("correct", scalar)]))
    return mods


def tiny_module_set(tiny: configs.TinyConfig):
    """Tiny resnet block at several Nt values for the §IV dt-sweep
    (gradient-consistency study) and fast Rust integration tests."""
    b, hw, c = tiny.batch, tiny.hw, tiny.channels
    cfg = configs.NetConfig(arch="resnet", batch=b, image=hw, channels=(c,))
    z = f32((b, hw, hw, c))
    theta_shapes = configs.block_param_shapes(cfg, 0)
    theta = [f32(s) for _, s in theta_shapes]
    theta_names = [n for n, _ in theta_shapes]
    mods = []
    for nt in tiny.nts:
        common_in = [spec_entry("z", z)] + [
            spec_entry(n, s) for n, s in zip(theta_names, theta)
        ]
        gout = [spec_entry("gz", z)] + [
            spec_entry(f"g_{n}", s) for n, s in zip(theta_names, theta)
        ]
        mods.append((f"tiny_euler_nt{nt}_fwd", model.block_fwd("resnet", "euler", nt),
                     [z] + theta, common_in, [spec_entry("z1", z)]))
        mods.append((f"tiny_euler_nt{nt}_vjp", model.block_vjp("resnet", "euler", nt),
                     [z] + theta + [z], common_in + [spec_entry("g", z)], gout))
        mods.append((f"tiny_euler_nt{nt}_otd", model.block_otd("resnet", "euler", nt),
                     [z] + theta + [z], common_in + [spec_entry("g", z)], gout))
        mods.append((f"tiny_euler_nt{nt}_node", model.block_node("resnet", "euler", nt),
                     [z] + theta + [z], common_in + [spec_entry("g", z)],
                     gout + [spec_entry("z0_rec", z)]))
        mods.append((f"tiny_euler_nt{nt}_step_fwd", model.block_step_fwd("resnet", "euler", nt),
                     [z] + theta, common_in, [spec_entry("z1", z)]))
        mods.append((f"tiny_euler_nt{nt}_step_vjp", model.block_step_vjp("resnet", "euler", nt),
                     [z] + theta + [z], common_in + [spec_entry("g", z)], gout))
    return mods


def write_params(out_dir):
    """Seeded initial parameters for every (arch, num_classes) model,
    concatenated into one params.bin; manifest records offsets."""
    params_index = {}
    blob = bytearray()
    offset = 0
    for arch, cfg in (("resnet", configs.RESNET), ("sqnxt", configs.SQNXT)):
        for ncls in (10, 100):
            layout, values = model.init_params(cfg, ncls, seed=0)
            entries = []
            for (name, shape), val in zip(layout, values):
                import numpy as np

                arr = np.asarray(val, dtype="<f4")
                entries.append({"name": name, "shape": list(shape), "offset": offset})
                blob.extend(arr.tobytes())
                offset += arr.size
            params_index[f"{arch}{ncls}"] = entries
    with open(os.path.join(out_dir, "params.bin"), "wb") as f:
        f.write(bytes(blob))
    return params_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="substring filter of module names")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    mods = []
    for cfg in (configs.RESNET, configs.SQNXT):
        for s in range(cfg.stages):
            mods.extend(block_module_set(cfg, s))
    # Shared stem/transition/head (identical shapes for both archs).
    mods.extend(shared_module_set(configs.RESNET, (10, 100)))
    mods.extend(tiny_module_set(configs.TINY))

    if args.only:
        mods = [m for m in mods if args.only in m[0]]

    manifest_modules = []
    t_all = time.time()
    for name, fn, argspecs, ins, outs in mods:
        t0 = time.time()
        text = to_hlo_text(fn, argspecs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest_modules.append({"name": name, "file": fname, "inputs": ins, "outputs": outs})
        print(f"  {name:<44} {len(text)//1024:>6} KB  {time.time()-t0:5.1f}s", flush=True)

    params_index = write_params(out_dir)

    # With --only, merge into the existing manifest instead of clobbering it.
    if args.only:
        manifest_path = os.path.join(out_dir, "manifest.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as f:
                old = json.load(f)
            rebuilt = {m["name"] for m in manifest_modules}
            manifest_modules = [
                m for m in old.get("modules", []) if m["name"] not in rebuilt
            ] + manifest_modules

    manifest = {
        "config": {
            "batch": configs.RESNET.batch,
            "image": configs.RESNET.image,
            "nt": configs.RESNET.nt,
            "channels": list(configs.RESNET.channels),
            "blocks_per_stage": configs.RESNET.blocks_per_stage,
            "tiny_batch": configs.TINY.batch,
            "tiny_hw": configs.TINY.hw,
            "tiny_channels": configs.TINY.channels,
            "tiny_nts": list(configs.TINY.nts),
            "rk45_max_steps": configs.RK45_MAX_STEPS,
        },
        "modules": manifest_modules,
        "params": params_index,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest_modules)} modules + params.bin in {time.time()-t_all:.1f}s")


if __name__ == "__main__":
    main()
