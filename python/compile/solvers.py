# Fixed-step and adaptive ODE solvers over pytree state.
#
# These are the *discrete* time steppers the paper's analysis is about:
# the DTO gradient is reverse-mode AD through exactly these loops, the OTD
# gradient discretizes the continuous adjoint instead (model.py), and the
# neural-ODE [8] baseline runs them backwards in time.

import jax
import jax.numpy as jnp

FIXED_SOLVERS = ("euler", "rk2", "rk4")


def tree_axpy(a, x, y):
    """y + a*x over pytrees."""
    return jax.tree_util.tree_map(lambda xi, yi: yi + a * xi, x, y)


def tree_scale(a, x):
    return jax.tree_util.tree_map(lambda xi: a * xi, x)


def tree_add(*xs):
    return jax.tree_util.tree_map(lambda *v: sum(v), *xs)


def step_fn(rhs, solver, h):
    """One fixed step of `solver` with step size `h` (h may be negative).

    rhs(z, theta) -> dz/dt; z is a pytree.
    """
    if solver == "euler":

        def step(z, theta):
            return tree_axpy(h, rhs(z, theta), z)

    elif solver == "rk2":
        # Explicit trapezoidal (Heun) — the "RK2 (Trapezoidal method)" of
        # Fig. 3; self-adjoint up to O(h^2), which is why the paper notes
        # OTD's inconsistency is milder for it.
        def step(z, theta):
            k1 = rhs(z, theta)
            k2 = rhs(tree_axpy(h, k1, z), theta)
            return tree_axpy(h / 2.0, tree_add(k1, k2), z)

    elif solver == "rk4":

        def step(z, theta):
            k1 = rhs(z, theta)
            k2 = rhs(tree_axpy(h / 2.0, k1, z), theta)
            k3 = rhs(tree_axpy(h / 2.0, k2, z), theta)
            k4 = rhs(tree_axpy(h, k3, z), theta)
            incr = tree_add(k1, tree_scale(2.0, k2), tree_scale(2.0, k3), k4)
            return tree_axpy(h / 6.0, incr, z)

    else:
        raise ValueError(f"unknown fixed-step solver {solver!r}")

    return step


def odeint_fixed(rhs, solver, nt, T=1.0):
    """Integrate dz/dt = rhs(z, theta) over `nt` steps of size T/nt.

    T may be negative (reverse-time integration, used by the neural-ODE [8]
    baseline). Returns fn(z0, theta) -> z(T).
    """
    h = T / nt
    step = step_fn(rhs, solver, h)

    def integrate(z0, theta):
        def body(z, _):
            return step(z, theta), None

        z, _ = jax.lax.scan(body, z0, None, length=nt)
        return z

    return integrate


def odeint_fixed_traj(rhs, solver, nt, T=1.0):
    """Like `odeint_fixed` but also returns the stacked trajectory
    (z_1 .. z_nt) — the forward states the OTD adjoint needs."""
    h = T / nt
    step = step_fn(rhs, solver, h)

    def integrate(z0, theta):
        def body(z, _):
            z1 = step(z, theta)
            return z1, z1

        z, traj = jax.lax.scan(body, z0, None, length=nt)
        return z, traj

    return integrate


# ---------------------------------------------------------------------------
# Adaptive Dormand–Prince RK45 with a bounded step count, AOT-friendly:
# a lax.scan over max_steps where steps past the horizon are no-ops. This is
# the solver the paper reports as *divergent* when used for the reverse
# reconstruction of [8].
# ---------------------------------------------------------------------------

# Dormand–Prince 5(4) Butcher tableau.
_DP_C = (0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0)
_DP_A = (
    (),
    (1 / 5,),
    (3 / 40, 9 / 40),
    (44 / 45, -56 / 15, 32 / 9),
    (19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729),
    (9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656),
    (35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84),
)
_DP_B5 = (35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0)
_DP_B4 = (5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40)


def _tree_norm_inf(t):
    leaves = jax.tree_util.tree_leaves(t)
    return jnp.max(jnp.stack([jnp.max(jnp.abs(l)) for l in leaves]))


def odeint_rk45(rhs, max_steps, T=1.0, rtol=1e-4, atol=1e-6):
    """Adaptive RK45 from t=0 to t=T (T may be negative).

    Fixed iteration count (`max_steps` scan) so the lowered HLO has a static
    while structure; unconverged integrations simply stop short — which is
    exactly the failure mode that makes [8]+RK45 diverge in training.
    Returns fn(z0, theta) -> (z(T_reached), steps_taken, t_reached).
    """
    sign = 1.0 if T >= 0 else -1.0

    def integrate(z0, theta):
        h0 = T / 8.0

        def body(carry, _):
            z, t, h, done = carry
            # Clamp the step to the remaining horizon.
            h_eff = jnp.where(sign * (t + h) > sign * T, T - t, h)

            ks = []
            for i in range(7):
                zi = z
                for j, aij in enumerate(_DP_A[i]):
                    zi = tree_axpy(h_eff * aij, ks[j], zi)
                ks.append(rhs(zi, theta))

            z5 = z
            z4 = z
            for i in range(7):
                if _DP_B5[i] != 0.0:
                    z5 = tree_axpy(h_eff * _DP_B5[i], ks[i], z5)
                if _DP_B4[i] != 0.0:
                    z4 = tree_axpy(h_eff * _DP_B4[i], ks[i], z4)

            err = _tree_norm_inf(tree_add(z5, tree_scale(-1.0, z4)))
            scale = atol + rtol * jnp.maximum(_tree_norm_inf(z), _tree_norm_inf(z5))
            ratio = err / scale
            accept = ratio <= 1.0

            z_next = jax.tree_util.tree_map(
                lambda a, b: jnp.where(jnp.logical_and(accept, ~done), a, b), z5, z
            )
            t_next = jnp.where(jnp.logical_and(accept, ~done), t + h_eff, t)
            # PI-less step-size controller.
            factor = jnp.clip(0.9 * ratio ** (-0.2), 0.2, 5.0)
            h_next = jnp.where(done, h, h_eff * factor)
            done_next = jnp.logical_or(done, sign * t_next >= sign * T - 1e-12)
            counted = jnp.logical_and(accept, jnp.logical_not(done))
            return (z_next, t_next, h_next, done_next), counted

        init = (z0, jnp.asarray(0.0, jnp.float32), jnp.asarray(h0, jnp.float32), jnp.asarray(False))
        (z, t, _, _), accepts = jax.lax.scan(body, init, None, length=max_steps)
        return z, jnp.sum(accepts.astype(jnp.int32)), t

    return integrate
