# L2: the ODE-network compute graphs, built on the L1 Pallas kernels.
#
# Everything the Rust coordinator calls at runtime is defined here as a pure
# jax function and AOT-lowered by aot.py:
#   - ODE block forward (fixed-step / RK45)                     -> *_fwd
#   - ANODE gradient: reverse-mode AD through the discrete
#     stepper (Discretize-Then-Optimize, Appendix C)            -> *_vjp
#   - OTD gradient: continuous adjoint discretized with stored
#     forward states (Eq. 10 — the *inconsistent* one)          -> *_otd
#   - neural-ODE [8] gradient: augmented reverse-time solve
#     that *reconstructs* z(t) backwards (the unstable one)     -> *_node
#   - single time step fwd/vjp for the revolve executor         -> *_step_*
#   - stem / transition / head modules and their VJPs.

import jax
import jax.numpy as jnp

from . import configs
from .kernels import downsample2x, make_conv2d
from .solvers import odeint_fixed, odeint_fixed_traj, odeint_rk45, tree_axpy

# ---------------------------------------------------------------------------
# Residual-block right-hand sides f(z, theta)
# ---------------------------------------------------------------------------


def resnet_rhs(z, theta):
    """Basic-block RHS: conv3x3 -> ReLU -> conv3x3 (norm-free; DESIGN.md §9)."""
    w1, b1, w2, b2 = theta
    h = make_conv2d("relu")(z, w1, b1)
    return make_conv2d("id")(h, w2, b2)


def sqnxt_rhs(z, theta):
    """SqueezeNext low-rank block of Fig. 2:
    1x1 (C->C/2) -> 1x1 (->C/4) -> 3x1 -> 1x3 -> 1x1 expand (->C)."""
    w1, b1, w2, b2, w3, b3, w4, b4, w5, b5 = theta
    h = make_conv2d("relu")(z, w1, b1)
    h = make_conv2d("relu")(h, w2, b2)
    h = make_conv2d("relu")(h, w3, b3)
    h = make_conv2d("relu")(h, w4, b4)
    return make_conv2d("id")(h, w5, b5)


RHS = {"resnet": resnet_rhs, "sqnxt": sqnxt_rhs}


def rhs_with_tuple(arch):
    """rhs(z, theta_tuple) — theta as a flat tuple of arrays."""
    return RHS[arch]


# ---------------------------------------------------------------------------
# ODE block: forward + the three gradient methods
# ---------------------------------------------------------------------------


def block_fwd(arch, solver, nt, T=1.0):
    """z1 = z0 + ∫ f dt, discretized (Eq. 1b)."""
    rhs = rhs_with_tuple(arch)
    if solver == "rk45":
        integ = odeint_rk45(rhs, configs.RK45_MAX_STEPS, T, configs.RK45_RTOL, configs.RK45_ATOL)

        def fwd(z, *theta):
            z1, _, _ = integ(z, tuple(theta))
            return (z1,)

        return fwd
    integ = odeint_fixed(rhs, solver, nt, T)

    def fwd(z, *theta):
        return (integ(z, tuple(theta)),)

    return fwd


def block_vjp(arch, solver, nt, T=1.0):
    """ANODE/DTO gradient: exact reverse-mode AD through the discrete
    stepper. The O(Nt) trajectory lives *inside* this executable's working
    set and is freed when the call returns — the coordinator stores only the
    block input (O(L) across blocks). Returns (g_z, g_theta...)."""
    fwd = block_fwd(arch, solver, nt, T)

    def vjp(z, *args):
        *theta, g = args
        _, pull = jax.vjp(lambda z_, *th: fwd(z_, *th)[0], z, *theta)
        return pull(g)

    return vjp


def block_otd(arch, solver, nt, T=1.0):
    """Optimize-Then-Discretize gradient (§IV, Eq. 10): solve the continuous
    adjoint -dα/dt = (∂f/∂z)ᵀ α backwards with explicit Euler, evaluating the
    Jacobian at the *stored forward* states. For forward Euler this evaluates
    ∂f/∂z at z_{i+1} where DTO uses z_i — the O(dt) inconsistency.

    Uses the stored trajectory, so it has NO reconstruction instability; it
    isolates the OTD-vs-DTO error from the reversal error of [8]."""
    rhs = rhs_with_tuple(arch)
    h = T / nt
    traj_fn = odeint_fixed_traj(rhs, solver, nt, T)

    def otd(z, *args):
        *theta, g = args
        theta = tuple(theta)
        _, traj = traj_fn(z, theta)  # z_1 .. z_nt, each (B,H,W,C)

        def body(carry, z_right):
            alpha, gth = carry
            # vjp of f at the right endpoint (OTD's inconsistent choice).
            _, pull = jax.vjp(lambda zz, *th: rhs(zz, tuple(th)), z_right, *theta)
            pulled = pull(alpha)
            az, ath = pulled[0], pulled[1:]
            alpha = tree_axpy(h, az, alpha)
            gth = tuple(tree_axpy(h, a, g0) for a, g0 in zip(ath, gth))
            return (alpha, gth), None

        gth0 = tuple(jnp.zeros_like(t) for t in theta)
        # March the adjoint backwards over the stored states z_nt .. z_1.
        rev_traj = jax.tree_util.tree_map(lambda t: jnp.flip(t, axis=0), traj)
        (alpha, gth), _ = jax.lax.scan(body, (g, gth0), rev_traj)
        return (alpha, *gth)

    return otd


def block_node(arch, solver, nt, T=1.0):
    """Neural-ODE [8] gradient: integrate the augmented system
    (z, α, g_θ) *backwards in time from z1*, reconstructing z(t) by solving
    the forward ODE in reverse — the numerically unstable part (§III).
    Returns (g_z, g_theta..., z0_reconstructed)."""
    rhs = rhs_with_tuple(arch)

    def aug_rhs(y, theta):
        z, alpha, gth = y
        f, pull = jax.vjp(lambda zz, *th: rhs(zz, tuple(th)), z, *theta)
        pulled = pull(alpha)
        az, ath = pulled[0], pulled[1:]
        # d/dt (z, α, gθ) = (f, -αᵀ∂f/∂z, -αᵀ∂f/∂θ); integrated from t=T to 0.
        return (f, jax.tree_util.tree_map(jnp.negative, az),
                tuple(jax.tree_util.tree_map(jnp.negative, a) for a in ath))

    def node(z1, *args):
        *theta, g = args
        theta = tuple(theta)
        gth0 = tuple(jnp.zeros_like(t) for t in theta)
        y1 = (z1, g, gth0)
        if solver == "rk45":
            integ = odeint_rk45(
                aug_rhs, configs.RK45_MAX_STEPS, -T, configs.RK45_RTOL, configs.RK45_ATOL
            )
            y0, _, _ = integ(y1, theta)
        else:
            y0 = odeint_fixed(aug_rhs, solver, nt, -T)(y1, theta)
        z0_rec, alpha0, gth = y0
        return (alpha0, *gth, z0_rec)

    return node


def block_step_fwd(arch, solver, nt, T=1.0):
    """A single time step z_{i+1} = Φ(z_i) — the unit of the revolve
    schedule executed by the Rust checkpoint executor."""
    rhs = rhs_with_tuple(arch)
    from .solvers import step_fn

    step = step_fn(rhs, solver, T / nt)

    def fwd(z, *theta):
        return (step(z, tuple(theta)),)

    return fwd


def block_step_vjp(arch, solver, nt, T=1.0):
    """VJP of a single time step (used when replaying a revolve schedule)."""
    fwd = block_step_fwd(arch, solver, nt, T)

    def vjp(z, *args):
        *theta, g = args
        _, pull = jax.vjp(lambda z_, *th: fwd(z_, *th)[0], z, *theta)
        return pull(g)

    return vjp


# ---------------------------------------------------------------------------
# Non-ODE modules: stem, transition, head
# ---------------------------------------------------------------------------


def stem_fwd_fn(z, w, b):
    """Input conv: 3 -> C0, ReLU."""
    return (make_conv2d("relu")(z, w, b),)


def stem_vjp_fn(z, w, b, g):
    _, pull = jax.vjp(lambda zz, ww, bb: stem_fwd_fn(zz, ww, bb)[0], z, w, b)
    gz, gw, gb = pull(g)
    return (gw, gb)  # input image gradient not needed


def trans_fwd_fn(z, w, b):
    """Transition (non-ODE, paper §V): conv3x3 C->2C + ReLU, then 2x
    downsample (stride-2 conv expressed as stride-1 + slice; conv.py)."""
    return (downsample2x(make_conv2d("relu")(z, w, b)),)


def trans_vjp_fn(z, w, b, g):
    _, pull = jax.vjp(lambda zz, ww, bb: trans_fwd_fn(zz, ww, bb)[0], z, w, b)
    return pull(g)  # (gz, gw, gb)


def _head_loss(z, w, b, labels_f):
    """Global average pool -> dense -> mean softmax cross-entropy.

    labels_f: f32 (B,) class indices (f32 so the Rust I/O path is uniformly
    f32; cast here). Returns (loss, correct_count)."""
    labels = labels_f.astype(jnp.int32)
    feat = z.mean(axis=(1, 2))  # (B, C)
    logits = jnp.dot(feat, w) + b
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    loss = -(onehot * logp).sum(axis=-1).mean()
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32).sum()
    return loss, correct


def head_loss_grad_fn(z, w, b, labels):
    """(loss, correct, g_z, g_w, g_b) in one call — the terminal condition
    Eq. 5c for the block adjoints plus the head parameter gradients."""
    (loss, correct), pull = jax.vjp(lambda zz, ww, bb: _head_loss(zz, ww, bb, labels), z, w, b)
    gz, gw, gb = pull((jnp.ones((), loss.dtype), jnp.zeros((), loss.dtype)))
    return (loss, correct, gz, gw, gb)


def head_eval_fn(z, w, b, labels):
    loss, correct = _head_loss(z, w, b, labels)
    return (loss, correct)


# ---------------------------------------------------------------------------
# Parameter initialization (shared with Rust via params.bin)
# ---------------------------------------------------------------------------


def init_params(cfg: configs.NetConfig, num_classes: int, seed: int = 0):
    """He-normal conv weights, zero biases, He-normal head. The *last* conv
    of each block RHS is scaled by 0.1 so the ODE forward map stays
    well-conditioned at init (paper §VI: forward stability is the user's
    responsibility; this mirrors the common zero/small-init of the last
    block conv in ResNets)."""
    key = jax.random.PRNGKey(seed)
    layout = configs.model_param_layout(cfg, num_classes)
    out = []
    last_w = {f"w{5 if cfg.arch == 'sqnxt' else 2}"}
    for name, shape in layout:
        key, sub = jax.random.split(key)
        leaf = name.split(".")[-1]
        if leaf.startswith("w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            std = (2.0 / fan_in) ** 0.5
            w = jax.random.normal(sub, shape, jnp.float32) * std
            if leaf in last_w and ".b" in name:  # block's last conv
                w = w * 0.1
            out.append(w)
        elif leaf.startswith("w"):  # head dense
            std = (2.0 / shape[0]) ** 0.5
            out.append(jax.random.normal(sub, shape, jnp.float32) * std)
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return layout, out
