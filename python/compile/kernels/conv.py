# L1: Pallas convolution kernels (the compute hot-spot of the ODE RHS).
#
# TPU adaptation of the paper's GPU convnets (DESIGN.md §3): stride-1 SAME
# convolution expressed as an im2col *patch-matmul* so the contraction runs
# on the MXU systolic array. BlockSpec tiles the HBM->VMEM schedule over the
# batch grid (one image block per grid step), the role threadblocks play in
# the CUDA formulation. Bias-add + activation are fused into the same kernel
# to avoid an HBM round trip.
#
# `pallas_call` has no reverse-mode rule, so convolution is wrapped in
# `jax.custom_vjp` whose backward pass is *also* Pallas kernels:
#   - input gradient  = SAME conv of the pre-activation gradient with the
#     spatially-flipped, channel-transposed weights (same fwd kernel);
#   - weight gradient = patch-matmul correlation accumulated across the
#     batch grid (revisited output block + @pl.when init).
# This is exactly the Discretize-Then-Optimize construction the paper
# advocates: the gradient of the *discrete* kernel, not of a continuous
# idealization.
#
# interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
# custom-calls; interpret mode folds the kernel into plain HLO (see
# /opt/xla-example/README.md).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activations supported inside the fused kernel.
LEAKY_SLOPE = 0.1


def _apply_act(pre, act):
    if act == "id":
        return pre
    if act == "relu":
        return jnp.maximum(pre, 0.0)
    if act == "leaky":
        return jnp.where(pre > 0, pre, LEAKY_SLOPE * pre)
    if act == "softplus":
        # Numerically-stable softplus.
        return jnp.logaddexp(pre, 0.0)
    raise ValueError(f"unknown act {act!r}")


def act_grad(pre, act):
    """d act / d pre, evaluated at the stored pre-activation."""
    if act == "id":
        return jnp.ones_like(pre)
    if act == "relu":
        return (pre > 0).astype(pre.dtype)
    if act == "leaky":
        return jnp.where(pre > 0, 1.0, LEAKY_SLOPE).astype(pre.dtype)
    if act == "softplus":
        return jax.nn.sigmoid(pre)
    raise ValueError(f"unknown act {act!r}")


def _patches(xp, kh, kw, h, w):
    """im2col: (B, Hp, Wp, Cin) padded batch -> (B*H*W, kh*kw*Cin) patch
    matrix.

    Static unrolled shifts (kh*kw slices) — on TPU these are cheap VMEM
    re-reads; the expensive op is the single big matmul that follows.
    """
    b = xp.shape[0]
    cols = [xp[:, i : i + h, j : j + w, :] for i in range(kh) for j in range(kw)]
    stack = jnp.concatenate(cols, axis=-1)  # (B, H, W, kh*kw*Cin)
    return stack.reshape(b * h * w, kh * kw * xp.shape[-1])


def _conv_fwd_kernel(xp_ref, w_ref, b_ref, pre_ref, y_ref, *, kh, kw, act):
    """Fused patch-matmul + bias + activation over the whole block.

    CPU-interpret runs one whole-batch block (grid=()); a real-TPU build
    would tile the same kernel over (batch, row-tile) grid with VMEM-sized
    BlockSpecs — `kernel_footprint` models that geometry for the perf
    estimates in DESIGN.md §8.
    """
    xp = xp_ref[...]  # (B, H+kh-1, W+kw-1, Cin)
    bsz, h, w = pre_ref.shape[0], pre_ref.shape[1], pre_ref.shape[2]
    cout = w_ref.shape[-1]
    pmat = _patches(xp, kh, kw, h, w)
    wmat = w_ref[...].reshape(kh * kw * xp.shape[-1], cout)
    # f32 accumulation regardless of input dtype (MXU-style).
    pre = jnp.dot(pmat, wmat, preferred_element_type=jnp.float32)
    pre = pre + b_ref[...].astype(jnp.float32)
    pre = pre.reshape(bsz, h, w, cout)
    pre_ref[...] = pre.astype(pre_ref.dtype)
    y_ref[...] = _apply_act(pre, act).astype(y_ref.dtype)


def _conv_wgrad_kernel(xp_ref, g_ref, gw_ref, *, kh, kw):
    """Weight gradient: correlation as one patch-matmul over the batch
    (pmatᵀ @ g); on TPU this contraction maps directly onto the MXU with
    the batch·spatial axis as the reduction dimension."""
    xp = xp_ref[...]
    g = g_ref[...]  # (B, H, W, Cout)
    bsz, h, w, cout = g.shape
    cin = xp.shape[-1]
    pmat = _patches(xp, kh, kw, h, w)  # (B*H*W, kh*kw*Cin)
    gmat = g.reshape(bsz * h * w, cout).astype(jnp.float32)
    gw = jnp.dot(pmat.T.astype(jnp.float32), gmat, preferred_element_type=jnp.float32)
    gw_ref[...] = gw.reshape(kh, kw, cin, cout).astype(gw_ref.dtype)


def _pad_same(x, kh, kw):
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    return jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))


def conv2d_pallas_raw(x, w, b, act, *, interpret=True):
    """Forward conv returning (pre, y). x: (B,H,W,Cin); w: (kh,kw,Cin,Cout)."""
    bsz, h, wd, _ = x.shape
    kh, kw, _, cout = w.shape
    xp = _pad_same(x, kh, kw)
    kern = functools.partial(_conv_fwd_kernel, kh=kh, kw=kw, act=act)
    out_shape = [
        jax.ShapeDtypeStruct((bsz, h, wd, cout), x.dtype),
        jax.ShapeDtypeStruct((bsz, h, wd, cout), x.dtype),
    ]
    pre, y = pl.pallas_call(
        kern,
        out_shape=out_shape,
        interpret=interpret,
    )(xp, w, b)
    return pre, y


def conv2d_input_grad(gpre, w, *, interpret=True):
    """∂L/∂x for stride-1 SAME conv: conv(gpre, flip_hw(w) with Cin<->Cout)."""
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)
    cin = w.shape[2]
    zero_b = jnp.zeros((cin,), dtype=gpre.dtype)
    _, gx = conv2d_pallas_raw(gpre, wt, zero_b, "id", interpret=interpret)
    return gx


def conv2d_weight_grad(x, gpre, kh, kw, *, interpret=True):
    """∂L/∂w via the Pallas correlation kernel."""
    bsz, h, wd, cin = x.shape
    cout = gpre.shape[-1]
    xp = _pad_same(x, kh, kw)
    kern = functools.partial(_conv_wgrad_kernel, kh=kh, kw=kw)
    gw = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((kh, kw, cin, cout), jnp.float32),
        interpret=interpret,
    )(xp, gpre)
    return gw.astype(x.dtype)


@functools.lru_cache(maxsize=None)
def make_conv2d(act: str, interpret: bool = True):
    """Differentiable fused conv+bias+act with Pallas forward AND backward.

    Returns conv(x, w, b) -> y with a custom VJP. The VJP is the exact
    gradient of the discrete kernel (DTO), implemented with the same Pallas
    machinery as the forward pass.
    """

    @jax.custom_vjp
    def conv(x, w, b):
        _, y = conv2d_pallas_raw(x, w, b, act, interpret=interpret)
        return y

    def fwd(x, w, b):
        pre, y = conv2d_pallas_raw(x, w, b, act, interpret=interpret)
        return y, (x, w, pre)

    def bwd(res, gy):
        x, w, pre = res
        gpre = gy * act_grad(pre, act)
        # Bias grad is a trivial reduction; XLA fuses it — no kernel needed.
        gb = gpre.sum(axis=(0, 1, 2)).astype(gpre.dtype)
        gx = conv2d_input_grad(gpre, w, interpret=interpret)
        gw = conv2d_weight_grad(x, gpre, w.shape[0], w.shape[1], interpret=interpret)
        return gx, gw, gb

    conv.defvjp(fwd, bwd)
    return conv


def downsample2x(x):
    """Stride-2 as stride-1 conv + slice.

    For even H and SAME padding, XLA's stride-2 conv pads (0,1) while
    stride-1 pads (1,1), so conv_s2(x)[i,j] == conv_s1(x)[2i+1, 2j+1] — the
    odd phase. Keeps every Pallas kernel stride-1 (transposed/dilated
    backward kernels never needed); autodiff through the slice is an exact
    scatter.
    """
    return x[:, 1::2, 1::2, :]


# VMEM/MXU structural estimate used by the perf pass (DESIGN.md §8).
def kernel_footprint(batch_block, h, w, cin, cout, kh, kw, dtype_bytes=4):
    """Return dict of VMEM bytes per grid step and MXU utilization estimate."""
    hp, wp = h + kh - 1, w + kw - 1
    vmem_in = batch_block * hp * wp * cin * dtype_bytes
    vmem_patches = h * w * kh * kw * cin * dtype_bytes
    vmem_w = kh * kw * cin * cout * dtype_bytes
    vmem_out = 2 * batch_block * h * w * cout * dtype_bytes  # pre + y
    m, k, n = h * w, kh * kw * cin, cout
    # MXU is a 128x128 systolic array: utilization ~ how well (m,k,n) fill it.
    mxu_util = min(1.0, k / 128.0) * min(1.0, n / 128.0)
    return {
        "vmem_bytes": vmem_in + vmem_patches + vmem_w + vmem_out,
        "matmul_mkn": (m, k, n),
        "mxu_utilization_est": mxu_util,
        "flops": 2.0 * m * k * n,
    }
