# L1: Pallas kernels for the paper's compute hot-spot (conv patch-matmul).
from .conv import (  # noqa: F401
    act_grad,
    conv2d_input_grad,
    conv2d_pallas_raw,
    conv2d_weight_grad,
    downsample2x,
    kernel_footprint,
    make_conv2d,
)
