# Pure-jnp correctness oracles for the Pallas kernels.
#
# Everything here is plain `lax`/`jnp` with no Pallas, differentiable by
# ordinary jax autodiff — the ground truth the kernel tests (and the DTO
# gradient tests) compare against.

import jax
import jax.numpy as jnp
from jax import lax

LEAKY_SLOPE = 0.1


def apply_act(pre, act):
    if act == "id":
        return pre
    if act == "relu":
        return jnp.maximum(pre, 0.0)
    if act == "leaky":
        return jnp.where(pre > 0, pre, LEAKY_SLOPE * pre)
    if act == "softplus":
        return jnp.logaddexp(pre, 0.0)
    raise ValueError(f"unknown act {act!r}")


def conv2d_ref(x, w, b, act="id"):
    """Stride-1 SAME conv, NHWC x HWIO -> NHWC, fused bias + activation."""
    pre = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    pre = pre + b.astype(jnp.float32)
    return apply_act(pre, act).astype(x.dtype)


def downsample2x_ref(x):
    return x[:, 1::2, 1::2, :]


def dense_ref(x, w, b):
    """(B, F) @ (F, C) + b."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)


def softmax_xent_ref(logits, labels_onehot):
    """Mean softmax cross-entropy; labels one-hot (B, C)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -(labels_onehot * logp).sum(axis=-1).mean()
