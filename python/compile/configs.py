# Shared model/solver configuration between aot.py, the tests, and (via
# manifest.json) the Rust coordinator. Single source of truth for shapes.

from dataclasses import dataclass, field


@dataclass(frozen=True)
class NetConfig:
    """ODE-network family configuration (paper §V experiments).

    `arch` selects the residual RHS:
      - "resnet": f = conv3x3 -> relu -> conv3x3 (ResNet-18-like basic block)
      - "sqnxt":  f = SqueezeNext low-rank block of Fig. 2
        (1x1 /2, 1x1 /2, 3x1, 1x3, 1x1 expand)
    Non-transition blocks are ODE blocks; transitions are plain strided
    residual-free conv downsamples (paper keeps transitions non-ODE).
    """

    arch: str = "resnet"
    batch: int = 32
    image: int = 32
    in_channels: int = 3
    channels: tuple = (16, 32, 64)
    blocks_per_stage: int = 2
    nt: int = 5  # time steps per ODE block
    time_horizon: float = 1.0

    @property
    def stages(self):
        return len(self.channels)

    def stage_hw(self, s):
        """Spatial side length at stage s (0-based)."""
        return self.image // (2**s)


@dataclass(frozen=True)
class TinyConfig:
    """Small block used for the gradient-consistency study (§IV) and fast
    integration tests: dt sweep needs several Nt values baked, so the shape
    is kept tiny."""

    batch: int = 4
    hw: int = 8
    channels: int = 8
    nts: tuple = (1, 2, 4, 8, 16, 32)


RESNET = NetConfig(arch="resnet")
SQNXT = NetConfig(arch="sqnxt")
TINY = TinyConfig()

# Solvers whose block artifacts are emitted per architecture (DESIGN.md §5).
SOLVERS = {
    "resnet": ("euler",),
    "sqnxt": ("euler", "rk2"),
}
RK45_MAX_STEPS = 64
RK45_RTOL = 1e-4
RK45_ATOL = 1e-6


def block_param_shapes(cfg: NetConfig, stage: int):
    """Parameter (name, shape) list of one ODE block at `stage` (0-based)."""
    c = cfg.channels[stage]
    if cfg.arch == "resnet":
        return [
            ("w1", (3, 3, c, c)),
            ("b1", (c,)),
            ("w2", (3, 3, c, c)),
            ("b2", (c,)),
        ]
    if cfg.arch == "sqnxt":
        c2, c4 = max(c // 2, 1), max(c // 4, 1)
        return [
            ("w1", (1, 1, c, c2)),
            ("b1", (c2,)),
            ("w2", (1, 1, c2, c4)),
            ("b2", (c4,)),
            ("w3", (3, 1, c4, c4)),
            ("b3", (c4,)),
            ("w4", (1, 3, c4, c4)),
            ("b4", (c4,)),
            ("w5", (1, 1, c4, c)),
            ("b5", (c,)),
        ]
    raise ValueError(f"unknown arch {cfg.arch!r}")


def stem_param_shapes(cfg: NetConfig):
    return [("w", (3, 3, cfg.in_channels, cfg.channels[0])), ("b", (cfg.channels[0],))]


def trans_param_shapes(cfg: NetConfig, stage: int):
    """Transition after stage `stage` (0-based): C_s -> C_{s+1}, /2 spatial."""
    return [
        ("w", (3, 3, cfg.channels[stage], cfg.channels[stage + 1])),
        ("b", (cfg.channels[stage + 1],)),
    ]


def head_param_shapes(cfg: NetConfig, num_classes: int):
    return [("w", (cfg.channels[-1], num_classes)), ("b", (num_classes,))]


def model_param_layout(cfg: NetConfig, num_classes: int):
    """Canonical (name, shape) list in execution order — must match the Rust
    coordinator's parameter ordering and params.bin."""
    layout = [(f"stem.{n}", s) for n, s in stem_param_shapes(cfg)]
    for s in range(cfg.stages):
        for b in range(cfg.blocks_per_stage):
            layout += [(f"s{s}.b{b}.{n}", shp) for n, shp in block_param_shapes(cfg, s)]
        if s + 1 < cfg.stages:
            layout += [(f"trans{s}.{n}", shp) for n, shp in trans_param_shapes(cfg, s)]
    layout += [(f"head.{n}", s) for n, s in head_param_shapes(cfg, num_classes)]
    return layout
