# L2 solver correctness: order of accuracy, pytree handling, reverse-time
# integration, and the bounded-step adaptive RK45.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.solvers import odeint_fixed, odeint_fixed_traj, odeint_rk45, step_fn


def linear_rhs(lam):
    return lambda z, theta: lam * z


@pytest.mark.parametrize("solver,order,nts", [
    ("euler", 1, (8, 16)),
    ("rk2", 2, (8, 16)),
    # rk4 at nt=16 hits f32 round-off; use coarser steps for a clean ratio.
    ("rk4", 4, (2, 4)),
])
def test_order_of_accuracy(solver, order, nts):
    lam = -1.0
    z0 = jnp.ones(())
    exact = float(np.exp(lam))
    errs = []
    for nt in nts:
        z = odeint_fixed(linear_rhs(lam), solver, nt)(z0, ())
        errs.append(abs(float(z) - exact))
    ratio = errs[0] / errs[1]
    assert ratio == pytest.approx(2.0**order, rel=0.4), f"{solver}: ratio {ratio}"


def test_negative_horizon_reverses_linear_flow():
    rhs = linear_rhs(-0.5)
    z1 = odeint_fixed(rhs, "rk4", 64)(jnp.asarray(2.0), ())
    z0 = odeint_fixed(rhs, "rk4", 64, T=-1.0)(z1, ())
    assert float(z0) == pytest.approx(2.0, rel=1e-5)


def test_pytree_state():
    rhs = lambda z, theta: jax.tree_util.tree_map(lambda x: -x, z)
    z0 = {"a": jnp.ones((2, 2)), "b": (jnp.zeros(3) + 2.0,)}
    z1 = odeint_fixed(rhs, "rk4", 32)(z0, ())
    expect = float(np.exp(-1.0))
    np.testing.assert_allclose(z1["a"], expect, rtol=1e-4)
    np.testing.assert_allclose(z1["b"][0], 2.0 * expect, rtol=1e-4)


def test_traj_matches_step_iteration():
    rhs = linear_rhs(-1.0)
    nt = 5
    zT, traj = odeint_fixed_traj(rhs, "euler", nt)(jnp.asarray(1.0), ())
    # Manual iteration.
    z = jnp.asarray(1.0)
    step = step_fn(rhs, "euler", 1.0 / nt)
    manual = []
    for _ in range(nt):
        z = step(z, ())
        manual.append(float(z))
    np.testing.assert_allclose(traj, manual, rtol=1e-6)
    assert float(zT) == pytest.approx(manual[-1])


def test_theta_is_passed_through():
    rhs = lambda z, theta: theta[0] * z
    z1 = odeint_fixed(rhs, "euler", 10)(jnp.asarray(1.0), (jnp.asarray(-1.0),))
    z2 = odeint_fixed(rhs, "euler", 10)(jnp.asarray(1.0), (jnp.asarray(-2.0),))
    assert float(z1) > float(z2)


class TestRk45:
    def test_matches_exact_solution(self):
        integ = odeint_rk45(linear_rhs(-1.0), max_steps=64)
        z, steps, t = integ(jnp.asarray(1.0), ())
        assert float(t) == pytest.approx(1.0, abs=1e-6)
        assert float(z) == pytest.approx(float(np.exp(-1.0)), rel=1e-4)
        assert int(steps) < 64

    def test_bounded_steps_stop_short_on_stiff_reverse(self):
        # Reversing dz/dt = -100 z under a small step budget: the error
        # controller caps h (the reverse flow grows like e^{100 s}), the
        # horizon is not reached, and the "reconstruction" is garbage —
        # the divergence mechanism of [8]+RK45 (footnote 2 of the paper).
        integ = odeint_rk45(linear_rhs(-30.0), max_steps=12, T=-1.0, rtol=1e-12, atol=1e-14)
        z1 = float(np.exp(-30.0))
        z, steps, t = integ(jnp.asarray(z1), ())
        assert abs(float(t)) < 0.9, f"reached t={float(t)}"  # did not reach -1
        # Reconstruction is nowhere near z0 = 1.
        assert abs(float(z) - 1.0) > 0.5

    def test_adapts_to_tolerance(self):
        loose = odeint_rk45(linear_rhs(-5.0), max_steps=128, rtol=1e-2, atol=1e-4)
        tight = odeint_rk45(linear_rhs(-5.0), max_steps=128, rtol=1e-8, atol=1e-10)
        _, s1, _ = loose(jnp.asarray(1.0), ())
        _, s2, _ = tight(jnp.asarray(1.0), ())
        assert int(s2) > int(s1)

    def test_pytree_state(self):
        rhs = lambda z, th: jax.tree_util.tree_map(lambda x: -x, z)
        integ = odeint_rk45(rhs, max_steps=64)
        z, _, t = integ({"x": jnp.ones(4)}, ())
        assert float(t) == pytest.approx(1.0, abs=1e-6)
        np.testing.assert_allclose(z["x"], np.exp(-1.0), rtol=1e-4)
