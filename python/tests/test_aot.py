# AOT path: manifest structure, params.bin layout, HLO text lowering and
# init-statistics sanity.

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_roundtrip(tmp_path):
    # A tiny function lowers to HLO text parseable by the old XLA (no
    # serialized protos — DESIGN.md §5 / aot.py docstring).
    def fn(x):
        return (x * 2.0 + 1.0,)

    text = aot.to_hlo_text(fn, [jax.ShapeDtypeStruct((4,), jnp.float32)])
    assert "HloModule" in text
    assert "f32[4]" in text


def test_param_layout_matches_init():
    for cfg, ncls in [(configs.RESNET, 10), (configs.SQNXT, 100)]:
        layout = configs.model_param_layout(cfg, ncls)
        l2, values = model.init_params(cfg, ncls, seed=0)
        assert [n for n, _ in layout] == [n for n, _ in l2]
        for (name, shape), v in zip(layout, values):
            assert tuple(v.shape) == tuple(shape), name


def test_init_statistics():
    _, values = model.init_params(configs.RESNET, 10, seed=0)
    layout = configs.model_param_layout(configs.RESNET, 10)
    for (name, shape), v in zip(layout, values):
        leaf = name.split(".")[-1]
        if leaf.startswith("b"):
            assert float(jnp.abs(v).max()) == 0.0, f"{name} biases must start at 0"
        elif len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            std = float(jnp.std(v))
            he = (2.0 / fan_in) ** 0.5
            # Block-final convs are down-scaled by 0.1.
            assert std < he * 1.5, f"{name}: std {std} vs he {he}"


def test_init_deterministic():
    _, a = model.init_params(configs.RESNET, 10, seed=0)
    _, b = model.init_params(configs.RESNET, 10, seed=0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    _, c = model.init_params(configs.RESNET, 10, seed=1)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_all_expected_modules(self, manifest):
        names = {m["name"] for m in manifest["modules"]}
        # Spot-check the full experiment matrix.
        for arch in ("resnet", "sqnxt"):
            for s in range(3):
                for kind in ("fwd", "vjp", "node", "step_fwd", "step_vjp"):
                    assert f"block_{arch}_s{s}_euler_{kind}" in names
                assert f"block_{arch}_s{s}_euler_otd" in names
                assert f"block_{arch}_s{s}_rk45_fwd" in names
                assert f"block_{arch}_s{s}_rk45_node" in names
        for s in range(3):
            for kind in ("fwd", "vjp", "node", "step_fwd", "step_vjp"):
                assert f"block_sqnxt_s{s}_rk2_{kind}" in names
        assert "stem_fwd" in names and "stem_vjp" in names
        assert "head10_loss_grad" in names and "head100_eval" in names
        for nt in manifest["config"]["tiny_nts"]:
            assert f"tiny_euler_nt{nt}_vjp" in names

    def test_module_files_exist_and_are_hlo(self, manifest):
        for m in manifest["modules"][:10]:
            path = os.path.join(ARTIFACTS, m["file"])
            assert os.path.exists(path)
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_params_bin_length_covers_offsets(self, manifest):
        size = os.path.getsize(os.path.join(ARTIFACTS, "params.bin"))
        n_floats = size // 4
        for key, specs in manifest["params"].items():
            for p in specs:
                need = p["offset"] + int(np.prod(p["shape"]))
                assert need <= n_floats, f"{key}/{p['name']}"

    def test_params_bin_matches_python_init(self, manifest):
        specs = manifest["params"]["resnet10"]
        layout, values = model.init_params(configs.RESNET, 10, seed=0)
        raw = np.fromfile(os.path.join(ARTIFACTS, "params.bin"), dtype="<f4")
        for (name, _), v, spec in zip(layout, values, specs):
            assert spec["name"] == name
            n = int(np.prod(spec["shape"]))
            got = raw[spec["offset"] : spec["offset"] + n].reshape(spec["shape"])
            np.testing.assert_allclose(got, np.asarray(v), rtol=1e-6)

    def test_io_specs_have_shapes_and_dtypes(self, manifest):
        for m in manifest["modules"]:
            for io in m["inputs"] + m["outputs"]:
                assert isinstance(io["shape"], list)
                assert io["dtype"] == "f32"
