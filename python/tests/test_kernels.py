# L1 correctness: Pallas conv kernels vs the pure-jnp oracle, forward and
# custom-VJP backward, swept over shapes/dtypes with hypothesis.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    act_grad,
    conv2d_input_grad,
    conv2d_pallas_raw,
    conv2d_weight_grad,
    downsample2x,
    kernel_footprint,
    make_conv2d,
)
from compile.kernels import ref

ACTS = ["id", "relu", "leaky", "softplus"]


def rand(key, shape, dtype=jnp.float32, scale=0.5):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("act", ACTS)
@pytest.mark.parametrize(
    "kh,kw,cin,cout,h,w,b",
    [(3, 3, 4, 8, 8, 8, 2), (1, 1, 8, 4, 8, 8, 2), (3, 1, 4, 4, 6, 6, 1), (1, 3, 4, 4, 6, 6, 1)],
)
def test_forward_matches_ref(act, kh, kw, cin, cout, h, w, b):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(hash((act, kh, kw)) % 2**31), 3)
    x = rand(k1, (b, h, w, cin))
    wgt = rand(k2, (kh, kw, cin, cout), scale=0.3)
    bias = rand(k3, (cout,), scale=0.1)
    y = make_conv2d(act)(x, wgt, bias)
    yr = ref.conv2d_ref(x, wgt, bias, act)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ACTS)
def test_custom_vjp_matches_autodiff_of_ref(act):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    x = rand(k1, (2, 8, 8, 4))
    wgt = rand(k2, (3, 3, 4, 6), scale=0.3)
    bias = rand(k3, (6,), scale=0.1)
    g = rand(k4, (2, 8, 8, 6))
    conv = make_conv2d(act)
    gk = jax.grad(lambda *a: (conv(*a) * g).sum(), argnums=(0, 1, 2))(x, wgt, bias)
    gr = jax.grad(lambda *a: (ref.conv2d_ref(*a, act) * g).sum(), argnums=(0, 1, 2))(x, wgt, bias)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    kh=st.sampled_from([1, 3]),
    kw=st.sampled_from([1, 3]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    b=st.integers(1, 3),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_forward_sweep(kh, kw, cin, cout, h, w, b, act, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (b, h, w, cin))
    wgt = rand(k2, (kh, kw, cin, cout), scale=0.3)
    bias = rand(k3, (cout,), scale=0.1)
    pre, y = conv2d_pallas_raw(x, wgt, bias, act)
    yr = ref.conv2d_ref(x, wgt, bias, act)
    pr = ref.conv2d_ref(x, wgt, bias, "id")
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(pre, pr, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    h=st.integers(3, 10),
    b=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_gradient_sweep(cin, cout, h, b, seed):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(k1, (b, h, h, cin))
    wgt = rand(k2, (3, 3, cin, cout), scale=0.3)
    bias = rand(k3, (cout,), scale=0.1)
    g = rand(k4, (b, h, h, cout))
    conv = make_conv2d("relu")
    gk = jax.grad(lambda *a: (conv(*a) * g).sum(), argnums=(0, 1, 2))(x, wgt, bias)
    gr = jax.grad(lambda *a: (ref.conv2d_ref(*a, "relu") * g).sum(), argnums=(0, 1, 2))(
        x, wgt, bias
    )
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-3, atol=1e-3)


def test_bf16_inputs_accumulate_in_f32():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = rand(k1, (2, 8, 8, 16), jnp.bfloat16)
    wgt = rand(k2, (3, 3, 16, 16), jnp.bfloat16, scale=0.2)
    bias = jnp.zeros((16,), jnp.bfloat16)
    pre, y = conv2d_pallas_raw(x, wgt, bias, "relu")
    assert y.dtype == jnp.bfloat16
    yr = ref.conv2d_ref(x.astype(jnp.float32), wgt.astype(jnp.float32), bias.astype(jnp.float32), "relu")
    np.testing.assert_allclose(y.astype(jnp.float32), yr, rtol=5e-2, atol=5e-2)


def test_input_and_weight_grad_kernels_directly():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    x = rand(k1, (2, 6, 6, 3))
    wgt = rand(k2, (3, 3, 3, 5), scale=0.3)
    g = rand(k3, (2, 6, 6, 5))
    # Reference via autodiff of the pure conv.
    gx_ref, gw_ref = jax.grad(
        lambda xx, ww: (ref.conv2d_ref(xx, ww, jnp.zeros((5,)), "id") * g).sum(), argnums=(0, 1)
    )(x, wgt)
    gx = conv2d_input_grad(g, wgt)
    gw = conv2d_weight_grad(x, g, 3, 3)
    np.testing.assert_allclose(gx, gx_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ACTS)
def test_act_grad_finite_difference(act):
    pre = jnp.linspace(-2.0, 2.0, 41)
    eps = 1e-3
    from compile.kernels.conv import _apply_act

    fd = (_apply_act(pre + eps, act) - _apply_act(pre - eps, act)) / (2 * eps)
    ad = act_grad(pre, act)
    # ReLU/leaky kink at 0 excluded.
    mask = jnp.abs(pre) > 1e-2
    np.testing.assert_allclose(ad[mask], fd[mask], rtol=1e-3, atol=1e-3)


def test_downsample_is_stride2_conv_equivalent():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = rand(k1, (2, 8, 8, 4))
    wgt = rand(k2, (3, 3, 4, 6), scale=0.3)
    b = jnp.zeros((6,))
    full = ref.conv2d_ref(x, wgt, b, "id")
    strided = jax.lax.conv_general_dilated(
        x, wgt, window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(downsample2x(full), strided, rtol=1e-5, atol=1e-5)


def test_kernel_footprint_model():
    fp = kernel_footprint(1, 32, 32, 16, 16, 3, 3)
    assert fp["matmul_mkn"] == (1024, 144, 16)
    assert fp["flops"] == 2.0 * 1024 * 144 * 16
    assert 0.0 < fp["mxu_utilization_est"] <= 1.0
    assert fp["vmem_bytes"] > 0
    # Larger channel counts fill the MXU better.
    fp2 = kernel_footprint(1, 32, 32, 128, 128, 3, 3)
    assert fp2["mxu_utilization_est"] > fp["mxu_utilization_est"]
