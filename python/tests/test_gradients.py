# The paper's core claims at the block level (§III, §IV):
#   1. DTO VJP == jax autodiff through the discrete solver (exact).
#   2. OTD gradient error is O(dt) relative to DTO.
#   3. Neural-ODE [8] reconstruction error does not vanish; its gradient is
#      corrupted for generic (non-contractive) blocks.
#   4. RK2 (self-adjoint) narrows the OTD/DTO gap vs Euler.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.NetConfig(arch="resnet", batch=2, image=8, channels=(8,))
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    z = jax.random.normal(k1, (2, 8, 8, 8), jnp.float32) * 0.5
    theta = []
    for i, (_, s) in enumerate(configs.block_param_shapes(cfg, 0)):
        k2, sub = jax.random.split(k2)
        theta.append(jax.random.normal(sub, s) * (0.25 if len(s) == 4 else 0.05))
    g = jax.random.normal(k2, z.shape)
    return z, theta, g


def test_dto_vjp_equals_jax_grad(tiny_setup):
    z, theta, g = tiny_setup
    nt = 4
    fwd = model.block_fwd("resnet", "euler", nt)
    vjp = model.block_vjp("resnet", "euler", nt)
    outs = vjp(z, *theta, g)
    _, pull = jax.vjp(lambda zz, *th: fwd(zz, *th)[0], z, *theta)
    expect = pull(g)
    for a, b in zip(outs, expect):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_otd_error_scales_linearly_with_dt(tiny_setup):
    z, theta, g = tiny_setup
    errs = {}
    for nt in (4, 8, 16, 32):
        dto = model.block_vjp("resnet", "euler", nt)(z, *theta, g)
        otd = model.block_otd("resnet", "euler", nt)(z, *theta, g)
        errs[nt] = float(
            jnp.linalg.norm(otd[0] - dto[0]) / jnp.linalg.norm(dto[0])
        )
    # Halving dt should roughly halve the error (O(dt)).
    r1 = errs[4] / errs[8]
    r2 = errs[8] / errs[16]
    r3 = errs[16] / errs[32]
    for r in (r1, r2, r3):
        assert 1.4 < r < 2.8, f"O(dt) scaling violated: {errs}"


def test_node_reconstruction_fails_for_generic_block(tiny_setup):
    z, theta, g = tiny_setup
    nt = 8
    fwd = model.block_fwd("resnet", "euler", nt)
    z1 = fwd(z, *theta)[0]
    node = model.block_node("resnet", "euler", nt)
    outs = node(z1, *theta, g)
    z0_rec = outs[-1]
    rec_err = float(jnp.linalg.norm(z0_rec - z) / jnp.linalg.norm(z))
    assert rec_err > 0.05, f"expected O(1) reconstruction error, got {rec_err}"
    # And the resulting gradient differs from DTO far beyond O(dt).
    dto = model.block_vjp("resnet", "euler", nt)(z, *theta, g)
    gerr = float(jnp.linalg.norm(outs[0] - dto[0]) / jnp.linalg.norm(dto[0]))
    assert gerr > 0.05, f"node gradient suspiciously accurate: {gerr}"


def test_node_is_accurate_for_tiny_lipschitz_block(tiny_setup):
    # §III theory: with a small enough Lipschitz constant the reverse solve
    # IS well conditioned — [8] works there. Scale θ down hard.
    z, theta, g = tiny_setup
    theta_small = [t * 0.05 for t in theta]
    nt = 16
    fwd = model.block_fwd("resnet", "euler", nt)
    z1 = fwd(z, *theta_small)[0]
    outs = model.block_node("resnet", "euler", nt)(z1, *theta_small, g)
    rec_err = float(jnp.linalg.norm(outs[-1] - z) / jnp.linalg.norm(z))
    assert rec_err < 1e-2, f"small-λ reconstruction should work: {rec_err}"
    dto = model.block_vjp("resnet", "euler", nt)(z, *theta_small, g)
    gerr = float(jnp.linalg.norm(outs[0] - dto[0]) / jnp.linalg.norm(dto[0]))
    assert gerr < 0.05, f"small-λ node grad should be close: {gerr}"


def test_rk2_self_adjointness_narrows_gap(tiny_setup):
    # DTO-vs-node gap under RK2 with stored-output start should behave like
    # Euler or better for well-conditioned θ; mainly we verify RK2 block
    # machinery runs and VJP matches autodiff.
    z, theta, g = tiny_setup
    nt = 8
    fwd = model.block_fwd("resnet", "rk2", nt)
    vjp = model.block_vjp("resnet", "rk2", nt)
    outs = vjp(z, *theta, g)
    _, pull = jax.vjp(lambda zz, *th: fwd(zz, *th)[0], z, *theta)
    expect = pull(g)
    for a, b in zip(outs, expect):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_step_fwd_composes_to_block_fwd(tiny_setup):
    z, theta, _ = tiny_setup
    nt = 4
    step = model.block_step_fwd("resnet", "euler", nt)
    zz = z
    for _ in range(nt):
        zz = step(zz, *theta)[0]
    full = model.block_fwd("resnet", "euler", nt)(z, *theta)[0]
    np.testing.assert_allclose(zz, full, rtol=1e-6, atol=1e-7)


def test_step_vjp_chain_equals_block_vjp(tiny_setup):
    # Chaining single-step VJPs in reverse (what the revolve executor does)
    # reproduces the fused block VJP exactly: the revolve correctness
    # argument at the JAX level.
    z, theta, g = tiny_setup
    nt = 4
    step_f = model.block_step_fwd("resnet", "euler", nt)
    step_b = model.block_step_vjp("resnet", "euler", nt)
    states = [z]
    for _ in range(nt):
        states.append(step_f(states[-1], *theta)[0])
    adj = g
    gth_acc = [jnp.zeros_like(t) for t in theta]
    for i in reversed(range(nt)):
        outs = step_b(states[i], *theta, adj)
        adj = outs[0]
        gth_acc = [a + d for a, d in zip(gth_acc, outs[1:])]
    block = model.block_vjp("resnet", "euler", nt)(z, *theta, g)
    np.testing.assert_allclose(adj, block[0], rtol=1e-5, atol=1e-6)
    for a, b in zip(gth_acc, block[1:]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_sqnxt_block_vjp_matches_autodiff():
    cfg = configs.NetConfig(arch="sqnxt", batch=2, image=8, channels=(8,))
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    z = jax.random.normal(k1, (2, 8, 8, 8)) * 0.5
    theta = []
    for _, s in configs.block_param_shapes(cfg, 0):
        k2, sub = jax.random.split(k2)
        theta.append(jax.random.normal(sub, s) * (0.3 if len(s) == 4 else 0.05))
    g = jax.random.normal(k2, z.shape)
    nt = 3
    fwd = model.block_fwd("sqnxt", "euler", nt)
    outs = model.block_vjp("sqnxt", "euler", nt)(z, *theta, g)
    _, pull = jax.vjp(lambda zz, *th: fwd(zz, *th)[0], z, *theta)
    expect = pull(g)
    for a, b in zip(outs, expect):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_head_loss_grad_matches_autodiff():
    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    z = jax.random.normal(k1, (4, 8, 8, 16))
    w = jax.random.normal(k2, (16, 10)) * 0.3
    b = jnp.zeros((10,))
    labels = jnp.asarray([1.0, 3.0, 7.0, 3.0])
    loss, correct, gz, gw, gb = model.head_loss_grad_fn(z, w, b, labels)
    from compile.model import _head_loss

    gradfn = jax.grad(lambda zz, ww, bb: _head_loss(zz, ww, bb, labels)[0], argnums=(0, 1, 2))
    egz, egw, egb = gradfn(z, w, b)
    np.testing.assert_allclose(gz, egz, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gw, egw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, egb, rtol=1e-5, atol=1e-6)
    assert 0 <= float(correct) <= 4
    assert float(loss) > 0
