//! Offline stub of the `xla` (xla-rs / PJRT) API surface that `anode`
//! uses. The build image has no network access and no prebuilt
//! xla_extension, so this crate keeps the whole workspace compiling and
//! the host-side test suite green; every operation that would need a real
//! backend returns a descriptive [`Error`] instead.
//!
//! To execute AOT artifacts for real, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs crate (same API surface — this
//! stub mirrors the subset `anode::runtime::client` calls; see
//! rust/DESIGN.md §5).

/// Error type mirroring `xla::Error` as a plain message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Devices the stub platform simulates: `ANODE_SIM_DEVICES=N` (N >= 1),
/// default 1. A malformed or zero value falls back to 1 — the simulated
/// platform always has at least one device, like a real PJRT client.
pub fn simulated_device_count() -> usize {
    std::env::var("ANODE_SIM_DEVICES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires a real XLA/PJRT backend — this build links the offline \
         `xla` stub (rust/vendor/xla-stub); point the `xla` dependency in \
         rust/Cargo.toml at xla-rs to execute artifacts"
    )))
}

/// Element types a [`Literal`] can hold / convert to. The stub only ships
/// f32, the sole dtype anode's artifact I/O uses.
pub trait NativeType: Sized + Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// Host-side PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Stub client creation succeeds, so manifest-only workflows (engine
    /// build, validation, listing) run anywhere; execution fails later
    /// with a clear message.
    pub fn cpu() -> Result<Self> {
        Ok(Self { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of devices this client exposes. The stub simulates an
    /// N-device platform when `ANODE_SIM_DEVICES=N` is set (the offline
    /// multi-device harness — see `anode::runtime::DeviceSet`), mirroring
    /// xla-rs's `PjRtClient::device_count`; default is 1.
    pub fn device_count(&self) -> usize {
        simulated_device_count()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO module")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("parsing HLO text")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a module")
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

/// Dims of an array-shaped literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal: shape + f32 data. Enough to stage inputs; outputs only
/// ever come from [`PjRtBuffer::to_literal_sync`], which the stub refuses.
pub struct Literal {
    shape: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            shape: vec![data.len() as i64],
            data: data.iter().map(|&v| v.to_f32()).collect(),
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} wants {} elements, literal has {}",
                dims,
                want,
                self.data.len()
            )));
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.shape.clone() })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("tuple decomposition")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[5]).is_err());
    }

    #[test]
    fn backend_operations_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub");
        let err = HloModuleProto::from_text_file("/tmp/x.hlo").unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn simulated_platform_has_at_least_one_device() {
        // Without touching the process environment (other tests run in
        // parallel), the contract that holds for every env value is
        // "at least one device".
        let client = PjRtClient::cpu().unwrap();
        assert!(client.device_count() >= 1);
        assert!(simulated_device_count() >= 1);
    }
}
