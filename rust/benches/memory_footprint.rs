//! Bench + regeneration of the §V memory table: modeled peaks over (L, Nt)
//! plus LIVE ledger measurements from real coordinator backward passes.
//! Requires `make artifacts`. `cargo bench --bench memory_footprint`

use anode::coordinator::Coordinator;
use anode::data::SyntheticCifar;
use anode::harness::{format_memtable, memory_table};
use anode::memory::{human_bytes, Category, MemoryLedger};
use anode::models::{Arch, GradMethod, ModelConfig, Solver};
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;

fn main() {
    println!("=== §V — activation-memory footprint (model) ===\n");
    let act = 32 * 32 * 32 * 16 * 4usize; // one stage-0 activation
    let rows = memory_table(&[6, 8, 16], &[5, 16, 32], &[2, 4], act);
    println!("{}", format_memtable(&rows));

    let Ok(reg) = ArtifactRegistry::open(std::path::Path::new("artifacts")) else {
        eprintln!("artifacts/ missing — skipping live measurement");
        return;
    };
    println!("=== live ledger measurement (ResNet, Euler, one batch) ===\n");
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    println!(
        "{:<22} {:>16} {:>16} {:>12}",
        "method", "block_input peak", "step_state peak", "wall"
    );
    for method in [
        GradMethod::Anode,
        GradMethod::AnodeRevolve(3),
        GradMethod::AnodeRevolve(1),
        GradMethod::Node,
    ] {
        let co = Coordinator::new(&reg, cfg.clone(), Solver::Euler, method).unwrap();
        let params = co.load_params().unwrap();
        let mut ledger = MemoryLedger::new();
        let t0 = std::time::Instant::now();
        co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap();
        println!(
            "{:<22} {:>16} {:>16} {:>12.2?}",
            method.name(),
            human_bytes(ledger.peak_of(Category::BlockInput)),
            human_bytes(ledger.peak_of(Category::StepState)),
            t0.elapsed()
        );
    }
    println!("\nshape check: store_all O(L*Nt) > anode O(L)+O(Nt) > revolve O(L)+O(m) > node O(L).");
}
