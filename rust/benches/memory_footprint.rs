//! Bench + regeneration of the §V memory table: modeled peaks over (L, Nt)
//! plus LIVE ledger measurements from real backward passes through the
//! `anode::api` façade.
//! Requires `make artifacts`. `cargo bench --bench memory_footprint`

use anode::api::{Engine, SessionConfig};
use anode::data::SyntheticCifar;
use anode::harness::{format_memtable, memory_table};
use anode::memory::{human_bytes, Category};
use anode::tensor::Tensor;

fn main() {
    println!("=== §V — activation-memory footprint (model) ===\n");
    let act = 32 * 32 * 32 * 16 * 4usize; // one stage-0 activation
    let rows = memory_table(&[6, 8, 16], &[5, 16, 32], &[2, 4], act);
    println!("{}", format_memtable(&rows));

    let Ok(engine) = Engine::builder().artifacts("artifacts").build() else {
        eprintln!("artifacts/ missing — skipping live measurement");
        return;
    };
    println!("=== live ledger measurement (ResNet, Euler, one batch) ===\n");
    let batch = engine.config().batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    println!(
        "{:<22} {:>16} {:>16} {:>12}",
        "method", "block_input peak", "step_state peak", "wall"
    );
    for method in
        ["anode", "anode-revolve3", "anode-revolve1", "node", "symplectic", "interp-adjoint3"]
    {
        let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
        let t0 = std::time::Instant::now();
        session.loss_and_grad(&imgs, &y).unwrap();
        println!(
            "{:<22} {:>16} {:>16} {:>12.2?}",
            method,
            human_bytes(session.memory().peak_of(Category::BlockInput)),
            human_bytes(session.memory().peak_of(Category::StepState)),
            t0.elapsed()
        );
    }
    println!("\nshape check: store_all O(L*Nt) > anode O(L)+O(Nt) > revolve O(L)+O(m) > node O(L).");
}
