//! `compile_throughput` — the compiled backend (`anode::compile`) vs the
//! sim interpreter, emitted to `BENCH_compile.json`. Runs on every build
//! (simulated artifacts, no accelerator needed):
//!
//! 1. **Per-call dispatch** — the same module called through the sim
//!    interpreter (per-call spec walk + name hash + shape checks), the
//!    compiled plan (validated path), and the compiled trusted path
//!    (arity check only). The gap is exactly the per-call interpretation
//!    the compile pipeline moves to open time.
//! 2. **Fused inference** — the whole forward chain as sequential
//!    registry calls vs one [`InferProgram`] over the liveness-planned
//!    arena. Alongside latency, the shared [`CompileStats`] counters
//!    prove the steady state performs **zero arena allocations**.
//! 3. **Compile cost** — one full manifest compile (IR → passes →
//!    plans), the price paid once at open.
//!
//! `cargo bench --bench compile_throughput`; `ANODE_BENCH_QUICK=1`
//! shrinks iteration counts for the CI bench-smoke job while still
//! writing the full `BENCH_compile.json` artifact.

use anode::compile::{CompiledSet, InferCall, InferProgram};
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::{ArtifactRegistry, Backend};
use anode::tensor::Tensor;
use anode::util::bench::{bench, black_box, quick_mode, BenchStats};

fn main() {
    println!("=== compile_throughput — compiled plans vs the sim interpreter ===\n");
    let quick = quick_mode();
    let iters = if quick { 300 } else { 3000 };
    let warmup = iters / 10;

    let dir = std::env::temp_dir().join(format!("anode_bench_compile_{}", std::process::id()));
    if let Err(e) = write_artifacts(&dir, &SimSpec::default()) {
        eprintln!("could not write sim artifacts: {e} — skipping compile_throughput");
        return;
    }
    let sim = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Sim).unwrap();
    let compiled = ArtifactRegistry::open_with_backend(&dir, 0, Backend::Compiled).unwrap();

    // --- 1. per-call dispatch on one representative hot module ---------
    let module = "block_resnet_s0_euler_fwd";
    let shapes: Vec<Vec<usize>> =
        sim.module_spec(module).unwrap().inputs.iter().map(|t| t.shape.clone()).collect();
    let inputs: Vec<Tensor> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let n: usize = s.iter().product::<usize>().max(1);
            let data = (0..n).map(|j| ((i * 97 + j) % 89) as f32 * 0.5 - 22.0).collect();
            Tensor::from_vec(s.clone(), data).unwrap()
        })
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();

    let sim_call = bench(&format!("sim::call({module})"), warmup, iters, || {
        black_box(sim.call(module, &refs).unwrap());
    });
    let compiled_call = bench(&format!("compiled::call({module})"), warmup, iters, || {
        black_box(compiled.call(module, &refs).unwrap());
    });
    let trusted_call = bench(&format!("compiled::call_trusted({module})"), warmup, iters, || {
        black_box(compiled.call_trusted(module, &refs).unwrap());
    });
    println!("{}", sim_call.report());
    println!("{}", compiled_call.report());
    println!("{}", trusted_call.report());

    // --- 2. fused inference program vs sequential registry calls -------
    let layout: Vec<Vec<usize>> =
        compiled.param_layout("resnet10").unwrap().iter().map(|p| p.shape.clone()).collect();
    let chain = [
        InferCall { module: "stem_fwd".into(), params: vec![0, 1] },
        InferCall { module: "block_resnet_s0_euler_fwd".into(), params: vec![2, 3] },
        InferCall { module: "trans0_fwd".into(), params: vec![4, 5] },
        InferCall { module: "block_resnet_s1_euler_fwd".into(), params: vec![6, 7] },
    ];
    let prog = InferProgram::build(&compiled, &chain, &layout).unwrap();
    let params = compiled.load_params("resnet10").unwrap();
    let x = SimSpec::default().image_batch(1);

    let forward = |reg: &ArtifactRegistry| {
        let mut z = reg.call("stem_fwd", &[&x, &params[0], &params[1]]).unwrap().remove(0);
        for (module, w, b) in [
            ("block_resnet_s0_euler_fwd", 2usize, 3usize),
            ("trans0_fwd", 4, 5),
            ("block_resnet_s1_euler_fwd", 6, 7),
        ] {
            z = reg.call(module, &[&z, &params[w], &params[b]]).unwrap().remove(0);
        }
        z
    };
    let seq_sim = bench("forward: sequential sim calls", warmup, iters, || {
        black_box(forward(&sim));
    });
    let seq_compiled = bench("forward: sequential compiled calls", warmup, iters, || {
        black_box(forward(&compiled));
    });
    let stats_before_fused = compiled.compile_stats().unwrap();
    let fused = bench("forward: fused InferProgram::run", warmup, iters, || {
        black_box(prog.run(&x, &params).unwrap());
    });
    println!("{}", seq_sim.report());
    println!("{}", seq_compiled.report());
    println!("{}", fused.report());

    // The warmup allocates once per pooled arena; the timed steady state
    // must not allocate at all.
    let stats = compiled.compile_stats().unwrap();
    let steady_allocs = stats.arena_allocs - stats_before_fused.arena_allocs;
    let runs = (warmup + iters) as u64;
    println!(
        "\narena: {} bytes, {} alloc(s) over {} runs, {} pool reuses (steady-state allocs: {})",
        stats.arena_bytes,
        stats.arena_allocs,
        runs,
        stats.arena_reuses,
        steady_allocs.saturating_sub(1)
    );
    assert_eq!(stats.arena_allocs + stats.arena_reuses, runs, "every run hits the arena pool");
    assert_eq!(steady_allocs, 1, "exactly one warmup allocation, zero steady-state");

    // --- 3. one full manifest compile (the open-time cost) -------------
    let specs: Vec<_> =
        sim.module_names().iter().map(|&n| sim.module_spec(n).unwrap().clone()).collect();
    let compile_iters = if quick { 20 } else { 200 };
    let full_compile = bench("compile: full manifest", compile_iters / 10, compile_iters, || {
        black_box(CompiledSet::compile(specs.iter()).unwrap());
    });
    println!("{}", full_compile.report());

    let us = |s: &BenchStats| s.median.as_secs_f64() * 1e6;
    let json = format!(
        "{{\n  \"bench\": \"compile_throughput\",\n  \"mode\": \"sim\",\n  \
         \"iters\": {iters},\n  \
         \"sim_call_median_us\": {:.4},\n  \"compiled_call_median_us\": {:.4},\n  \
         \"trusted_call_median_us\": {:.4},\n  \
         \"forward_sim_median_us\": {:.4},\n  \"forward_compiled_median_us\": {:.4},\n  \
         \"forward_fused_median_us\": {:.4},\n  \
         \"full_compile_median_us\": {:.4},\n  \
         \"plans_cached\": {},\n  \"fused_ops\": {},\n  \"folded_consts\": {},\n  \
         \"arena_bytes\": {},\n  \"arena_allocs\": {},\n  \"arena_reuses\": {},\n  \
         \"steady_state_allocs\": {}\n}}\n",
        us(&sim_call),
        us(&compiled_call),
        us(&trusted_call),
        us(&seq_sim),
        us(&seq_compiled),
        us(&fused),
        us(&full_compile),
        stats.plans_cached,
        stats.fused_ops,
        stats.folded_consts,
        stats.arena_bytes,
        stats.arena_allocs,
        stats.arena_reuses,
        steady_allocs.saturating_sub(1),
    );
    match std::fs::write("BENCH_compile.json", &json) {
        Ok(()) => println!("\nwrote BENCH_compile.json"),
        Err(e) => eprintln!("could not write BENCH_compile.json: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
