//! `rollout_throughput` — the train→canary→promote/rollback orchestrator
//! (`anode::rollout`) over the simulated-device harness, emitted to
//! `BENCH_rollout.json`. Runs on every build (no real artifacts needed):
//!
//! 1. **Campaign under live traffic** — a promotion campaign runs on the
//!    caller's thread while a background client keeps the same pipeline
//!    busy; reports snapshot→swap promotion latency (p50/max), the
//!    serve-side p50/p95/p99 observed *during* the campaign, and the
//!    pipeline's own p99 for batches that completed inside a swap window
//!    (`rollout_swap_p99_us`).
//! 2. **Rollback detection** — a fault-injected device fails the canary
//!    step; reports the regression→last-good-swap latency.
//! 3. **Bit identity** — after the campaign, a far-deadline pipeline
//!    over the promoted snapshot must answer bitwise what the trainer's
//!    `predict_batches` answers. This is the flag the CI baseline gate
//!    (`bench_check`) hard-fails on.
//!
//! `cargo bench --bench rollout_throughput`; `ANODE_BENCH_QUICK=1`
//! shrinks the round count for the CI bench-smoke job while still
//! writing the full `BENCH_rollout.json` artifact.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anode::api::{Engine, Session, SessionConfig};
use anode::rollout::{RolloutConfig, RolloutOrchestrator};
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::ArtifactRegistry;
use anode::serve::{split_examples, ServeConfig, ServeHandle};
use anode::tensor::Tensor;
use anode::util::bench::{percentile, quick_mode};

const DEVICES: usize = 2;

fn main() {
    println!("=== rollout_throughput — canary campaigns on simulated devices ===\n");
    let quick = quick_mode();
    let rounds = if quick { 4 } else { 12 };
    let canary_every = 2;

    let dir = std::env::temp_dir().join(format!("anode_bench_rollout_{}", std::process::id()));
    if let Err(e) = write_artifacts(&dir, &SimSpec::default()) {
        eprintln!("could not write sim artifacts: {e} — skipping rollout_throughput");
        return;
    }
    let engine =
        Engine::builder().artifacts(&dir).devices(DEVICES).simulate(true).build().unwrap();
    let spec = SimSpec::default();
    let train: Vec<(Tensor, Tensor)> =
        (0..4).map(|k| (spec.image_batch(k), spec.label_batch(k))).collect();
    let eval: Vec<(Tensor, Tensor)> =
        (0..2).map(|k| (spec.image_batch(100 + k), spec.label_batch(100 + k))).collect();

    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let serve_cfg = ServeConfig::default().max_delay_ms(2).workers(2).queue_cap(512);
    let handle = session.serve(serve_cfg).unwrap();

    // Scenario 1: promotion campaign with a background client hammering
    // the same pipeline the whole time.
    let stop = Arc::new(AtomicBool::new(false));
    let traffic = spawn_traffic(&handle, &spec, stop.clone());
    let config =
        RolloutConfig::default().rounds(rounds).canary_every(canary_every).gate_threshold(10.0);
    let report = session.rollout(&handle, &train, &eval, config).unwrap();
    stop.store(true, Ordering::SeqCst);
    let mut serve_lat = traffic.join().unwrap();
    let (serve_p50, serve_p95, serve_p99) = pct_ms(&mut serve_lat);

    let mut promote_ms: Vec<f64> =
        report.promote_latency.iter().map(|d| d.as_secs_f64() * 1e3).collect();
    promote_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let promote_p50 = promote_ms.get(promote_ms.len() / 2).copied().unwrap_or(0.0);
    let promote_max = promote_ms.last().copied().unwrap_or(0.0);
    let stats = handle.stats();
    println!("--- campaign under live traffic ({rounds} rounds, {DEVICES} devices) ---");
    println!(
        "promotions={} rollbacks={} baseline_loss={:.4} wall={:.1}ms",
        report.promotions,
        report.rollbacks,
        report.baseline_loss,
        report.wall.as_secs_f64() * 1e3
    );
    println!("promote latency p50={promote_p50:.3}ms max={promote_max:.3}ms");
    println!(
        "serve during campaign p50={serve_p50:.3}ms p95={serve_p95:.3}ms p99={serve_p99:.3}ms \
         ({} samples); swap-window batch p99={}us",
        serve_lat.len(),
        stats.rollout_swap_p99_us
    );

    // Scenario 3 (while the pipeline is still up): bit identity of the
    // promoted snapshot. A far-deadline sibling pipeline reassembles the
    // exact batches, so replies must match predict_batches bitwise.
    let bit_identical = bit_identity(&session, &spec);
    println!("\n--- bit identity after promotion: {bit_identical} ---");
    handle.shutdown().unwrap();

    // Scenario 2: rollback detection with a fault-injected device 0.
    let rollback_detect_ms = rollback_detection(&dir, &train, &eval);

    let json = format!(
        "{{\n  \"bench\": \"rollout_throughput\",\n  \"mode\": \"sim\",\n  \
         \"devices\": {DEVICES},\n  \"rounds\": {rounds},\n  \
         \"canary_every\": {canary_every},\n  \
         \"promotions\": {},\n  \"rollbacks\": {},\n  \
         \"promote_p50_ms\": {promote_p50:.4},\n  \"promote_max_ms\": {promote_max:.4},\n  \
         \"serve_during_p50_ms\": {serve_p50:.4},\n  \
         \"serve_during_p95_ms\": {serve_p95:.4},\n  \
         \"serve_during_p99_ms\": {serve_p99:.4},\n  \
         \"swap_window_p99_us\": {},\n  \
         \"rollback_detect_ms\": {rollback_detect_ms:.4},\n  \
         \"bit_identical\": {bit_identical}\n}}\n",
        report.promotions, report.rollbacks, stats.rollout_swap_p99_us,
    );
    match std::fs::write("BENCH_rollout.json", &json) {
        Ok(()) => println!("\nwrote BENCH_rollout.json"),
        Err(e) => eprintln!("could not write BENCH_rollout.json: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Sort and summarize as (p50, p95, p99) in milliseconds.
fn pct_ms(lat: &mut [Duration]) -> (f64, f64, f64) {
    lat.sort();
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    if lat.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    (ms(percentile(lat, 50.0)), ms(percentile(lat, 95.0)), ms(percentile(lat, 99.0)))
}

/// Background client: submit examples in a loop until `stop`, recording
/// each reply's end-to-end pipeline latency.
fn spawn_traffic(
    handle: &ServeHandle,
    spec: &SimSpec,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<Vec<Duration>> {
    let handle = handle.clone();
    let examples = split_examples(&spec.image_batch(999)).unwrap();
    std::thread::spawn(move || {
        let mut lat = Vec::new();
        while !stop.load(Ordering::SeqCst) {
            let pendings: Vec<_> =
                examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
            for p in pendings {
                lat.push(p.wait().unwrap().stats.total());
            }
        }
        lat
    })
}

/// Serve the promoted snapshot through a full-batch pipeline and compare
/// classes + logits bitwise against the trainer's predict path.
fn bit_identity(session: &Session, spec: &SimSpec) -> bool {
    let far = ServeConfig::default().max_delay_ms(600_000).workers(2).queue_cap(512);
    let handle = session.serve(far).unwrap();
    let images: Vec<Tensor> = (0..2).map(|k| spec.image_batch(500 + k)).collect();
    let examples: Vec<Tensor> =
        images.iter().flat_map(|b| split_examples(b).unwrap()).collect();
    let pendings: Vec<_> = examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
    let served: Vec<(usize, Vec<f32>)> = pendings
        .into_iter()
        .map(|p| {
            let reply = p.wait().unwrap();
            (reply.class, reply.logits.data().to_vec())
        })
        .collect();
    handle.shutdown().unwrap();

    let pred = session.predict_batches_with_workers(&images, 1).unwrap();
    let mut expected = Vec::new();
    for p in &pred.predictions {
        let k = *p.logits.shape().last().unwrap();
        for (r, &class) in p.classes.iter().enumerate() {
            expected.push((class, p.logits.data()[r * k..(r + 1) * k].to_vec()));
        }
    }
    served.len() == expected.len()
        && served.iter().zip(&expected).all(|(a, b)| {
            a.0 == b.0
                && a.1.len() == b.1.len()
                && a.1.iter().zip(&b.1).all(|(x, y)| x.to_bits() == y.to_bits())
        })
}

/// A campaign over a fault-injected session: the canary step errors and
/// the orchestrator swaps last-good back in. Returns detection→swap
/// latency in milliseconds.
fn rollback_detection(
    dir: &std::path::Path,
    train: &[(Tensor, Tensor)],
    eval: &[(Tensor, Tensor)],
) -> f64 {
    let reg = Arc::new(ArtifactRegistry::open_simulated_with_fault(dir, 0, "stem_fwd").unwrap());
    let engine = Engine::builder().registry(reg).devices(DEVICES).build().unwrap();
    let mut session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let handle = session.serve(ServeConfig::default().max_delay_ms(2).workers(2)).unwrap();
    let config = RolloutConfig::default().rounds(1).canary_every(1).gate_threshold(10.0);
    let mut orch = RolloutOrchestrator::new(
        handle.clone(),
        Arc::new(session.params().to_vec()),
        config,
    );
    let report = orch.run(&mut session, train, eval).unwrap();
    handle.shutdown().unwrap();
    let ms = report
        .rollback_latency
        .first()
        .map(|d| d.as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    println!("\n--- rollback detection (injected stem_fwd fault on device 0) ---");
    println!("rollbacks={} detect->swap={ms:.3}ms", report.rollbacks);
    ms
}
