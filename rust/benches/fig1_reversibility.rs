//! Bench + regeneration of Fig. 1 / Fig. 7: reversibility of a random
//! Gaussian conv residual block across activations and solvers.
//! `cargo bench --bench fig1_reversibility`

use anode::harness::{fig1_reversibility, format_fig1};
use anode::util::bench::bench;

fn main() {
    println!("=== Fig. 1 / Fig. 7 — residual-block reversibility ===\n");
    let rows = fig1_reversibility(3, 3.0, 8);
    println!("{}", format_fig1(&rows));

    // Paper-shape assertions (who wins / what fails).
    let euler_bad = rows
        .iter()
        .filter(|r| r.solver.starts_with("euler"))
        .all(|r| r.rho > 1e-2);
    let rk45_bad = rows.iter().filter(|r| r.solver == "rk45").all(|r| r.rho > 1e-3);
    println!("shape check: euler roundtrip O(1) error = {euler_bad}; rk45 above own tol = {rk45_bad}\n");

    let s = bench("fig1_full_study(4 acts x 2 solvers)", 1, 5, || {
        anode::util::bench::black_box(fig1_reversibility(3, 3.0, 8));
    });
    println!("{}", s.report());
}
