//! Bench + regeneration of the §III scalar/matrix reversibility studies.
//! `cargo bench --bench sec3_scalar_reversibility`

use anode::harness::{format_sec3, sec3_scalar_studies};
use anode::util::bench::bench;

fn main() {
    println!("=== §III — scalar/matrix reversibility ===\n");
    let rows = sec3_scalar_studies(0);
    println!("{}", format_sec3(&rows));

    // Paper-shape assertions.
    let lin: Vec<_> = rows.iter().filter(|r| r.study == "linear_lambda-100").collect();
    println!(
        "shape check: lambda=-100 coarse rho={:.3} -> 200k-step rho={:.3} (paper: ~2e5 steps for % regime)",
        lin.first().unwrap().rho,
        lin.last().unwrap().rho
    );
    let raw128 = rows.iter().find(|r| r.study == "gaussian_W_raw" && r.param.contains("n=128")).unwrap();
    let norm128 = rows
        .iter()
        .find(|r| r.study == "gaussian_W_normalized" && r.param.contains("n=128"))
        .unwrap();
    println!(
        "shape check: gaussian W n=128 raw rho={:.3e} vs normalized rho={:.3e} (paper: normalization makes reversal possible)\n",
        raw128.rho, norm128.rho
    );

    let s = bench("sec3_full_study", 1, 3, || {
        anode::util::bench::black_box(sec3_scalar_studies(0));
    });
    println!("{}", s.report());
}
