//! `net_throughput` — the wire front end (`anode::net`) over the
//! simulated-device harness, emitted to `BENCH_net.json`. Runs on every
//! build (no real artifacts needed):
//!
//! 1. **Wire vs in-process** — the same request stream through a
//!    loopback `NetServer` (length-prefixed frames, blocking clients)
//!    vs direct `ServeHandle::submit`, p50/p95/p99 per-request latency
//!    on both paths. The gap is the protocol + reactor overhead.
//! 2. **Shed rate at saturation** — pipelined floods against a
//!    deliberately tiny admission queue; fraction of requests answered
//!    with `RetryAfter`, cross-checked against the scraped
//!    `anode_shed_total`.
//! 3. **Adaptive vs fixed `max_delay`** — the same mixed-SLO workload
//!    under a pinned flush window and under the arrival-rate-adaptive
//!    window, comparing client-observed latency and the final window.
//!
//! `cargo bench --bench net_throughput`; `ANODE_BENCH_QUICK=1` shrinks
//! request counts for the CI bench-smoke job while still writing the
//! full `BENCH_net.json` artifact.

use std::time::{Duration, Instant};

use anode::api::{Engine, Session, SessionConfig};
use anode::net::metrics::scrape_value;
use anode::net::{ClientReply, NetClient, NetConfig};
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::serve::{split_examples, ServeConfig, SloClass};
use anode::tensor::Tensor;
use anode::util::bench::{percentile, quick_mode};

fn main() {
    println!("=== net_throughput — socket front end on simulated devices ===\n");
    let quick = quick_mode();
    let requests = if quick { 32 } else { 96 };
    let clients = if quick { 3 } else { 4 };

    let dir = std::env::temp_dir().join(format!("anode_bench_net_{}", std::process::id()));
    if let Err(e) = write_artifacts(&dir, &SimSpec::default()) {
        eprintln!("could not write sim artifacts: {e} — skipping net_throughput");
        return;
    }
    let engine = Engine::builder().artifacts(&dir).devices(2).simulate(true).build().unwrap();
    let spec = SimSpec::default();
    let mut examples: Vec<Tensor> = Vec::with_capacity(requests);
    for k in 0.. {
        if examples.len() >= requests {
            break;
        }
        examples.extend(split_examples(&spec.image_batch(k)).unwrap());
    }
    examples.truncate(requests);

    let session = |engine: &Engine| engine.session(SessionConfig::with_method("anode")).unwrap();
    let (inproc, wire) = wire_vs_inprocess(&session(&engine), &examples, clients);
    let shed = saturation_shed_rate(&session(&engine), &examples);
    let fixed_cfg = ServeConfig::default().max_delay_ms(5).batch_delay_ms(20).workers(2);
    let fixed = delay_policy_run(&session(&engine), &examples, clients, fixed_cfg.clone(), "fixed");
    let adaptive_cfg = fixed_cfg.adaptive_delay_ms(1, 20);
    let adaptive =
        delay_policy_run(&session(&engine), &examples, clients, adaptive_cfg, "adaptive");

    let json = format!(
        "{{\n  \"bench\": \"net_throughput\",\n  \"mode\": \"sim\",\n  \
         \"requests\": {requests},\n  \"clients\": {clients},\n  \
         \"inprocess_p50_ms\": {:.4},\n  \"inprocess_p95_ms\": {:.4},\n  \
         \"inprocess_p99_ms\": {:.4},\n  \
         \"wire_p50_ms\": {:.4},\n  \"wire_p95_ms\": {:.4},\n  \"wire_p99_ms\": {:.4},\n  \
         \"wire_overhead_p50_ms\": {:.4},\n  \
         \"saturation_requests\": {},\n  \"saturation_shed\": {},\n  \
         \"saturation_shed_rate\": {:.4},\n  \
         \"fixed_p50_ms\": {:.4},\n  \"fixed_p95_ms\": {:.4},\n  \
         \"fixed_final_window_us\": {},\n  \"fixed_deadline_flushes\": {},\n  \
         \"adaptive_p50_ms\": {:.4},\n  \"adaptive_p95_ms\": {:.4},\n  \
         \"adaptive_final_window_us\": {},\n  \"adaptive_deadline_flushes\": {}\n}}\n",
        inproc.0,
        inproc.1,
        inproc.2,
        wire.0,
        wire.1,
        wire.2,
        wire.0 - inproc.0,
        shed.total,
        shed.shed,
        shed.rate,
        fixed.p50_ms,
        fixed.p95_ms,
        fixed.final_window_us,
        fixed.deadline_flushes,
        adaptive.p50_ms,
        adaptive.p95_ms,
        adaptive.final_window_us,
        adaptive.deadline_flushes,
    );
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("\nwrote BENCH_net.json"),
        Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Sort and summarize as (p50, p95, p99) in milliseconds.
fn pct_ms(lat: &mut [Duration]) -> (f64, f64, f64) {
    lat.sort();
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    (ms(percentile(lat, 50.0)), ms(percentile(lat, 95.0)), ms(percentile(lat, 99.0)))
}

/// Drive `examples` through a loopback server from `clients` blocking
/// client threads (interleaved shares, one request in flight each) and
/// return the client-observed wall latencies.
fn wire_latencies<F>(addr: &str, examples: &[Tensor], clients: usize, class_for: F) -> Vec<Duration>
where
    F: Fn(usize) -> SloClass + Sync,
{
    std::thread::scope(|scope| {
        let class_for = &class_for;
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let mut lat = Vec::new();
                    for i in (c..examples.len()).step_by(clients) {
                        let t0 = Instant::now();
                        let reply =
                            client.request_with_retry(&examples[i], class_for(i), 16).unwrap();
                        assert!(matches!(reply, ClientReply::Reply { .. }), "request {i} shed out");
                        lat.push(t0.elapsed());
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    })
}

/// Scenario 1: identical request stream in-process (`ServeHandle::submit`)
/// and over the wire; returns ((p50, p95, p99) ms, same) for both paths.
fn wire_vs_inprocess(
    session: &Session,
    examples: &[Tensor],
    clients: usize,
) -> ((f64, f64, f64), (f64, f64, f64)) {
    let config = ServeConfig::default().max_delay_ms(2).workers(2).queue_cap(512);

    let handle = session.serve(config.clone()).unwrap();
    let pendings: Vec<_> = examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
    let mut inproc: Vec<Duration> =
        pendings.into_iter().map(|p| p.wait().unwrap().stats.total()).collect();
    handle.shutdown().unwrap();
    let inproc = pct_ms(&mut inproc);

    let server = session.serve_net(config, NetConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let mut wire = wire_latencies(&addr, examples, clients, |_| SloClass::Interactive);
    let text = NetClient::connect(&addr).and_then(|mut c| c.metrics()).unwrap_or_default();
    let server_p50_us = scrape_value(&text, "net_latency_p50_us").unwrap_or(0);
    let report = server.shutdown().unwrap();
    let wire = pct_ms(&mut wire);

    println!("--- wire vs in-process ({} requests, {clients} clients) ---", examples.len());
    println!("in-process p50={:.3}ms p95={:.3}ms p99={:.3}ms", inproc.0, inproc.1, inproc.2);
    println!("wire       p50={:.3}ms p95={:.3}ms p99={:.3}ms", wire.0, wire.1, wire.2);
    println!(
        "wire overhead p50 {:+.3}ms (server-side wire p50 {server_p50_us}us, {} replies)",
        wire.0 - inproc.0,
        report.net.replies
    );
    (inproc, wire)
}

struct ShedRate {
    total: usize,
    shed: usize,
    rate: f64,
}

/// Scenario 2: pipelined floods against a one-worker, two-slot admission
/// queue — requests beyond capacity must come back as `RetryAfter`.
fn saturation_shed_rate(session: &Session, examples: &[Tensor]) -> ShedRate {
    let flood_clients = 4;
    let per_client = examples.len().min(24);
    let config = ServeConfig::default().max_delay_ms(1).workers(1).queue_cap(2);
    let server = session.serve_net(config, NetConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let shed: usize = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..flood_clients)
            .map(|_| {
                let addr = &addr;
                scope.spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let burst: Vec<Tensor> = examples[..per_client].to_vec();
                    let replies = client.pipeline(&burst, SloClass::Interactive).unwrap();
                    replies.iter().filter(|r| matches!(r, ClientReply::RetryAfter(_))).count()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let text = NetClient::connect(&addr).and_then(|mut c| c.metrics()).unwrap_or_default();
    let scraped_shed = scrape_value(&text, "shed_total").unwrap_or(0);
    server.shutdown().unwrap();

    let total = flood_clients * per_client;
    let rate = shed as f64 / total as f64;
    println!("\n--- shed rate at saturation (queue_cap=2, workers=1) ---");
    println!(
        "{total} pipelined requests -> {shed} shed ({:.1}%), \
         scraped anode_shed_total={scraped_shed}",
        100.0 * rate
    );
    ShedRate { total, shed, rate }
}

struct DelayPolicy {
    p50_ms: f64,
    p95_ms: f64,
    final_window_us: u64,
    deadline_flushes: u64,
}

/// Scenario 3: one mixed-SLO wire run under the given flush-window
/// policy; returns client latency plus the final interactive window.
fn delay_policy_run(
    session: &Session,
    examples: &[Tensor],
    clients: usize,
    config: ServeConfig,
    label: &str,
) -> DelayPolicy {
    let server = session.serve_net(config, NetConfig::default(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let mixed = |i: usize| if i % 4 == 3 { SloClass::Batch } else { SloClass::Interactive };
    let mut lat = wire_latencies(&addr, examples, clients, mixed);
    let text = NetClient::connect(&addr).and_then(|mut c| c.metrics()).unwrap_or_default();
    let final_window_us = scrape_value(&text, "max_delay_us").unwrap_or(0);
    let report = server.shutdown().unwrap();
    let (p50_ms, p95_ms, _) = pct_ms(&mut lat);

    println!("\n--- max_delay policy: {label} ---");
    println!(
        "p50={p50_ms:.3}ms p95={p95_ms:.3}ms  final window={final_window_us}us  \
         flushes full={} deadline={}",
        report.serve.full_flushes, report.serve.deadline_flushes
    );
    DelayPolicy { p50_ms, p95_ms, final_window_us, deadline_flushes: report.serve.deadline_flushes }
}
