//! Bench + regeneration of the §IV gradient-consistency study (DTO vs OTD
//! vs [8], dt sweep). Requires `make artifacts`.
//! `cargo bench --bench gradient_consistency`

use anode::harness::{format_gradcheck, gradient_consistency};
use anode::runtime::ArtifactRegistry;
use anode::util::bench::bench;

fn main() {
    let Ok(reg) = ArtifactRegistry::open(std::path::Path::new("artifacts")) else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== §IV — gradient consistency (tiny block, Euler) ===\n");
    let rows = gradient_consistency(&reg, 5).unwrap();
    println!("{}", format_gradcheck(&rows));
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "shape check: OTD err {:.3}->{:.3} (O(dt) decay), node recon {:.2}->{:.2} (stays large), dto-vs-fd <= {:.1e}\n",
        first.otd_rel_err,
        last.otd_rel_err,
        first.node_recon_err,
        last.node_recon_err,
        rows.iter().map(|r| r.dto_fd_err).fold(0.0f32, f32::max)
    );

    let s = bench("gradcheck_sweep(6 nt values)", 1, 2, || {
        anode::util::bench::black_box(gradient_consistency(&reg, 5).unwrap());
    });
    println!("{}", s.report());
}
