//! Bench + miniature regeneration of Fig. 5: ResNet-18-like ODE net on
//! (synthetic) Cifar-100 with Euler, ANODE vs neural-ODE [8].
//! Requires `make artifacts`. `cargo bench --bench fig5_resnet_cifar100`

use anode::harness::{train_figure, TrainFigOptions};
use anode::metrics::format_table;
use anode::models::{Arch, GradMethod, Solver};
use anode::api::open_artifacts;

fn main() {
    let Ok(reg) = open_artifacts("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== Fig. 5 (miniature) — ResNet+ODE on synthetic Cifar-100, Euler ===\n");
    let mut curves = Vec::new();
    for method in [GradMethod::Anode, GradMethod::Node] {
        let o = TrainFigOptions {
            arch: Arch::Resnet,
            solver: Solver::Euler,
            method,
            num_classes: 100,
            train_size: 160,
            test_size: 32,
            steps: 10,
            eval_every: 5,
            lr: 0.02,
            seed: 0,
            verbose: false,
            workers: 1,
            ..TrainFigOptions::default()
        };
        match train_figure(&reg, &o) {
            Ok(run) => {
                println!(
                    "{:<28} final_acc {:>6.2}%  diverged {}  sec/step {:.3}",
                    run.series,
                    run.curve.final_acc() * 100.0,
                    run.diverged,
                    run.sec_per_step
                );
                curves.push(run.curve);
            }
            Err(e) => eprintln!("{method:?} failed: {e}"),
        }
    }
    println!("\n{}", format_table(&curves));
    println!("note: chance accuracy is 1% on Cifar-100; the relative ordering (ANODE > [8]) is the reproduced shape.");
}
