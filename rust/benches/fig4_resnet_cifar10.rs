//! Bench + miniature regeneration of Fig. 4: ResNet-18-like ODE net on
//! (synthetic) Cifar-10 with Euler, ANODE vs neural-ODE [8] (+RK45 footnote).
//! Requires `make artifacts`. `cargo bench --bench fig4_resnet_cifar10`

use anode::harness::{train_figure, TrainFigOptions};
use anode::metrics::format_table;
use anode::models::{Arch, GradMethod, Solver};
use anode::api::open_artifacts;

fn main() {
    let Ok(reg) = open_artifacts("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== Fig. 4 (miniature) — ResNet+ODE on synthetic Cifar-10, Euler ===\n");
    let mut curves = Vec::new();
    for (method, solver, steps) in [
        (GradMethod::Anode, Solver::Euler, 10),
        (GradMethod::Node, Solver::Euler, 10),
        (GradMethod::Node, Solver::Rk45, 8),
    ] {
        let o = TrainFigOptions {
            arch: Arch::Resnet,
            solver,
            method,
            num_classes: 10,
            train_size: 160,
            test_size: 32,
            steps,
            eval_every: 5,
            lr: 0.02,
            seed: 0,
            verbose: false,
            workers: 1,
            ..TrainFigOptions::default()
        };
        match train_figure(&reg, &o) {
            Ok(run) => {
                println!(
                    "{:<28} final_acc {:>6.2}%  diverged {}  sec/step {:.3}",
                    run.series,
                    run.curve.final_acc() * 100.0,
                    run.diverged,
                    run.sec_per_step
                );
                curves.push(run.curve);
            }
            Err(e) => eprintln!("{method:?}/{solver:?} failed: {e}"),
        }
    }
    println!("\n{}", format_table(&curves));
}
