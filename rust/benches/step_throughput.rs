//! §V compute-cost claim: "ANODE has the same computational cost as the
//! neural ODE of [8]" — wall-clock per gradient computation, per method.
//! Requires `make artifacts`. `cargo bench --bench step_throughput`

use anode::coordinator::Coordinator;
use anode::data::SyntheticCifar;
use anode::memory::MemoryLedger;
use anode::models::{Arch, GradMethod, ModelConfig, Solver};
use anode::runtime::ArtifactRegistry;
use anode::tensor::Tensor;
use anode::util::bench::bench;

fn main() {
    let Ok(reg) = ArtifactRegistry::open(std::path::Path::new("artifacts")) else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== §V — per-step gradient cost by method (ResNet, Euler, B=32) ===\n");
    let cfg = ModelConfig::from_registry(&reg, Arch::Resnet, 10).unwrap();
    let batch = cfg.batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let mut anode_time = None;
    let mut node_time = None;
    for method in [
        GradMethod::Anode,
        GradMethod::Node,
        GradMethod::Otd,
        GradMethod::AnodeRevolve(3),
        GradMethod::AnodeRevolve(1),
        GradMethod::AnodeEquispaced(2),
    ] {
        let co = Coordinator::new(&reg, cfg.clone(), Solver::Euler, method).unwrap();
        let params = co.load_params().unwrap();
        let stats = bench(&format!("loss_and_grad[{}]", method.name()), 1, 3, || {
            let mut ledger = MemoryLedger::new();
            anode::util::bench::black_box(
                co.loss_and_grad(&imgs, &y, &params, &mut ledger).unwrap(),
            );
        });
        println!("{}", stats.report());
        match method {
            GradMethod::Anode => anode_time = Some(stats.median),
            GradMethod::Node => node_time = Some(stats.median),
            _ => {}
        }
    }
    if let (Some(a), Some(n)) = (anode_time, node_time) {
        println!(
            "\nshape check: anode/node cost ratio = {:.2} (paper claims ~1.0 — same cost)",
            a.as_secs_f64() / n.as_secs_f64()
        );
    }

    // Forward-only throughput for context.
    let co = Coordinator::new(&reg, cfg, Solver::Euler, GradMethod::Anode).unwrap();
    let params = co.load_params().unwrap();
    let stats = bench("forward_only", 1, 3, || {
        let mut ledger = MemoryLedger::new();
        anode::util::bench::black_box(co.forward(&imgs, &params, &mut ledger).unwrap());
    });
    println!("{}", stats.report());
}
