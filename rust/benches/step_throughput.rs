//! §V compute-cost claim: "ANODE has the same computational cost as the
//! neural ODE of [8]" — wall-clock per gradient computation, per method,
//! through the `anode::api` façade. Also times the batched inference path
//! (`Session::predict`), the serving-side number.
//! Requires `make artifacts`. `cargo bench --bench step_throughput`

use anode::api::{Engine, SessionConfig};
use anode::data::SyntheticCifar;
use anode::tensor::Tensor;
use anode::util::bench::bench;

fn main() {
    let Ok(engine) = Engine::builder().artifacts("artifacts").build() else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== §V — per-step gradient cost by method (ResNet, Euler, B=32) ===\n");
    let batch = engine.config().batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let mut anode_time = None;
    let mut node_time = None;
    for method in [
        "anode",
        "node",
        "otd",
        "anode-revolve3",
        "anode-revolve1",
        "anode-equispaced2",
    ] {
        let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
        let stats = bench(&format!("loss_and_grad[{method}]"), 1, 3, || {
            anode::util::bench::black_box(session.loss_and_grad(&imgs, &y).unwrap());
        });
        println!("{}", stats.report());
        match method {
            "anode" => anode_time = Some(stats.median),
            "node" => node_time = Some(stats.median),
            _ => {}
        }
    }
    if let (Some(a), Some(n)) = (anode_time, node_time) {
        println!(
            "\nshape check: anode/node cost ratio = {:.2} (paper claims ~1.0 — same cost)",
            a.as_secs_f64() / n.as_secs_f64()
        );
    }

    // Serving-side numbers: inference forward and the predict path.
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let stats = bench("predict(batched inference)", 1, 3, || {
        anode::util::bench::black_box(session.predict(&imgs).unwrap());
    });
    println!("{}", stats.report());
    if let Ok(p) = session.predict(&imgs) {
        println!(
            "predict: {:.0} examples/s, peak rolling activation {}B",
            p.stats.examples_per_sec, p.stats.peak_activation_bytes
        );
    }
}
