//! §V compute-cost claim: "ANODE has the same computational cost as the
//! neural ODE of [8]" — wall-clock per gradient computation, per method,
//! through the `anode::api` façade. Also times the batched inference path
//! (`Session::predict`), the parallel `predict_throughput` fan-out
//! (serial vs 4 workers, emitting `BENCH_predict.json`), and the
//! `serve_throughput` scenario: single requests through the
//! `anode::serve` deadline-batched admission queue vs the pre-batched
//! path, with a p50/p95/p99 per-request latency report emitted to
//! `BENCH_serve.json`.
//!
//! The `train_throughput` scenario times the data-parallel training step
//! (`Session::step_accumulate`, serial vs multi-worker, with a
//! bit-identity spot check) and the pool-reuse savings of the migrated
//! predict path (reused persistent pool vs per-call spawn), emitted to
//! `BENCH_train.json`.
//!
//! The `shard_throughput` scenario times pool-per-device sharding on the
//! `runtime::sim` simulated-device harness (1 device vs 4, training and
//! predict, with bit-identity and ledger-traffic checks), emitted to
//! `BENCH_shard.json` — it runs on every build, stub included.
//!
//! `cargo bench --bench step_throughput` (method timings need
//! `make artifacts`; `predict_throughput`, `serve_throughput`,
//! `train_throughput` and `shard_throughput` also run on the offline
//! stub). `ANODE_BENCH_QUICK=1` shrinks iteration/request counts for the
//! CI bench-smoke job while still writing all four `BENCH_*.json`
//! artifacts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anode::api::{head_logits, Engine, SessionConfig};
use anode::data::SyntheticCifar;
use anode::memory::MemoryLedger;
use anode::runtime::sim::{write_artifacts, SimSpec};
use anode::runtime::Backend;
use anode::serve::{split_examples, BatchRunner, HostTailRunner, ServeConfig, ServeHandle};
use anode::tensor::Tensor;
use anode::util::bench::{bench, black_box, percentile, quick_mode};
use anode::util::pool::{parallel_map, parallel_map_with, PersistentPool};

fn main() {
    let engine = Engine::builder().artifacts("artifacts").build();
    match &engine {
        Ok(engine) => method_timings(engine),
        Err(_) => eprintln!("artifacts/ missing — skipping per-method gradient timings"),
    }
    predict_throughput(engine.as_ref().ok());
    serve_throughput(engine.as_ref().ok());
    train_throughput(engine.as_ref().ok());
    shard_throughput();
}

fn method_timings(engine: &Engine) {
    println!("=== §V — per-step gradient cost by method (ResNet, Euler, B=32) ===\n");
    let iters = if quick_mode() { 1 } else { 3 };
    let batch = engine.config().batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let mut anode_time = None;
    let mut node_time = None;
    for method in [
        "anode",
        "node",
        "otd",
        "anode-revolve3",
        "anode-revolve1",
        "anode-equispaced2",
    ] {
        let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
        let stats = bench(&format!("loss_and_grad[{method}]"), 1, iters, || {
            black_box(session.loss_and_grad(&imgs, &y).unwrap());
        });
        println!("{}", stats.report());
        match method {
            "anode" => anode_time = Some(stats.median),
            "node" => node_time = Some(stats.median),
            _ => {}
        }
    }
    if let (Some(a), Some(n)) = (anode_time, node_time) {
        println!(
            "\nshape check: anode/node cost ratio = {:.2} (paper claims ~1.0 — same cost)",
            a.as_secs_f64() / n.as_secs_f64()
        );
    }

    // Serving-side numbers: inference forward and the predict path.
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let stats = bench("predict(batched inference)", 1, iters, || {
        black_box(session.predict(&imgs).unwrap());
    });
    println!("{}", stats.report());
    if let Ok(p) = session.predict(&imgs) {
        println!(
            "predict: {:.0} examples/s, peak rolling activation {}B",
            p.stats.examples_per_sec, p.stats.peak_activation_bytes
        );
    }
}

/// Serial vs 4-worker predict throughput. With real artifacts this times
/// `Session::predict_batches` end to end; on the offline stub it times the
/// host-side serving tail (global-average-pool + dense head over synthetic
/// activations) through the same `util::pool` worker pool, so the
/// parallel-speedup number exists on every build.
fn predict_throughput(engine: Option<&Engine>) {
    println!("\n=== predict_throughput — serial vs 4 workers ===\n");
    const WORKERS: usize = 4;
    let quick = quick_mode();

    let (mode, batch, n_batches, serial, par) = match engine {
        Some(engine) => {
            let cfg = engine.config().clone();
            let session = engine.session(SessionConfig::with_method("anode")).unwrap();
            let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.1);
            let count = if quick { 4 } else { 16 };
            let batches: Vec<Tensor> =
                (0..count).map(|k| ds.generate(cfg.batch, k as u64).0).collect();
            let iters = if quick { 1 } else { 3 };
            let serial = bench("predict_batches[workers=1]", 1, iters, || {
                black_box(session.predict_batches_with_workers(&batches, 1).unwrap());
            });
            let par = bench(&format!("predict_batches[workers={WORKERS}]"), 1, iters, || {
                black_box(session.predict_batches_with_workers(&batches, WORKERS).unwrap());
            });
            // Ledger-merge sanity for the printed numbers: same traffic.
            let s = session.predict_batches_with_workers(&batches, 1).unwrap();
            let p = session.predict_batches_with_workers(&batches, WORKERS).unwrap();
            println!(
                "ledger: serial traffic {}B, merged {}-worker traffic {}B (must match)",
                s.memory.total_traffic(),
                p.workers,
                p.memory.total_traffic()
            );
            ("session", cfg.batch, batches.len(), serial, par)
        }
        None => {
            // Host-side tail: (B, 16, 16, 64) activations through the
            // 10-class head — the post-XLA portion of every predict call.
            let (b, h, c, k) = (32usize, 16usize, 64usize, 10usize);
            let count = if quick { 8 } else { 48 };
            let zs: Vec<Tensor> = (0..count)
                .map(|i| Tensor::full(&[b, h, h, c], 0.01 * (i + 1) as f32))
                .collect();
            let w = Tensor::full(&[c, k], 0.05);
            let bias = Tensor::full(&[k], 0.1);
            let iters = if quick { 2 } else { 5 };
            let serial = bench("predict_tail[workers=1]", 1, iters, || {
                for z in &zs {
                    black_box(head_logits(z, &w, &bias).unwrap());
                }
            });
            let par = bench(&format!("predict_tail[workers={WORKERS}]"), 1, iters, || {
                black_box(parallel_map(&zs, WORKERS, |_, z| head_logits(z, &w, &bias).unwrap()));
            });
            ("stub-tail", b, zs.len(), serial, par)
        }
    };

    println!("{}", serial.report());
    println!("{}", par.report());
    let s_secs = serial.median.as_secs_f64();
    let p_secs = par.median.as_secs_f64();
    let examples = (batch * n_batches) as f64;
    let speedup = s_secs / p_secs.max(1e-12);
    println!(
        "speedup x{speedup:.2}  ({:.0} -> {:.0} examples/s)",
        examples / s_secs.max(1e-12),
        examples / p_secs.max(1e-12)
    );

    let json = format!(
        "{{\n  \"bench\": \"predict_throughput\",\n  \"mode\": \"{mode}\",\n  \
         \"batch\": {batch},\n  \"batches\": {n_batches},\n  \"workers\": {WORKERS},\n  \
         \"serial_median_secs\": {s_secs:.6},\n  \"workers{WORKERS}_median_secs\": {p_secs:.6},\n  \
         \"serial_examples_per_sec\": {:.1},\n  \"workers{WORKERS}_examples_per_sec\": {:.1},\n  \
         \"speedup\": {speedup:.3}\n}}\n",
        examples / s_secs.max(1e-12),
        examples / p_secs.max(1e-12),
    );
    match std::fs::write("BENCH_predict.json", &json) {
        Ok(()) => println!("wrote BENCH_predict.json"),
        Err(e) => eprintln!("could not write BENCH_predict.json: {e}"),
    }
}

/// Single-request serving through the `anode::serve` admission queue vs
/// the pre-batched predict path: p50/p95/p99 per-request latency plus
/// throughput, emitted to `BENCH_serve.json`. Replies are checked
/// bit-identical against the pre-batched run row by row. Works on the
/// offline stub via the `HostTailRunner` demo model.
fn serve_throughput(engine: Option<&Engine>) {
    println!("\n=== serve_throughput — deadline-batched queue vs pre-batched ===\n");
    const WORKERS: usize = 4;
    let quick = quick_mode();
    let max_delay = Duration::from_millis(2);
    let n_batches = if quick { 4 } else { 16 };

    match engine {
        Some(engine) => {
            let cfg = engine.config().clone();
            let session = engine.session(SessionConfig::with_method("anode")).unwrap();
            let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.1);
            let stacked: Vec<Tensor> =
                (0..n_batches).map(|k| ds.generate(cfg.batch, k as u64).0).collect();
            let t0 = Instant::now();
            let base = session.predict_batches_with_workers(&stacked, WORKERS).unwrap();
            let prebatched_eps =
                (n_batches * cfg.batch) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            let expected = expected_rows(base.predictions.iter().map(|p| (&p.classes, &p.logits)));
            let config = ServeConfig {
                max_delay,
                workers: WORKERS,
                queue_cap: 2 * cfg.batch,
                ..ServeConfig::default()
            };
            let handle = session.serve(config).unwrap();
            let args = ServeBenchArgs {
                mode: "session",
                batch: cfg.batch,
                max_delay,
                prebatched_eps,
            };
            run_serve_bench(args, handle, &stacked, &expected);
        }
        None => {
            let (b, h, c, k) = (32usize, 16usize, 64usize, 10usize);
            let runner = HostTailRunner::new(b, h, c, k);
            let ex_len = h * h * c;
            let stacked: Vec<Tensor> = (0..n_batches)
                .map(|i| {
                    let data = (0..b * ex_len)
                        .map(|j| (((i * 131 + j) % 977) as f32) * 0.001 - 0.3)
                        .collect();
                    Tensor::from_vec(vec![b, h, h, c], data).unwrap()
                })
                .collect();
            let t0 = Instant::now();
            let (base, _ledgers) =
                parallel_map_with(&stacked, WORKERS, MemoryLedger::new, |ledger, _i, z| {
                    runner.run(z, ledger).unwrap()
                });
            let prebatched_eps = (n_batches * b) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            let expected = expected_rows(base.iter().map(|p| (&p.classes, &p.logits)));
            let config = ServeConfig {
                max_delay,
                workers: WORKERS,
                queue_cap: 2 * b,
                ..ServeConfig::default()
            };
            let handle = ServeHandle::spawn(Arc::new(runner), config).unwrap();
            let args = ServeBenchArgs { mode: "stub-tail", batch: b, max_delay, prebatched_eps };
            run_serve_bench(args, handle, &stacked, &expected);
        }
    }
}

/// Flatten per-batch predictions into per-request (class, logits-row)
/// pairs in row order — the reference for the serve identity check.
fn expected_rows<'a, I>(predictions: I) -> Vec<(usize, Vec<f32>)>
where
    I: Iterator<Item = (&'a Vec<usize>, &'a Tensor)>,
{
    let mut rows = Vec::new();
    for (classes, logits) in predictions {
        let k = *logits.shape().last().unwrap_or(&1);
        for (r, &class) in classes.iter().enumerate() {
            rows.push((class, logits.data()[r * k..(r + 1) * k].to_vec()));
        }
    }
    rows
}

struct ServeBenchArgs {
    mode: &'static str,
    batch: usize,
    max_delay: Duration,
    prebatched_eps: f64,
}

fn run_serve_bench(
    args: ServeBenchArgs,
    handle: ServeHandle,
    stacked: &[Tensor],
    expected: &[(usize, Vec<f32>)],
) {
    let ServeBenchArgs { mode, batch, max_delay, prebatched_eps } = args;
    let max_delay_ms = max_delay.as_secs_f64() * 1e3;
    let examples: Vec<Tensor> = stacked.iter().flat_map(|b| split_examples(b).unwrap()).collect();
    let t0 = Instant::now();
    let pendings: Vec<_> = examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
    let mut latencies = Vec::with_capacity(pendings.len());
    let mut mismatches = 0usize;
    for (i, pending) in pendings.into_iter().enumerate() {
        let reply = pending.wait().unwrap();
        let (class, logits) = &expected[i];
        if reply.class != *class || reply.logits.data() != logits.as_slice() {
            mismatches += 1;
        }
        latencies.push(reply.stats.total());
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = handle.shutdown().unwrap();
    latencies.sort();
    let n = latencies.len();
    let serve_eps = n as f64 / wall.max(1e-12);
    let p50 = percentile(&latencies, 50.0);
    let p95 = percentile(&latencies, 95.0);
    let p99 = percentile(&latencies, 99.0);

    println!(
        "mode={mode} requests={n} batch={batch} workers={} max_delay={max_delay:?}",
        report.workers
    );
    println!("latency p50={p50:?} p95={p95:?} p99={p99:?}");
    println!(
        "throughput: serve {serve_eps:.0} examples/s vs pre-batched {prebatched_eps:.0} examples/s"
    );
    println!(
        "flushes: full={} deadline={} drain={}  memory: {}",
        report.full_flushes,
        report.deadline_flushes,
        report.drain_flushes,
        report.memory.summary()
    );
    println!(
        "bit-identity vs pre-batched path: {}",
        if mismatches == 0 { "OK" } else { "MISMATCH" }
    );
    if mismatches > 0 {
        eprintln!("WARNING: {mismatches} served replies diverged from the pre-batched path");
    }

    let json = format!(
        "{{\n  \"bench\": \"serve_throughput\",\n  \"mode\": \"{mode}\",\n  \
         \"batch\": {batch},\n  \"requests\": {n},\n  \"workers\": {},\n  \
         \"max_delay_ms\": {max_delay_ms:.3},\n  \
         \"p50_ms\": {:.4},\n  \"p95_ms\": {:.4},\n  \"p99_ms\": {:.4},\n  \
         \"serve_examples_per_sec\": {serve_eps:.1},\n  \
         \"prebatched_examples_per_sec\": {prebatched_eps:.1},\n  \
         \"full_flushes\": {},\n  \"deadline_flushes\": {},\n  \"drain_flushes\": {},\n  \
         \"bit_identical\": {}\n}}\n",
        report.workers,
        p50.as_secs_f64() * 1e3,
        p95.as_secs_f64() * 1e3,
        p99.as_secs_f64() * 1e3,
        report.full_flushes,
        report.deadline_flushes,
        report.drain_flushes,
        mismatches == 0,
    );
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

/// Data-parallel training-step throughput plus pool-reuse accounting,
/// emitted to `BENCH_train.json`.
///
/// Times one optimizer step over `accum` micro-batches serial vs
/// 4-worker (`Session::step_accumulate_with_workers`, with a bit-identity
/// spot check), and measures the spawn-overhead savings of the migrated
/// predict path: the same fan-out through the reused persistent pool vs a
/// per-call transient pool (what `predict_batches` paid before PR 4 —
/// compare the reused number against `BENCH_predict.json`, which now
/// rides the cached pool). On the offline stub the gradient stand-in is
/// the host-side serving tail run twice per micro-batch (forward +
/// same-cost pseudo-VJP).
fn train_throughput(engine: Option<&Engine>) {
    println!("\n=== train_throughput — data-parallel gradient accumulation ===\n");
    const WORKERS: usize = 4;
    let quick = quick_mode();
    let accum = if quick { 4 } else { 8 };
    let iters = if quick { 1 } else { 3 };

    let (mode, serial, par, identical, reused, per_call) = match engine {
        Some(engine) => {
            let cfg = engine.config().clone();
            let ds = SyntheticCifar::new(cfg.num_classes, 9, 0.1);
            let micro: Vec<(Tensor, Tensor)> = (0..accum)
                .map(|m| {
                    let (imgs, labels) = ds.generate(cfg.batch, m as u64);
                    let lf: Vec<f32> = labels.iter().map(|&l| l as f32).collect();
                    (imgs, Tensor::from_vec(vec![cfg.batch], lf).unwrap())
                })
                .collect();

            let mut s1 = engine.session(SessionConfig::with_method("anode")).unwrap();
            let serial = bench("step_accumulate[workers=1]", 1, iters, || {
                black_box(s1.step_accumulate_with_workers(&micro, 1).unwrap());
            });
            let mut sw = engine.session(SessionConfig::with_method("anode")).unwrap();
            let par = bench(&format!("step_accumulate[workers={WORKERS}]"), 1, iters, || {
                black_box(sw.step_accumulate_with_workers(&micro, WORKERS).unwrap());
            });

            // Bit-identity spot check on fresh sessions (the full grid
            // lives in rust/tests/concurrency.rs).
            let run = |workers: usize| {
                let mut s = engine.session(SessionConfig::with_method("anode")).unwrap();
                for _ in 0..2 {
                    s.step_accumulate_with_workers(&micro, workers).unwrap();
                }
                s.params().to_vec()
            };
            let identical = run(1) == run(WORKERS);

            // Pool reuse vs per-call spawn on the migrated predict path:
            // the session's cached persistent pool vs a transient pool
            // stood up per call over the same batches.
            let session = engine.session(SessionConfig::with_method("anode")).unwrap();
            let batches: Vec<Tensor> = micro.iter().map(|(imgs, _)| imgs.clone()).collect();
            let reused = bench("predict_batches[reused pool]", 1, iters, || {
                black_box(session.predict_batches_with_workers(&batches, WORKERS).unwrap());
            });
            let per_call = bench("predict_batches[per-call spawn]", 1, iters, || {
                black_box(parallel_map(&batches, WORKERS, |_, b| session.predict(b).unwrap()));
            });
            ("session", serial, par, identical, reused, per_call)
        }
        None => {
            // Host-side gradient stand-in: the serving tail forward plus a
            // same-cost pseudo-VJP pass per micro-batch, through the same
            // pooled fan-out the real step uses.
            let (b, h, c, k) = (32usize, 16usize, 64usize, 10usize);
            let zs: Vec<Tensor> = (0..accum)
                .map(|i| Tensor::full(&[b, h, h, c], 0.01 * (i + 1) as f32))
                .collect();
            let w = Tensor::full(&[c, k], 0.05);
            let bias = Tensor::full(&[k], 0.1);
            let grad_sim = |z: &Tensor| {
                let fwd = head_logits(z, &w, &bias).unwrap();
                let bwd = head_logits(z, &w, &bias).unwrap();
                (fwd, bwd)
            };
            let pool = PersistentPool::new(WORKERS, "bench-train", || ()).unwrap();
            let serial = bench("train_tail[workers=1]", 1, iters, || {
                for z in &zs {
                    black_box(grad_sim(z));
                }
            });
            let par = bench(&format!("train_tail[workers={WORKERS}]"), 1, iters, || {
                black_box(pool.map(WORKERS, &zs, |_, z| grad_sim(z)));
            });
            let mut direct = Vec::with_capacity(zs.len());
            for z in &zs {
                direct.push(grad_sim(z));
            }
            let pooled = pool.map(WORKERS, &zs, |_, z| grad_sim(z));
            let identical = direct == pooled;
            let reused = bench("train_tail[reused pool]", 1, iters, || {
                black_box(pool.map(WORKERS, &zs, |_, z| grad_sim(z)));
            });
            let per_call = bench("train_tail[per-call spawn]", 1, iters, || {
                black_box(parallel_map(&zs, WORKERS, |_, z| grad_sim(z)));
            });
            ("stub-tail", serial, par, identical, reused, per_call)
        }
    };

    println!("{}", serial.report());
    println!("{}", par.report());
    let s_secs = serial.median.as_secs_f64();
    let p_secs = par.median.as_secs_f64();
    let speedup = s_secs / p_secs.max(1e-12);
    println!("step speedup x{speedup:.2}  bit-identical to serial: {identical}");
    println!("{}", reused.report());
    println!("{}", per_call.report());
    let reused_secs = reused.median.as_secs_f64();
    let per_call_secs = per_call.median.as_secs_f64();
    let savings = per_call_secs - reused_secs;
    println!(
        "pool reuse saves {:.3} ms/call over per-call spawn ({:.1}% of the spawned call)",
        savings * 1e3,
        100.0 * savings / per_call_secs.max(1e-12)
    );
    if !identical {
        eprintln!("WARNING: parallel step diverged bitwise from serial");
    }

    let compiled_extra = compiled_train_section(iters).unwrap_or_default();

    let json = format!(
        "{{\n  \"bench\": \"train_throughput\",\n  \"mode\": \"{mode}\",\n  \
         \"micro_batches\": {accum},\n  \"workers\": {WORKERS},\n  \
         \"serial_step_median_secs\": {s_secs:.6},\n  \
         \"workers{WORKERS}_step_median_secs\": {p_secs:.6},\n  \
         \"step_speedup\": {speedup:.3},\n  \"bit_identical\": {identical},\n  \
         \"predict_reused_pool_median_secs\": {reused_secs:.6},\n  \
         \"predict_per_call_spawn_median_secs\": {per_call_secs:.6},\n  \
         \"spawn_overhead_savings_secs\": {savings:.6}{compiled_extra}\n}}\n"
    );
    match std::fs::write("BENCH_train.json", &json) {
        Ok(()) => println!("wrote BENCH_train.json"),
        Err(e) => eprintln!("could not write BENCH_train.json: {e}"),
    }
}

/// Compiled-vs-sim training step, per gradient strategy, on the sim
/// harness (runs on every build — no `artifacts/` needed): per-backend
/// step medians, the fused `TrainProgram`'s arena counters, and two
/// invariants the bench-baseline gate hard-fails on — bitwise identity
/// between the backends and zero steady-state arena allocations after
/// warmup. Returns the extra `BENCH_train.json` fields.
fn compiled_train_section(iters: usize) -> Option<String> {
    const STRATEGIES: [&str; 7] = [
        "anode",
        "node",
        "otd",
        "anode-revolve3",
        "anode-equispaced2",
        "symplectic",
        "interp-adjoint3",
    ];
    println!("\n--- compiled vs sim training step (per strategy, sim harness) ---\n");
    let dir = std::env::temp_dir().join(format!("anode_bench_ctrain_{}", std::process::id()));
    if let Err(e) = write_artifacts(&dir, &SimSpec::default()) {
        eprintln!("could not write sim artifacts: {e} — skipping compiled train section");
        return None;
    }
    let build = |backend: Backend| {
        Engine::builder().artifacts(&dir).devices(1).backend(backend).build().unwrap()
    };
    let sim = build(Backend::Sim);
    let compiled = build(Backend::Compiled);
    let spec = SimSpec::default();
    let (x, y) = (spec.image_batch(0), spec.label_batch(0));

    let mut fields = String::new();
    let mut identical = true;
    let mut steady_zero = true;
    for method in STRATEGIES {
        let mut a = sim.session(SessionConfig::with_method(method)).unwrap();
        let mut b = compiled.session(SessionConfig::with_method(method)).unwrap();
        // Warmup both sides (the compiled arena allocates here), spot-check
        // the loss bits, then pin the alloc counter across the timed runs.
        let la = a.step(&x, &y).unwrap().loss.to_bits();
        let lb = b.step(&x, &y).unwrap().loss.to_bits();
        identical &= la == lb;
        let warm = compiled.registry().compile_stats().unwrap().train_arena_allocs;
        let s = bench(&format!("step[sim,{method}]"), 1, iters, || {
            black_box(a.step(&x, &y).unwrap());
        });
        let c = bench(&format!("step[compiled,{method}]"), 1, iters, || {
            black_box(b.step(&x, &y).unwrap());
        });
        println!("{}", s.report());
        println!("{}", c.report());
        steady_zero &= compiled.registry().compile_stats().unwrap().train_arena_allocs == warm;
        let key = method.replace('-', "_");
        fields.push_str(&format!(
            ",\n  \"{key}_sim_step_median_secs\": {:.6},\n  \
             \"{key}_compiled_step_median_secs\": {:.6}",
            s.median.as_secs_f64(),
            c.median.as_secs_f64(),
        ));
    }
    let stats = compiled.registry().compile_stats().unwrap();
    println!(
        "compiled train arena: allocs={} reuses={} trajectory={}B recompute_segments={} \
         interp_nodes={}",
        stats.train_arena_allocs,
        stats.train_arena_reuses,
        stats.trajectory_bytes,
        stats.train_recompute_segments,
        stats.train_interp_nodes
    );
    println!("bit-identical to sim: {identical}  steady-state allocs zero: {steady_zero}");
    if !identical {
        eprintln!("WARNING: compiled training steps diverged bitwise from sim");
    }
    if !steady_zero {
        eprintln!("WARNING: compiled training allocated arenas after warmup");
    }
    fields.push_str(&format!(
        ",\n  \"train_arena_allocs\": {},\n  \"train_arena_reuses\": {},\n  \
         \"train_trajectory_bytes\": {},\n  \"train_recompute_segments\": {},\n  \
         \"train_interp_nodes\": {},\n  \
         \"train_compiled_bit_identical\": {identical},\n  \
         \"train_steady_state_allocs_zero\": {steady_zero}",
        stats.train_arena_allocs,
        stats.train_arena_reuses,
        stats.trajectory_bytes,
        stats.train_recompute_segments,
        stats.train_interp_nodes
    ));
    std::fs::remove_dir_all(&dir).ok();
    Some(fields)
}

/// Pool-per-device sharding on **simulated devices**, emitted to
/// `BENCH_shard.json`. Runs on every build: the model is the deterministic
/// `runtime::sim` harness (synthetic artifacts + value-level simulation),
/// so the full multi-device engine — per-device registries, device-pinned
/// worker pools, the load-aware `ShardRouter` — executes offline. Times a
/// data-parallel training step and a `predict_batches` sweep at 1 device
/// vs `DEVICES` devices, and asserts params/losses/logits bit-identical to
/// the serial run (the §6d invariant) plus ledger traffic equality.
fn shard_throughput() {
    println!("\n=== shard_throughput — pool-per-device sharding (simulated devices) ===\n");
    const DEVICES: usize = 4;
    const WORKERS: usize = 2; // per device
    let quick = quick_mode();
    let iters = if quick { 2 } else { 5 };
    let accum = if quick { 8 } else { 16 };
    let steps = 2;
    let n_predict = if quick { 16 } else { 64 };

    let dir = std::env::temp_dir().join(format!("anode_bench_shard_{}", std::process::id()));
    if let Err(e) = write_artifacts(&dir, &SimSpec::default()) {
        eprintln!("could not write sim artifacts: {e} — skipping shard_throughput");
        return;
    }
    let engine_for = |devices: usize| {
        Engine::builder().artifacts(&dir).devices(devices).simulate(true).build().unwrap()
    };
    let one = engine_for(1);
    let sharded = engine_for(DEVICES);

    // Deterministic inputs from the spec's shared generators (the same
    // ones rust/tests/sharding.rs uses).
    let spec = SimSpec::default();
    let micro: Vec<(Tensor, Tensor)> =
        (0..accum).map(|m| (spec.image_batch(m), spec.label_batch(m))).collect();
    let batches: Vec<Tensor> = (0..n_predict).map(|k| spec.image_batch(k + 1000)).collect();

    // --- training step: 1 device vs DEVICES devices -------------------
    let mut s1 = one.session(SessionConfig::with_method("anode")).unwrap();
    let one_dev = bench(&format!("step_accumulate[1 device x {WORKERS}]"), 1, iters, || {
        black_box(s1.step_accumulate_with_workers(&micro, WORKERS).unwrap());
    });
    let mut sd = sharded.session(SessionConfig::with_method("anode")).unwrap();
    let shard = bench(&format!("step_accumulate[{DEVICES} devices x {WORKERS}]"), 1, iters, || {
        black_box(sd.step_accumulate_with_workers(&micro, WORKERS).unwrap());
    });

    // Bit-identity + ledger traffic equality: fresh sessions, `steps`
    // accumulate-steps, compared against the serial (inline) run.
    let train_run = |engine: &Engine, workers: usize| {
        let mut s = engine.session(SessionConfig::with_method("anode")).unwrap();
        let t0 = s.memory().total_traffic();
        let mut losses = Vec::new();
        for _ in 0..steps {
            losses.push(s.step_accumulate_with_workers(&micro, workers).unwrap().loss.to_bits());
        }
        let params: Vec<u32> =
            s.params().iter().flat_map(|p| p.data().iter().map(|x| x.to_bits())).collect();
        (losses, params, s.memory().total_traffic() - t0)
    };
    let (loss_serial, params_serial, traffic_serial) = train_run(&one, 1);
    let (loss_shard, params_shard, traffic_shard) = train_run(&sharded, WORKERS);
    let train_identical = loss_serial == loss_shard && params_serial == params_shard;
    let traffic_equal = traffic_serial == traffic_shard;

    // --- predict sweep: 1 device vs DEVICES devices --------------------
    let p1 = one.session(SessionConfig::with_method("anode")).unwrap();
    let pd = sharded.session(SessionConfig::with_method("anode")).unwrap();
    let predict_one = bench(&format!("predict_batches[1 device x {WORKERS}]"), 1, iters, || {
        black_box(p1.predict_batches_with_workers(&batches, WORKERS).unwrap());
    });
    let predict_shard =
        bench(&format!("predict_batches[{DEVICES} devices x {WORKERS}]"), 1, iters, || {
            black_box(pd.predict_batches_with_workers(&batches, WORKERS).unwrap());
        });
    let serial_pred = p1.predict_batches_with_workers(&batches, 1).unwrap();
    let shard_pred = pd.predict_batches_with_workers(&batches, WORKERS).unwrap();
    let predict_identical = serial_pred
        .predictions
        .iter()
        .zip(&shard_pred.predictions)
        .all(|(a, b)| a.classes == b.classes && a.logits.data() == b.logits.data());
    let identical = train_identical && predict_identical;

    println!("{}", one_dev.report());
    println!("{}", shard.report());
    println!("{}", predict_one.report());
    println!("{}", predict_shard.report());
    let step_1 = one_dev.median.as_secs_f64();
    let step_d = shard.median.as_secs_f64();
    let pred_1 = predict_one.median.as_secs_f64();
    let pred_d = predict_shard.median.as_secs_f64();
    let step_speedup = step_1 / step_d.max(1e-12);
    let predict_speedup = pred_1 / pred_d.max(1e-12);
    println!(
        "sharding {DEVICES}x{WORKERS}: step x{step_speedup:.2}, predict x{predict_speedup:.2}  \
         bit-identical to serial: {identical}  traffic equal: {traffic_equal}"
    );
    if !identical {
        eprintln!("WARNING: sharded run diverged bitwise from serial");
    }

    let json = format!(
        "{{\n  \"bench\": \"shard_throughput\",\n  \"mode\": \"sim\",\n  \
         \"devices\": {DEVICES},\n  \"workers_per_device\": {WORKERS},\n  \
         \"micro_batches\": {accum},\n  \"predict_batches\": {n_predict},\n  \
         \"one_device_step_median_secs\": {step_1:.6},\n  \
         \"sharded_step_median_secs\": {step_d:.6},\n  \
         \"step_speedup\": {step_speedup:.3},\n  \
         \"one_device_predict_median_secs\": {pred_1:.6},\n  \
         \"sharded_predict_median_secs\": {pred_d:.6},\n  \
         \"predict_speedup\": {predict_speedup:.3},\n  \
         \"bit_identical\": {identical},\n  \"traffic_equal\": {traffic_equal}\n}}\n"
    );
    match std::fs::write("BENCH_shard.json", &json) {
        Ok(()) => println!("wrote BENCH_shard.json"),
        Err(e) => eprintln!("could not write BENCH_shard.json: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
