//! §V compute-cost claim: "ANODE has the same computational cost as the
//! neural ODE of [8]" — wall-clock per gradient computation, per method,
//! through the `anode::api` façade. Also times the batched inference path
//! (`Session::predict`), the serving-side number, and the parallel
//! `predict_throughput` fan-out (serial vs `--workers 4`), emitting
//! `BENCH_predict.json` to seed the perf trajectory.
//! `cargo bench --bench step_throughput` (method timings need
//! `make artifacts`; `predict_throughput` also runs on the offline stub,
//! where it times the host-side serving tail through the same worker pool).

use anode::api::{head_logits, Engine, SessionConfig};
use anode::data::SyntheticCifar;
use anode::tensor::Tensor;
use anode::util::bench::{bench, black_box};
use anode::util::pool::parallel_map;

fn main() {
    let engine = Engine::builder().artifacts("artifacts").build();
    match &engine {
        Ok(engine) => method_timings(engine),
        Err(_) => eprintln!("artifacts/ missing — skipping per-method gradient timings"),
    }
    predict_throughput(engine.as_ref().ok());
}

fn method_timings(engine: &Engine) {
    println!("=== §V — per-step gradient cost by method (ResNet, Euler, B=32) ===\n");
    let batch = engine.config().batch;
    let ds = SyntheticCifar::new(10, 3, 0.1);
    let (imgs, labels) = ds.generate(batch, 0);
    let y = Tensor::from_vec(vec![batch], labels.iter().map(|&l| l as f32).collect()).unwrap();

    let mut anode_time = None;
    let mut node_time = None;
    for method in [
        "anode",
        "node",
        "otd",
        "anode-revolve3",
        "anode-revolve1",
        "anode-equispaced2",
    ] {
        let mut session = engine.session(SessionConfig::with_method(method)).unwrap();
        let stats = bench(&format!("loss_and_grad[{method}]"), 1, 3, || {
            black_box(session.loss_and_grad(&imgs, &y).unwrap());
        });
        println!("{}", stats.report());
        match method {
            "anode" => anode_time = Some(stats.median),
            "node" => node_time = Some(stats.median),
            _ => {}
        }
    }
    if let (Some(a), Some(n)) = (anode_time, node_time) {
        println!(
            "\nshape check: anode/node cost ratio = {:.2} (paper claims ~1.0 — same cost)",
            a.as_secs_f64() / n.as_secs_f64()
        );
    }

    // Serving-side numbers: inference forward and the predict path.
    let session = engine.session(SessionConfig::with_method("anode")).unwrap();
    let stats = bench("predict(batched inference)", 1, 3, || {
        black_box(session.predict(&imgs).unwrap());
    });
    println!("{}", stats.report());
    if let Ok(p) = session.predict(&imgs) {
        println!(
            "predict: {:.0} examples/s, peak rolling activation {}B",
            p.stats.examples_per_sec, p.stats.peak_activation_bytes
        );
    }
}

/// Serial vs 4-worker predict throughput. With real artifacts this times
/// `Session::predict_batches` end to end; on the offline stub it times the
/// host-side serving tail (global-average-pool + dense head over synthetic
/// activations) through the same `util::pool` worker pool, so the
/// parallel-speedup number exists on every build.
fn predict_throughput(engine: Option<&Engine>) {
    println!("\n=== predict_throughput — serial vs 4 workers ===\n");
    const WORKERS: usize = 4;

    let (mode, batch, n_batches, serial, par) = match engine {
        Some(engine) => {
            let cfg = engine.config().clone();
            let session = engine.session(SessionConfig::with_method("anode")).unwrap();
            let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.1);
            let batches: Vec<Tensor> =
                (0..16).map(|k| ds.generate(cfg.batch, k as u64).0).collect();
            let serial = bench("predict_batches[workers=1]", 1, 3, || {
                black_box(session.predict_batches_with_workers(&batches, 1).unwrap());
            });
            let par = bench(&format!("predict_batches[workers={WORKERS}]"), 1, 3, || {
                black_box(session.predict_batches_with_workers(&batches, WORKERS).unwrap());
            });
            // Ledger-merge sanity for the printed numbers: same traffic.
            let s = session.predict_batches_with_workers(&batches, 1).unwrap();
            let p = session.predict_batches_with_workers(&batches, WORKERS).unwrap();
            println!(
                "ledger: serial traffic {}B, merged {}-worker traffic {}B (must match)",
                s.memory.total_traffic(),
                p.workers,
                p.memory.total_traffic()
            );
            ("session", cfg.batch, batches.len(), serial, par)
        }
        None => {
            // Host-side tail: (B, 16, 16, 64) activations through the
            // 10-class head — the post-XLA portion of every predict call.
            let (b, h, c, k) = (32usize, 16usize, 64usize, 10usize);
            let zs: Vec<Tensor> = (0..48)
                .map(|i| Tensor::full(&[b, h, h, c], 0.01 * (i + 1) as f32))
                .collect();
            let w = Tensor::full(&[c, k], 0.05);
            let bias = Tensor::full(&[k], 0.1);
            let serial = bench("predict_tail[workers=1]", 1, 5, || {
                for z in &zs {
                    black_box(head_logits(z, &w, &bias).unwrap());
                }
            });
            let par = bench(&format!("predict_tail[workers={WORKERS}]"), 1, 5, || {
                black_box(parallel_map(&zs, WORKERS, |_, z| head_logits(z, &w, &bias).unwrap()));
            });
            ("stub-tail", b, zs.len(), serial, par)
        }
    };

    println!("{}", serial.report());
    println!("{}", par.report());
    let s_secs = serial.median.as_secs_f64();
    let p_secs = par.median.as_secs_f64();
    let examples = (batch * n_batches) as f64;
    let speedup = s_secs / p_secs.max(1e-12);
    println!(
        "speedup x{speedup:.2}  ({:.0} -> {:.0} examples/s)",
        examples / s_secs.max(1e-12),
        examples / p_secs.max(1e-12)
    );

    let json = format!(
        "{{\n  \"bench\": \"predict_throughput\",\n  \"mode\": \"{mode}\",\n  \
         \"batch\": {batch},\n  \"batches\": {n_batches},\n  \"workers\": {WORKERS},\n  \
         \"serial_median_secs\": {s_secs:.6},\n  \"workers{WORKERS}_median_secs\": {p_secs:.6},\n  \
         \"serial_examples_per_sec\": {:.1},\n  \"workers{WORKERS}_examples_per_sec\": {:.1},\n  \
         \"speedup\": {speedup:.3}\n}}\n",
        examples / s_secs.max(1e-12),
        examples / p_secs.max(1e-12),
    );
    match std::fs::write("BENCH_predict.json", &json) {
        Ok(()) => println!("wrote BENCH_predict.json"),
        Err(e) => eprintln!("could not write BENCH_predict.json: {e}"),
    }
}
