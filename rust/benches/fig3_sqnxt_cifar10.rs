//! Bench + miniature regeneration of Fig. 3: SqueezeNext+ODE on (synthetic)
//! Cifar-10, Euler (top) and RK2 (bottom), ANODE vs neural-ODE [8], plus the
//! [8]+RK45 divergence footnote. Short-budget version — the full curves come
//! from `anode figures --fig fig3` (see Makefile `figures` target).
//! Requires `make artifacts`. `cargo bench --bench fig3_sqnxt_cifar10`

use anode::harness::{train_figure, TrainFigOptions};
use anode::metrics::format_table;
use anode::models::{Arch, GradMethod, Solver};
use anode::api::open_artifacts;

fn main() {
    let Ok(reg) = open_artifacts("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        return;
    };
    println!("=== Fig. 3 (miniature) — SqueezeNext+ODE on synthetic Cifar-10 ===\n");
    let mut curves = Vec::new();
    let mut summary = Vec::new();
    for solver in [Solver::Euler, Solver::Rk2] {
        for method in [GradMethod::Anode, GradMethod::Node] {
            let o = TrainFigOptions {
                arch: Arch::Sqnxt,
                solver,
                method,
                num_classes: 10,
                train_size: 160,
                test_size: 32,
                steps: 10,
                eval_every: 5,
                lr: 0.02,
                seed: 0,
                verbose: false,
                workers: 1,
                ..TrainFigOptions::default()
            };
            match train_figure(&reg, &o) {
                Ok(run) => {
                    summary.push((run.series.clone(), run.curve.final_acc(), run.diverged, run.sec_per_step));
                    curves.push(run.curve);
                }
                Err(e) => eprintln!("{solver:?}/{method:?} failed: {e}"),
            }
        }
    }
    // [8]+RK45: the divergence footnote.
    let o = TrainFigOptions {
        arch: Arch::Sqnxt,
        solver: Solver::Rk45,
        method: GradMethod::Node,
        num_classes: 10,
        train_size: 160,
        test_size: 32,
        steps: 8,
        eval_every: 5,
        lr: 0.02,
        seed: 0,
        verbose: false,
        workers: 1,
        ..TrainFigOptions::default()
    };
    if let Ok(run) = train_figure(&reg, &o) {
        summary.push((run.series.clone(), run.curve.final_acc(), run.diverged, run.sec_per_step));
        curves.push(run.curve);
    }

    println!("{}", format_table(&curves));
    println!("{:<28} {:>10} {:>10} {:>12}", "series", "final_acc", "diverged", "sec/step");
    for (name, acc, div, sps) in &summary {
        println!("{:<28} {:>9.2}% {:>10} {:>12.3}", name, acc * 100.0, div, sps);
    }
    let anode_acc = summary.iter().find(|s| s.0.starts_with("anode-")).map(|s| s.1).unwrap_or(0.0);
    let node_acc = summary.iter().find(|s| s.0.starts_with("node-sqnxt-euler")).map(|s| s.1).unwrap_or(0.0);
    println!("\nshape check: anode acc {:.1}% vs node acc {:.1}% (paper: ANODE converges higher)", anode_acc * 100.0, node_acc * 100.0);
}
