//! `bench_check` — CI's bench-baseline regression gate.
//!
//! Diffs the `BENCH_*.json` artifacts a bench run just produced against
//! the committed baselines in `rust/bench-baselines/`, with two tiers:
//!
//! * **Hard failures** (exit 1, `::error::` annotations): a baseline
//!   artifact with no counterpart in the current run, or any boolean
//!   invariant that was `true` at the baseline and is now `false` or
//!   missing. Bit-identity flags (`bit_identical`, `traffic_equal`) are
//!   correctness claims — a run where one goes false is a regression no
//!   timing number can excuse.
//! * **Soft drift** (`::warning::` annotations, exit 0): latency-flavored
//!   numbers (fields ending `_ms`, `_us`, or `_secs`) more than 30% above
//!   the baseline. Shared CI runners jitter far too much for timing to be
//!   a hard gate; the warning keeps drift visible on the run summary
//!   without flaking the build.
//!
//! Integer counters, throughput rates, and mode strings are informational
//! and never gate — they vary run to run (quick vs full, stub vs sim).
//!
//! Usage, from anywhere in the repo after a bench run:
//!
//! ```text
//! cargo run --bin bench_check            # gate the artifacts in CWD / rust/
//! cargo run --bin bench_check -- --bless # rewrite the baselines from this run
//! ```
//!
//! `--bless` is the intended workflow after a deliberate perf-affecting
//! change: run `rust/scripts/check.sh --bench`, eyeball the diff of
//! `rust/bench-baselines/`, and commit it alongside the change. See
//! rust/DESIGN.md §6g.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anode::util::json::Json;

/// Relative latency drift (vs baseline) that earns a warning.
const DRIFT_TOLERANCE: f64 = 0.30;

fn baselines_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/bench-baselines"))
}

/// Find the freshly-produced counterpart of a baseline artifact: benches
/// write to the invoking CWD, which is the repo root in CI and `rust/`
/// under a bare `cargo bench`.
fn find_artifact(name: &str) -> Option<PathBuf> {
    let candidates = [
        PathBuf::from(name),
        PathBuf::from("rust").join(name),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(name),
    ];
    candidates.into_iter().find(|p| p.is_file())
}

fn load(path: &Path) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Is this a latency-flavored field the soft drift check applies to?
fn is_latency_field(key: &str) -> bool {
    key.ends_with("_ms") || key.ends_with("_us") || key.ends_with("_secs")
}

struct Outcome {
    errors: usize,
    warnings: usize,
}

/// Diff one artifact against its baseline. Pushes `::error::` /
/// `::warning::` annotations (the format GitHub Actions renders onto the
/// run summary) alongside the human lines.
fn check_one(name: &str, baseline: &Json, current: &Json, out: &mut Outcome) {
    let fields = match baseline {
        Json::Obj(map) => map,
        _ => {
            println!("::error::{name}: baseline is not a JSON object");
            out.errors += 1;
            return;
        }
    };
    for (key, base_val) in fields {
        match base_val {
            Json::Bool(true) => match current.get(key).and_then(Json::as_bool) {
                Some(true) => {}
                Some(false) => {
                    println!(
                        "::error::{name}: invariant \"{key}\" regressed true -> false \
                         (a correctness flag the baseline guarantees)"
                    );
                    out.errors += 1;
                }
                None => {
                    println!("::error::{name}: invariant \"{key}\" is missing from this run");
                    out.errors += 1;
                }
            },
            Json::Num(base) if is_latency_field(key) => {
                let Some(cur) = current.get(key).and_then(Json::as_f64) else {
                    continue;
                };
                if *base > 0.0 && cur > base * (1.0 + DRIFT_TOLERANCE) {
                    println!(
                        "::warning::{name}: \"{key}\" drifted {cur:.4} vs baseline {base:.4} \
                         (+{:.0}%, tolerance {:.0}%)",
                        100.0 * (cur / base - 1.0),
                        100.0 * DRIFT_TOLERANCE
                    );
                    out.warnings += 1;
                }
            }
            _ => {}
        }
    }
    let bools = fields.values().filter(|v| matches!(v, Json::Bool(true))).count();
    println!(
        "checked {name}: {bools} invariant(s), drift tolerance {:.0}%",
        100.0 * DRIFT_TOLERANCE
    );
}

fn bless(dir: &Path) -> ExitCode {
    let mut blessed = 0usize;
    let Ok(entries) = std::fs::read_dir(dir) else {
        eprintln!("::error::no baselines dir at {}", dir.display());
        return ExitCode::FAILURE;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.starts_with("BENCH_") || !name.ends_with(".json") {
            continue;
        }
        match find_artifact(&name) {
            Some(artifact) => {
                if let Err(e) = std::fs::copy(&artifact, entry.path()) {
                    eprintln!("::error::bless {name}: {e}");
                    return ExitCode::FAILURE;
                }
                println!("blessed {} <- {}", entry.path().display(), artifact.display());
                blessed += 1;
            }
            None => println!("skipped {name}: no artifact from this run (bench not executed?)"),
        }
    }
    println!("blessed {blessed} baseline(s); review the diff before committing");
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "bench_check — diff BENCH_*.json artifacts against rust/bench-baselines/\n\n\
             USAGE: bench_check [--bless]\n\n\
             Hard-fails (exit 1) on a missing artifact or a true->false boolean\n\
             invariant; warns on >{}% latency drift. --bless rewrites the\n\
             baselines from the current run's artifacts.",
            (100.0 * DRIFT_TOLERANCE) as u32
        );
        return ExitCode::SUCCESS;
    }
    let dir = baselines_dir();
    if args.iter().any(|a| a == "--bless") {
        return bless(&dir);
    }

    let mut names: Vec<String> = match std::fs::read_dir(&dir) {
        Ok(entries) => entries
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect(),
        Err(e) => {
            println!("::error::no baselines dir at {}: {e}", dir.display());
            return ExitCode::FAILURE;
        }
    };
    names.sort();
    if names.is_empty() {
        println!("::error::{} holds no BENCH_*.json baselines", dir.display());
        return ExitCode::FAILURE;
    }

    let mut out = Outcome { errors: 0, warnings: 0 };
    for name in &names {
        let baseline = match load(&dir.join(name)) {
            Ok(j) => j,
            Err(e) => {
                println!("::error::unreadable baseline {e}");
                out.errors += 1;
                continue;
            }
        };
        let Some(artifact) = find_artifact(name) else {
            println!(
                "::error::{name}: baseline exists but this run produced no artifact — \
                 did the bench crash or get dropped from the suite?"
            );
            out.errors += 1;
            continue;
        };
        match load(&artifact) {
            Ok(current) => check_one(name, &baseline, &current, &mut out),
            Err(e) => {
                println!("::error::unreadable artifact {e}");
                out.errors += 1;
            }
        }
    }

    println!(
        "\nbench_check: {} baseline(s), {} error(s), {} warning(s)",
        names.len(),
        out.errors,
        out.warnings
    );
    if out.errors > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
