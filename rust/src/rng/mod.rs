//! Deterministic pseudo-random number generation (SplitMix64 core).
//!
//! Everything stochastic in the coordinator — synthetic data, augmentation,
//! shuffling, weight init for native tests — flows through this module so
//! every run is bit-reproducible from a seed.

/// SplitMix64 PRNG: tiny state, excellent distribution, splittable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-worker / per-epoch streams).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9);
        Rng::new(s)
    }

    /// Next raw 64-bit value (SplitMix64 step).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 high-quality mantissa bits.
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (bias < 2^-40 for small n).
        ((self.next_u64() >> 11) as u128 * n as u128 >> 53) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * (u1 as f64).ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2 as f64;
        (r * th.cos()) as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(5);
        let mut s1 = r.split(1);
        let mut s2 = r.split(2);
        let a: Vec<u64> = (0..10).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
