//! Optimization passes over [`ModuleIr`], run in a fixed order:
//! constant folding → dead-code elimination → fusion.
//!
//! * **Constant folding** ([`const_fold`]) evaluates every op whose
//!   operands are manifest-known at compile time: the module-name digest
//!   and any length-mix over an already-constant digest. For every real
//!   module this folds the entire pre-data prefix — the seed the emitted
//!   plan starts from, so the hot path never re-hashes the module name.
//! * **DCE** ([`dce`]) keeps only ops reachable from the effect roots
//!   (output fills) by walking `src` edges backwards; orphaned constants
//!   left behind by folding, and any unreferenced chain in a
//!   hand-constructed or corrupted IR, are dropped.
//! * **Fusion** ([`fuse`]) merges each single-use chain of
//!   `MixLen`/`AbsorbData` ops into one [`OpKind::FusedAbsorb`] kernel
//!   and all fills off one digest into one [`OpKind::FusedFill`] — the
//!   value-model analog of fusing a time step's conv/norm/act chain into
//!   a single dispatched op. Fused ops carry `primitives`, so
//!   [`ModuleIr::primitive_count`] is **invariant under fusion** (the
//!   op-count accounting the tests pin down).

use std::collections::{HashMap, HashSet};

use crate::runtime::sim;

use super::ir::{AbsorbStep, ModuleIr, Op, OpKind, TrainArg, TrainIr, TrainOp, ValueId};

/// What one full pass pipeline did to a module's IR.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PassStats {
    /// Ops replaced by constants.
    pub folded: usize,
    /// Ops removed as unreachable from any effect.
    pub removed: usize,
    /// Fused kernels created.
    pub fused: usize,
}

/// Fold manifest-known scalars: `NameDigest` and `MixLen` over constant
/// digests become [`OpKind::Const`]. Returns the number of ops folded.
pub fn const_fold(ir: &mut ModuleIr) -> usize {
    let mut consts: HashMap<ValueId, u64> = HashMap::new();
    let mut folded = 0usize;
    let name = ir.name.clone();
    for op in &mut ir.ops {
        let replacement = match &op.kind {
            OpKind::Const(c) => {
                consts.insert(op.id, *c);
                None
            }
            OpKind::NameDigest => Some(sim::name_digest(&name)),
            OpKind::MixLen { src, len } => consts.get(src).map(|&c| sim::mix(c, *len)),
            _ => None,
        };
        if let Some(c) = replacement {
            consts.insert(op.id, c);
            op.kind = OpKind::Const(c);
            folded += 1;
        }
    }
    folded
}

/// Remove every op not reachable (via `src` edges) from an effect root.
/// Returns the number of ops removed.
pub fn dce(ir: &mut ModuleIr) -> usize {
    let by_id: HashMap<ValueId, Option<ValueId>> =
        ir.ops.iter().map(|op| (op.id, op.kind.src())).collect();
    let mut live: HashSet<ValueId> = HashSet::new();
    let mut stack: Vec<ValueId> = ir
        .ops
        .iter()
        .filter(|op| op.kind.is_effect())
        .map(|op| op.id)
        .collect();
    while let Some(id) = stack.pop() {
        if live.insert(id) {
            if let Some(Some(src)) = by_id.get(&id) {
                stack.push(*src);
            }
        }
    }
    let before = ir.ops.len();
    ir.ops.retain(|op| live.contains(&op.id));
    before - ir.ops.len()
}

/// Fuse single-use `MixLen`/`AbsorbData` chains into [`OpKind::FusedAbsorb`]
/// kernels and same-digest fills into [`OpKind::FusedFill`]. Returns the
/// number of fused ops created. Preserves [`ModuleIr::primitive_count`].
pub fn fuse(ir: &mut ModuleIr) -> usize {
    // A value is fusable into its consumer only if nothing else reads it.
    let mut uses: HashMap<ValueId, usize> = HashMap::new();
    for op in &ir.ops {
        if let Some(src) = op.kind.src() {
            *uses.entry(src).or_default() += 1;
        }
    }

    let mut fused_created = 0usize;
    let mut out: Vec<Op> = Vec::with_capacity(ir.ops.len());
    let mut i = 0usize;
    while i < ir.ops.len() {
        let op = &ir.ops[i];
        let absorb_step = |kind: &OpKind| match kind {
            OpKind::MixLen { len, .. } => Some(AbsorbStep::Len(*len)),
            OpKind::AbsorbData { input, .. } => Some(AbsorbStep::Data(*input)),
            _ => None,
        };
        if let Some(first_step) = absorb_step(&op.kind) {
            // Grow the run while the next op consumes exactly this value.
            let chain_src = op.kind.src().expect("absorb ops always read a digest");
            let mut steps = vec![first_step];
            let mut last_id = op.id;
            let mut j = i + 1;
            while j < ir.ops.len() {
                let next = &ir.ops[j];
                let extends = next.kind.src() == Some(last_id)
                    && uses.get(&last_id).copied().unwrap_or(0) == 1;
                match (extends, absorb_step(&next.kind)) {
                    (true, Some(step)) => {
                        steps.push(step);
                        last_id = next.id;
                        j += 1;
                    }
                    _ => break,
                }
            }
            if steps.len() > 1 {
                let primitives = steps.len();
                out.push(Op {
                    id: last_id,
                    kind: OpKind::FusedAbsorb { src: chain_src, steps, primitives },
                });
                fused_created += 1;
                i = j;
                continue;
            }
        }
        if let OpKind::Fill { src, output } = op.kind {
            // Collect every later fill off the same digest into one kernel.
            let mut outputs = vec![output];
            let mut rest: Vec<Op> = Vec::new();
            for later in &ir.ops[i + 1..] {
                match later.kind {
                    OpKind::Fill { src: s2, output: o2 } if s2 == src => outputs.push(o2),
                    _ => rest.push(later.clone()),
                }
            }
            if outputs.len() > 1 {
                let primitives = outputs.len();
                out.push(Op { id: op.id, kind: OpKind::FusedFill { src, outputs, primitives } });
                fused_created += 1;
                out.extend(rest);
                ir.ops = out;
                return fused_created;
            }
        }
        out.push(op.clone());
        i += 1;
    }
    ir.ops = out;
    fused_created
}

/// Dead-fill elimination over a training-step IR ([`TrainIr`]): a call
/// output that no later op reads and that is not a program root (loss,
/// correct count, a parameter gradient) is never materialized — its
/// `outs` entry becomes `None`, so the lowering assigns it no arena slot
/// and the runtime skips its fill. The digest absorbs *inputs* only, so
/// a skipped fill cannot perturb any live output: bit-identity is
/// structural. The concrete win: `node`'s z0_rec reconstruction (a full
/// activation per block) costs neither arena bytes nor fill time in the
/// training plan. Returns the number of fills pruned.
pub fn prune_dead_outputs(ir: &mut TrainIr) -> usize {
    let mut read = vec![false; ir.value_count];
    for op in &ir.ops {
        match op {
            TrainOp::Call { args, .. } => {
                for a in args {
                    if let TrainArg::Val(v) = a {
                        read[*v] = true;
                    }
                }
            }
            TrainOp::Zero { .. } => {}
            TrainOp::Acc { src, dst } => {
                read[*src] = true;
                read[*dst] = true;
            }
            TrainOp::Interp { terms, .. } => {
                // Node states are read by every reconstruction — without
                // this the stepwise forward's interior fills look dead.
                for (src, _) in terms {
                    read[*src] = true;
                }
            }
        }
    }
    for &r in &ir.roots {
        read[r] = true;
    }
    let mut pruned = 0usize;
    for op in &mut ir.ops {
        if let TrainOp::Call { outs, .. } = op {
            for out in outs.iter_mut() {
                if matches!(out, Some(v) if !read[*v]) {
                    *out = None;
                    pruned += 1;
                }
            }
        }
    }
    pruned
}

/// The default pipeline: fold → DCE → fuse, with per-pass accounting.
pub fn run_default_passes(ir: &mut ModuleIr) -> PassStats {
    let folded = const_fold(ir);
    let removed = dce(ir);
    let fused = fuse(ir);
    PassStats { folded, removed, fused }
}

#[cfg(test)]
mod tests {
    use super::super::ir::build_module_ir;
    use super::*;
    use crate::runtime::{ModuleSpec, TensorSpec};

    fn spec(name: &str, ins: &[&[usize]], outs: &[&[usize]]) -> ModuleSpec {
        let t = |n: String, s: &[usize]| TensorSpec {
            name: n,
            shape: s.to_vec(),
            dtype: "f32".into(),
        };
        ModuleSpec {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            inputs: ins.iter().enumerate().map(|(i, s)| t(format!("i{i}"), s)).collect(),
            outputs: outs.iter().enumerate().map(|(o, s)| t(format!("o{o}"), s)).collect(),
        }
    }

    #[test]
    fn fold_reduces_prefix_to_seed_constant() {
        let mut ir = build_module_ir(&spec("m", &[&[4], &[2]], &[&[4]])).unwrap();
        let folded = const_fold(&mut ir);
        // NameDigest and the first MixLen fold; the second MixLen reads a
        // post-data digest and must not.
        assert_eq!(folded, 2);
        let expected = sim::mix(sim::name_digest("m"), 4);
        assert!(ir
            .ops
            .iter()
            .any(|op| matches!(op.kind, OpKind::Const(c) if c == expected)));
    }

    #[test]
    fn dce_drops_orphaned_constants_after_folding() {
        let mut ir = build_module_ir(&spec("m", &[&[4]], &[&[4]])).unwrap();
        let n = ir.op_count();
        const_fold(&mut ir);
        let removed = dce(&mut ir);
        // The folded NameDigest constant is no longer referenced.
        assert_eq!(removed, 1);
        assert_eq!(ir.op_count(), n - 1);
    }

    #[test]
    fn fusion_preserves_primitive_count() {
        let mut ir = build_module_ir(&spec("m", &[&[4], &[2], &[3]], &[&[4], &[1]])).unwrap();
        let primitives = ir.primitive_count();
        let stats = run_default_passes(&mut ir);
        assert!(stats.fused >= 2, "absorb chain + fill group: {stats:?}");
        assert_eq!(
            ir.primitive_count() + stats.removed,
            primitives,
            "fusion must account for every primitive it swallows"
        );
        assert!(ir.op_count() < primitives, "the program must actually shrink");
    }
}
