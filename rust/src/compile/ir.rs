//! Typed IR over one manifest module.
//!
//! [`build_module_ir`] decomposes the deterministic value model of
//! [`crate::runtime::sim`] into primitive digest operations over a
//! dataflow graph, validating the [`ModuleSpec`] **once** — dtype, output
//! materializability, element counts — so the emitted plan never checks a
//! shape again. The op set is tiny but it is a real IR: values have
//! identities, effects have roots, and the passes
//! ([`super::passes`]) do genuine dataflow work over it (constant
//! folding of manifest-known scalars, dead-code elimination by
//! reachability, fusion of op chains into single fused kernels with
//! primitive-count accounting).

use crate::runtime::ModuleSpec;

use super::{CompileError, Result};

/// Identity of the value an [`Op`] defines. Ids are unique within a
/// [`ModuleIr`] but need not stay dense — passes remove and merge ops.
pub type ValueId = usize;

/// One step of a fused absorb chain: either mix a manifest-known scalar
/// (an input's element count) or absorb a runtime input's data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbsorbStep {
    /// Mix a compile-time-known length into the digest.
    Len(u64),
    /// Mix every element of runtime input `i` into the digest.
    Data(usize),
}

/// Primitive (and fused) digest operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpKind {
    /// A digest constant (the product of constant folding).
    Const(u64),
    /// FNV digest of the module name — manifest-known, hence foldable.
    NameDigest,
    /// Mix a manifest-known scalar into `src` — foldable when `src` is
    /// already constant.
    MixLen { src: ValueId, len: u64 },
    /// Absorb runtime input `input`'s elements into `src`.
    AbsorbData { src: ValueId, input: usize },
    /// Fusion product: a whole absorb chain as one kernel. `primitives`
    /// records how many primitive ops it covers (op-count accounting).
    FusedAbsorb { src: ValueId, steps: Vec<AbsorbStep>, primitives: usize },
    /// Materialize output `output` from digest `src`.
    Fill { src: ValueId, output: usize },
    /// Fusion product: all output fills off one digest as one kernel.
    FusedFill { src: ValueId, outputs: Vec<usize>, primitives: usize },
}

impl OpKind {
    /// The value this op reads, if any.
    pub fn src(&self) -> Option<ValueId> {
        match self {
            OpKind::Const(_) | OpKind::NameDigest => None,
            OpKind::MixLen { src, .. }
            | OpKind::AbsorbData { src, .. }
            | OpKind::FusedAbsorb { src, .. }
            | OpKind::Fill { src, .. }
            | OpKind::FusedFill { src, .. } => Some(*src),
        }
    }

    /// How many primitive operations this op represents (fused ops carry
    /// their coverage; primitives count as one).
    pub fn primitive_count(&self) -> usize {
        match self {
            OpKind::FusedAbsorb { primitives, .. } | OpKind::FusedFill { primitives, .. } => {
                *primitives
            }
            _ => 1,
        }
    }

    /// Is this op an observable effect (an output materialization)?
    pub fn is_effect(&self) -> bool {
        matches!(self, OpKind::Fill { .. } | OpKind::FusedFill { .. })
    }
}

/// One IR operation: the value it defines plus what it computes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Op {
    pub id: ValueId,
    pub kind: OpKind,
}

/// The IR of one module: validated shapes plus the op list in program
/// order. Effects ([`OpKind::is_effect`]) are the DCE roots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModuleIr {
    pub name: String,
    /// Validated input shapes (element counts are the foldable scalars).
    pub input_shapes: Vec<Vec<usize>>,
    /// Validated output shapes.
    pub output_shapes: Vec<Vec<usize>>,
    pub ops: Vec<Op>,
}

impl ModuleIr {
    /// Total primitive operations represented (invariant under fusion:
    /// the fusion pass must preserve this number — asserted by tests).
    pub fn primitive_count(&self) -> usize {
        self.ops.iter().map(|op| op.kind.primitive_count()).sum()
    }

    /// Ops currently in the program (shrinks under DCE and fusion).
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    /// A fresh value id (max existing + 1) for passes that insert ops.
    pub fn fresh_id(&self) -> ValueId {
        self.ops.iter().map(|op| op.id + 1).max().unwrap_or(0)
    }
}

/// Element count of a shape under the value model (empty shape = scalar
/// = 1 element, matching `sim_outputs`).
pub(crate) fn element_count(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(1)
}

/// Shape-check one lowered call site against a module spec: arity first,
/// then each *known* supplied shape against the declaration. `None`
/// entries skip the shape check (program inputs whose shape only the
/// session knows — the image batch on the first chain step, the label
/// batch). Shared by [`super::plan::InferProgram`] and
/// [`super::plan::TrainProgram`] so both fused lowerings reject a
/// mismatched manifest with the same typed errors.
pub(crate) fn check_module_args(spec: &ModuleSpec, supplied: &[Option<&[usize]>]) -> Result<()> {
    if spec.inputs.len() != supplied.len() {
        return Err(CompileError::ArityMismatch {
            module: spec.name.clone(),
            expected: spec.inputs.len(),
            found: supplied.len(),
        });
    }
    for (decl, sup) in spec.inputs.iter().zip(supplied) {
        if let Some(shape) = sup {
            if decl.shape.as_slice() != *shape {
                return Err(CompileError::ShapeMismatch {
                    module: spec.name.clone(),
                    input: decl.name.clone(),
                    expected: decl.shape.clone(),
                    found: shape.to_vec(),
                });
            }
        }
    }
    Ok(())
}

/// Operand of a training-step IR op: where the data comes from before
/// the arena layout exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainArg {
    /// The image batch (a program input, never in the arena).
    Image,
    /// The label batch (program input of the loss/grad head).
    Labels,
    /// A parameter tensor (index into the canonical parameter vector).
    Param(usize),
    /// A virtual value defined by an earlier op.
    Val(usize),
}

/// One op of the training-step IR: module calls over virtual values plus
/// the two scalar-free accumulator primitives the adjoint needs
/// (`Zero`/`Acc` replicate the interpreter's `Tensor::zeros` +
/// `axpy(1.0, g)` per-step parameter-gradient fold, in the same order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrainOp {
    /// Execute plan `plan` over `args`; `outs[i]` is the value holding
    /// output `i`, or `None` when the fill was pruned as dead
    /// ([`super::passes::prune_dead_outputs`]) — the digest is shared,
    /// so skipping a dead fill cannot perturb live outputs.
    Call { plan: usize, args: Vec<TrainArg>, outs: Vec<Option<usize>> },
    /// Define `out` as all zeros (a parameter-gradient accumulator).
    Zero { out: usize },
    /// `dst += src`, elementwise (`axpy` with alpha = 1.0).
    Acc { src: usize, dst: usize },
    /// Define `out` as the barycentric mix `Σ_j c_j · vals[j]` over the
    /// interpolated adjoint's trajectory nodes: zero `out`, then
    /// `out += c_j · src_j` in term order — replicating the
    /// interpreter's `Tensor::zeros` + `axpy(c_j, node_j)` fold exactly.
    /// Coefficients are const-folded at build time and carried as f32
    /// bit patterns so the op stays `Eq`/hashable.
    Interp { out: usize, terms: Vec<(usize, u32)> },
}

/// The training step as a value graph before arena layout: ops in
/// program order over `value_count` virtual values, with `roots` (loss,
/// correct count, parameter gradients) pinned live to the epilogue.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrainIr {
    pub ops: Vec<TrainOp>,
    pub value_count: usize,
    pub roots: Vec<usize>,
}

/// Build the typed IR for one module, performing all validation the hot
/// path will skip: dtype support, output materializability, non-empty
/// output set. Inputs with zero elements are legal (they absorb only
/// their length), zero-sized *outputs* are not — they cannot be
/// materialized as tensors.
pub fn build_module_ir(spec: &ModuleSpec) -> Result<ModuleIr> {
    if spec.outputs.is_empty() {
        return Err(CompileError::NoOutputs { module: spec.name.clone() });
    }
    for t in spec.inputs.iter().chain(spec.outputs.iter()) {
        if t.dtype != "f32" {
            return Err(CompileError::UnsupportedDtype {
                module: spec.name.clone(),
                tensor: t.name.clone(),
                dtype: t.dtype.clone(),
            });
        }
    }
    for t in &spec.outputs {
        if !t.shape.is_empty() && t.shape.iter().any(|&d| d == 0) {
            return Err(CompileError::ZeroDimOutput {
                module: spec.name.clone(),
                tensor: t.name.clone(),
                shape: t.shape.clone(),
            });
        }
    }

    let mut ops = Vec::with_capacity(1 + 2 * spec.inputs.len() + spec.outputs.len());
    let mut next = 0usize;
    let mut push = |ops: &mut Vec<Op>, kind: OpKind| -> ValueId {
        let id = next;
        next += 1;
        ops.push(Op { id, kind });
        id
    };

    let mut digest = push(&mut ops, OpKind::NameDigest);
    for (i, t) in spec.inputs.iter().enumerate() {
        // `sim_outputs` mixes the *actual* data length, which equals the
        // manifest element count for every validated call — the scalar is
        // therefore manifest-known and becomes a fold/fuse candidate.
        let len = t.shape.iter().product::<usize>() as u64;
        digest = push(&mut ops, OpKind::MixLen { src: digest, len });
        digest = push(&mut ops, OpKind::AbsorbData { src: digest, input: i });
    }
    for o in 0..spec.outputs.len() {
        push(&mut ops, OpKind::Fill { src: digest, output: o });
    }

    Ok(ModuleIr {
        name: spec.name.clone(),
        input_shapes: spec.inputs.iter().map(|t| t.shape.clone()).collect(),
        output_shapes: spec.outputs.iter().map(|t| t.shape.clone()).collect(),
        ops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorSpec;

    fn tensor(name: &str, shape: &[usize], dtype: &str) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: dtype.into() }
    }

    fn spec(name: &str, ins: &[&[usize]], outs: &[&[usize]]) -> ModuleSpec {
        ModuleSpec {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            inputs: ins
                .iter()
                .enumerate()
                .map(|(i, s)| tensor(&format!("i{i}"), s, "f32"))
                .collect(),
            outputs: outs
                .iter()
                .enumerate()
                .map(|(o, s)| tensor(&format!("o{o}"), s, "f32"))
                .collect(),
        }
    }

    #[test]
    fn ir_shape_matches_value_model() {
        let ir = build_module_ir(&spec("m", &[&[2, 3], &[3]], &[&[2, 3], &[1]])).unwrap();
        // NameDigest + 2×(MixLen + AbsorbData) + 2×Fill.
        assert_eq!(ir.op_count(), 7);
        assert_eq!(ir.primitive_count(), 7);
        assert_eq!(ir.ops[1].kind, OpKind::MixLen { src: 0, len: 6 });
        assert!(ir.ops[5].kind.is_effect() && ir.ops[6].kind.is_effect());
    }

    #[test]
    fn ir_rejects_bad_manifests_with_typed_errors() {
        let e = build_module_ir(&spec("empty", &[&[2]], &[])).unwrap_err();
        assert_eq!(e, CompileError::NoOutputs { module: "empty".into() });

        let mut bad_dtype = spec("dt", &[&[2]], &[&[2]]);
        bad_dtype.inputs[0].dtype = "i32".into();
        let e = build_module_ir(&bad_dtype).unwrap_err();
        assert!(matches!(e, CompileError::UnsupportedDtype { ref tensor, .. } if tensor == "i0"));

        let e = build_module_ir(&spec("z", &[], &[&[2, 0]])).unwrap_err();
        assert!(matches!(e, CompileError::ZeroDimOutput { ref shape, .. } if shape == &[2, 0]));
    }

    #[test]
    fn zero_element_inputs_are_legal() {
        let ir = build_module_ir(&spec("zin", &[&[0]], &[&[1]])).unwrap();
        assert_eq!(ir.ops[1].kind, OpKind::MixLen { src: 0, len: 0 });
        assert_eq!(element_count(&[]), 1, "scalar output occupies one element");
    }
}
