//! # `anode::compile` — manifest → typed IR → fused native kernels
//!
//! The third execution backend ([`crate::runtime::Backend::Compiled`]):
//! instead of interpreting the manifest per call (the sim path) or
//! round-tripping through PJRT, the whole manifest graph is lowered
//! **ahead of time** through a typed IR into compact kernel plans, and
//! the hot path dispatches those plans with zero per-call shape checks
//! and zero steady-state allocations beyond the returned tensors.
//!
//! The pipeline (rust/DESIGN.md §6f):
//!
//! ```text
//! ModuleSpec ──ir::build_module_ir──▶ ModuleIr      (shape inference +
//!                                        │            validation, once)
//!             passes: const-fold ▶ DCE ▶ fusion      (optimization)
//!                                        │
//!             plan::lower_module ────▶ ModulePlan    (flat fused-kernel
//!                                                     program, folded seed)
//! ```
//!
//! and, one level up, [`plan::InferProgram`] fuses the *model-level*
//! inference chain (stem → per-time-step block applications →
//! transitions) into a single flat instruction list whose intermediate
//! activations live in a preallocated buffer arena laid out by liveness
//! analysis — the ANODE-specific win: the discretize-then-optimize
//! structure makes the whole forward pass a statically known sequence,
//! so it compiles to one program instead of `O(stages × blocks)`
//! dispatches with per-step tensor allocations.
//!
//! **Value model.** The offline artifact set carries no executable code,
//! so what the kernels compute is the deterministic value model of
//! [`crate::runtime::sim`] — and they share its primitives
//! (`mix`/`centered`), which makes *compiled ≡ sim, bitwise* a
//! structural property. The IR/plan seam is execution-agnostic: a real
//! native or JIT (e.g. Cranelift) kernel set slots in behind
//! [`plan::ModulePlan`] without touching the passes (ROADMAP follow-up).
//!
//! Everything here is std-only pure Rust: no new dependencies.

pub mod ir;
pub mod passes;
pub mod plan;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::ModuleSpec;

pub use ir::{
    build_module_ir, AbsorbStep, ModuleIr, Op, OpKind, TrainArg, TrainIr, TrainOp, ValueId,
};
pub use passes::{prune_dead_outputs, run_default_passes, PassStats};
pub use plan::{
    compile_module, InferCall, InferProgram, ModulePlan, TrainBackward, TrainBlock, TrainChain,
    TrainProgram, TrainStage, TransCall,
};

/// Compile-time result type.
pub type Result<T> = std::result::Result<T, CompileError>;

/// Typed compile-time errors: everything the pipeline rejects is named
/// with the module/tensor that caused it, so a corrupt manifest fails at
/// **compile time** with a diagnosable error — never a panic, never a
/// mid-training shape surprise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// A module declares no outputs — the value model cannot seed any.
    NoOutputs { module: String },
    /// Only f32 tensors are lowerable (the manifest's only dtype today).
    UnsupportedDtype { module: String, tensor: String, dtype: String },
    /// An output tensor with a zero dimension cannot be materialized.
    ZeroDimOutput { module: String, tensor: String, shape: Vec<usize> },
    /// Cross-module shape inference failed: a consumer's declared input
    /// shape disagrees with what the producer (or parameter layout)
    /// actually supplies.
    ShapeMismatch {
        module: String,
        input: String,
        expected: Vec<usize>,
        found: Vec<usize>,
    },
    /// A chain step references a module with the wrong input arity.
    ArityMismatch { module: String, expected: usize, found: usize },
    /// A chain step references a module the manifest does not define.
    MissingModule { module: String },
    /// The IR has a shape the lowering cannot express (e.g. a digest
    /// graph that is not a single chain) — surfaced, not panicked on.
    Unsupported { module: String, reason: String },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::NoOutputs { module } => {
                write!(f, "{module}: declares no outputs")
            }
            CompileError::UnsupportedDtype { module, tensor, dtype } => {
                write!(f, "{module}: tensor {tensor} has unsupported dtype {dtype:?}")
            }
            CompileError::ZeroDimOutput { module, tensor, shape } => {
                write!(f, "{module}: output {tensor} has zero-sized shape {shape:?}")
            }
            CompileError::ShapeMismatch { module, input, expected, found } => {
                write!(
                    f,
                    "{module}: input {input} expects shape {expected:?} but the \
                     producer supplies {found:?}"
                )
            }
            CompileError::ArityMismatch { module, expected, found } => {
                write!(f, "{module}: expects {expected} inputs, chain supplies {found}")
            }
            CompileError::MissingModule { module } => {
                write!(f, "{module}: not in the manifest")
            }
            CompileError::Unsupported { module, reason } => {
                write!(f, "{module}: unsupported IR shape: {reason}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

impl From<CompileError> for crate::runtime::RuntimeError {
    fn from(e: CompileError) -> Self {
        crate::runtime::RuntimeError::Io(format!("compile: {e}"))
    }
}

/// Live counters of one compiled backend instance (per registry),
/// shared by `Arc` with every [`InferProgram`] built over it, so plan
/// and arena activity aggregate in one place and export through the
/// `net::metrics` endpoint.
#[derive(Debug, Default)]
pub struct CompileStats {
    /// Module plans compiled and cached at open time.
    pub plans_cached: AtomicU64,
    /// Fused kernels across all cached plans (each covers a chain of
    /// primitive IR ops — see [`PassStats`]).
    pub fused_ops: AtomicU64,
    /// IR ops constant-folded away at compile time.
    pub folded_consts: AtomicU64,
    /// Bytes of liveness-planned arena backing fused programs (infer
    /// and train).
    pub arena_bytes: AtomicU64,
    /// Arena buffers allocated (warmup only, in steady state).
    pub arena_allocs: AtomicU64,
    /// Arena buffers reused from the pool (the steady-state path).
    pub arena_reuses: AtomicU64,
    /// Bytes of train-arena slots holding trajectory state (block
    /// boundaries plus checkpointed/taped step states) — the planned
    /// O(L)+O(N_t) budget of the paper, per built [`plan::TrainProgram`].
    pub trajectory_bytes: AtomicU64,
    /// Recompute segments (checkpoint restores replayed as sub-programs)
    /// unrolled into train programs at build time.
    pub train_recompute_segments: AtomicU64,
    /// Interior trajectory node states pinned in long-lived arena slots
    /// by interpolated-adjoint blocks, per built [`plan::TrainProgram`].
    pub train_interp_nodes: AtomicU64,
    /// Training-arena buffers allocated (warmup only, in steady state).
    pub train_arena_allocs: AtomicU64,
    /// Training-arena buffers reused from the pool (every steady-state
    /// training step).
    pub train_arena_reuses: AtomicU64,
}

impl CompileStats {
    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> CompileStatsSnapshot {
        CompileStatsSnapshot {
            plans_cached: self.plans_cached.load(Ordering::Relaxed),
            fused_ops: self.fused_ops.load(Ordering::Relaxed),
            folded_consts: self.folded_consts.load(Ordering::Relaxed),
            arena_bytes: self.arena_bytes.load(Ordering::Relaxed),
            arena_allocs: self.arena_allocs.load(Ordering::Relaxed),
            arena_reuses: self.arena_reuses.load(Ordering::Relaxed),
            trajectory_bytes: self.trajectory_bytes.load(Ordering::Relaxed),
            train_recompute_segments: self.train_recompute_segments.load(Ordering::Relaxed),
            train_interp_nodes: self.train_interp_nodes.load(Ordering::Relaxed),
            train_arena_allocs: self.train_arena_allocs.load(Ordering::Relaxed),
            train_arena_reuses: self.train_arena_reuses.load(Ordering::Relaxed),
        }
    }
}

/// Plain-number snapshot of [`CompileStats`] — what crosses thread and
/// wire boundaries (`ServeHandle::compile_stats`, the metrics text).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompileStatsSnapshot {
    pub plans_cached: u64,
    pub fused_ops: u64,
    pub folded_consts: u64,
    pub arena_bytes: u64,
    pub arena_allocs: u64,
    pub arena_reuses: u64,
    pub trajectory_bytes: u64,
    pub train_recompute_segments: u64,
    pub train_interp_nodes: u64,
    pub train_arena_allocs: u64,
    pub train_arena_reuses: u64,
}

impl CompileStatsSnapshot {
    /// Fold another device's snapshot into this one (sharded serving
    /// sums per-device compiled backends for the metrics endpoint).
    pub fn absorb(&mut self, other: &CompileStatsSnapshot) {
        self.plans_cached += other.plans_cached;
        self.fused_ops += other.fused_ops;
        self.folded_consts += other.folded_consts;
        self.arena_bytes += other.arena_bytes;
        self.arena_allocs += other.arena_allocs;
        self.arena_reuses += other.arena_reuses;
        self.trajectory_bytes += other.trajectory_bytes;
        self.train_recompute_segments += other.train_recompute_segments;
        self.train_interp_nodes += other.train_interp_nodes;
        self.train_arena_allocs += other.train_arena_allocs;
        self.train_arena_reuses += other.train_arena_reuses;
    }
}

/// The compiled backend of one registry: every manifest module lowered
/// to a [`ModulePlan`] **eagerly at open time** (compile once, dispatch
/// forever — a corrupt manifest fails the open, not the thousandth
/// call), plus the shared [`CompileStats`].
pub struct CompiledSet {
    plans: HashMap<String, Arc<ModulePlan>>,
    stats: Arc<CompileStats>,
}

impl CompiledSet {
    /// Lower every module through the full pipeline (IR → passes →
    /// plan). Deterministic: modules compile in sorted-name order, so
    /// stats are reproducible across runs.
    pub fn compile<'a>(modules: impl IntoIterator<Item = &'a ModuleSpec>) -> Result<CompiledSet> {
        let mut specs: Vec<&ModuleSpec> = modules.into_iter().collect();
        specs.sort_by(|a, b| a.name.cmp(&b.name));
        let stats = Arc::new(CompileStats::default());
        let mut plans = HashMap::with_capacity(specs.len());
        for spec in specs {
            let plan = compile_module(spec)?;
            stats.plans_cached.fetch_add(1, Ordering::Relaxed);
            stats.fused_ops.fetch_add(plan.fused_ops() as u64, Ordering::Relaxed);
            stats.folded_consts.fetch_add(plan.folded_consts() as u64, Ordering::Relaxed);
            plans.insert(spec.name.clone(), Arc::new(plan));
        }
        Ok(CompiledSet { plans, stats })
    }

    /// The cached plan for a module, if the manifest defines it.
    pub fn plan(&self, name: &str) -> Option<&Arc<ModulePlan>> {
        self.plans.get(name)
    }

    /// Plans cached (== manifest module count after a successful open).
    pub fn plan_count(&self) -> usize {
        self.plans.len()
    }

    /// The shared live counters.
    pub fn stats(&self) -> &Arc<CompileStats> {
        &self.stats
    }
}

// One compiled set is shared across every worker thread of its registry.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<CompiledSet>();
    assert_send_sync::<CompileStats>();
};
