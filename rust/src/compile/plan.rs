//! Plan emission: typed IR → compact kernel programs, plus the fused
//! model-level inference program with its liveness-planned buffer arena.
//!
//! A [`ModulePlan`] is the unit the registry dispatches: a folded seed
//! digest, a flat list of absorb steps, and shape-specialized output
//! fills — no spec lookup, no name hashing, no shape checks on the hot
//! path. An [`InferProgram`] chains module plans into the whole
//! inference forward (stem → per-time-step blocks → transitions) with
//! every intermediate activation placed in one preallocated arena by
//! liveness analysis ([`assign_slots`]), so steady-state execution
//! performs **zero allocations** beyond the returned output tensor
//! (arena buffers recycle through a pool; the counters in
//! [`CompileStats`] prove it).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::runtime::sim::{centered, mix};
use crate::runtime::{ArtifactRegistry, ModuleSpec, RuntimeError};
use crate::tensor::Tensor;

use std::collections::HashMap;

use crate::checkpoint::{interp_coeffs, interp_nodes, Action, Schedule};

use super::ir::{
    check_module_args, element_count, AbsorbStep, ModuleIr, OpKind, TrainArg, TrainIr, TrainOp,
    ValueId,
};
use super::passes::{prune_dead_outputs, run_default_passes};
use super::{CompileError, CompileStats, CompiledSet, Result};

/// One shape-specialized output fill of a [`ModulePlan`].
#[derive(Debug, Clone, PartialEq, Eq)]
struct OutputPlan {
    shape: Vec<usize>,
    len: usize,
}

/// The compiled form of one module: a flat fused-kernel program.
///
/// Executing a plan is exactly the value model of
/// [`crate::runtime::sim::sim_outputs`] — bit-identical by construction,
/// since both build on the same `mix`/`centered` primitives — minus all
/// per-call interpretation: the constant prefix (name digest + first
/// length mix) is folded into [`seed`](Self::seed) at compile time, and
/// shapes were validated when the plan was built.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModulePlan {
    name: String,
    seed: u64,
    steps: Vec<AbsorbStep>,
    outputs: Vec<OutputPlan>,
    input_count: usize,
    fused_ops: usize,
    folded_consts: usize,
    primitives: usize,
}

/// Compile one module through the full pipeline: IR construction (all
/// validation), default passes, lowering. Never panics — corrupt specs
/// surface as typed [`CompileError`]s.
pub fn compile_module(spec: &ModuleSpec) -> Result<ModulePlan> {
    let mut ir = super::ir::build_module_ir(spec)?;
    let stats = run_default_passes(&mut ir);
    let mut plan = lower_module(&ir)?;
    plan.fused_ops = stats.fused;
    plan.folded_consts = stats.folded;
    Ok(plan)
}

/// Lower a (passed or raw) [`ModuleIr`] to a [`ModulePlan`]. The digest
/// graph must be a single chain ending in the fills — anything else is a
/// typed [`CompileError::Unsupported`], so hand-mangled IR cannot panic
/// the lowering.
pub fn lower_module(ir: &ModuleIr) -> Result<ModulePlan> {
    let unsupported = |reason: &str| CompileError::Unsupported {
        module: ir.name.clone(),
        reason: reason.to_string(),
    };

    let mut consts: std::collections::HashMap<ValueId, u64> = std::collections::HashMap::new();
    let mut seed: Option<u64> = None;
    let mut chain: Option<ValueId> = None;
    let mut steps: Vec<AbsorbStep> = Vec::new();
    let mut fills: Vec<(usize, ValueId)> = Vec::new();

    // Adopt `src` as the start of the dynamic chain (or extend it).
    fn begin_or_extend(
        name: &str,
        src: ValueId,
        id: ValueId,
        chain: &mut Option<ValueId>,
        seed: &mut Option<u64>,
        consts: &std::collections::HashMap<ValueId, u64>,
    ) -> Result<()> {
        let unsupported = |reason: &str| CompileError::Unsupported {
            module: name.to_string(),
            reason: reason.to_string(),
        };
        match (*chain, consts.get(&src)) {
            (Some(tail), _) if tail == src => {}
            (None, Some(&c)) => *seed = Some(c),
            (Some(_), Some(_)) | (Some(_), None) => {
                return Err(unsupported("digest graph is not a single chain"));
            }
            (None, None) => return Err(unsupported("op reads an undefined digest")),
        }
        *chain = Some(id);
        Ok(())
    }

    for op in &ir.ops {
        match &op.kind {
            OpKind::Const(c) => {
                consts.insert(op.id, *c);
            }
            OpKind::NameDigest => {
                consts.insert(op.id, crate::runtime::sim::name_digest(&ir.name));
            }
            OpKind::MixLen { src, len } => {
                if let Some(&c) = consts.get(src) {
                    consts.insert(op.id, mix(c, *len));
                } else {
                    begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                    steps.push(AbsorbStep::Len(*len));
                }
            }
            OpKind::AbsorbData { src, input } => {
                if *input >= ir.input_shapes.len() {
                    return Err(unsupported("absorb references a nonexistent input"));
                }
                begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                steps.push(AbsorbStep::Data(*input));
            }
            OpKind::FusedAbsorb { src, steps: fused, .. } => {
                if fused.iter().any(
                    |s| matches!(s, AbsorbStep::Data(i) if *i >= ir.input_shapes.len()),
                ) {
                    return Err(unsupported("fused absorb references a nonexistent input"));
                }
                begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                steps.extend(fused.iter().copied());
            }
            OpKind::Fill { src, output } => fills.push((*output, *src)),
            OpKind::FusedFill { src, outputs, .. } => {
                fills.extend(outputs.iter().map(|&o| (o, *src)));
            }
        }
    }

    // Every fill must read the final digest — either the chain tail or,
    // for a module with no runtime inputs, a fully folded constant.
    let final_digest = chain;
    for &(_, src) in &fills {
        match final_digest {
            Some(tail) if src == tail => {}
            Some(_) => return Err(unsupported("fill reads a non-final digest")),
            None => {
                let Some(&c) = consts.get(&src) else {
                    return Err(unsupported("fill reads an undefined digest"));
                };
                match seed {
                    Some(s) if s != c => {
                        return Err(unsupported("fills disagree on the seed digest"));
                    }
                    _ => seed = Some(c),
                }
            }
        }
    }
    let Some(seed) = seed else {
        return Err(unsupported("program produces no digest"));
    };

    // Exactly one fill per declared output.
    let mut outputs: Vec<Option<OutputPlan>> = vec![None; ir.output_shapes.len()];
    for (o, _) in fills {
        let Some(slot) = outputs.get_mut(o) else {
            return Err(unsupported("fill targets a nonexistent output"));
        };
        if slot.is_some() {
            return Err(unsupported("output filled twice"));
        }
        let shape = ir.output_shapes[o].clone();
        let len = element_count(&shape);
        *slot = Some(OutputPlan { shape, len });
    }
    let outputs = outputs
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| unsupported("a declared output is never filled"))?;

    Ok(ModulePlan {
        name: ir.name.clone(),
        seed,
        steps,
        outputs,
        input_count: ir.input_shapes.len(),
        fused_ops: 0,
        folded_consts: 0,
        primitives: ir.primitive_count(),
    })
}

impl ModulePlan {
    /// Module this plan was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Inputs the plan expects (the only per-call check trusted callers
    /// keep is this arity).
    pub fn input_count(&self) -> usize {
        self.input_count
    }

    /// Outputs the plan materializes.
    pub fn output_count(&self) -> usize {
        self.outputs.len()
    }

    /// Fused kernels in this plan (see [`super::passes::fuse`]).
    pub fn fused_ops(&self) -> usize {
        self.fused_ops
    }

    /// Ops constant-folded while compiling this plan.
    pub fn folded_consts(&self) -> usize {
        self.folded_consts
    }

    /// Primitive ops this plan covers (invariant under fusion).
    pub fn primitive_count(&self) -> usize {
        self.primitives
    }

    /// The digest after absorbing `parts` (one slice per declared input).
    fn digest_parts(&self, parts: &[&[f32]]) -> u64 {
        let mut h = self.seed;
        for step in &self.steps {
            match *step {
                AbsorbStep::Len(l) => h = mix(h, l),
                AbsorbStep::Data(i) => {
                    for &v in parts[i] {
                        h = mix(h, u64::from(v.to_bits()));
                    }
                }
            }
        }
        h
    }

    /// Fill output `oi` (0-based) off the final digest into `out`.
    fn fill_into(&self, h: u64, oi: usize, out: &mut [f32]) {
        let base = mix(h, oi as u64 + 1);
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = centered(mix(base, j as u64));
        }
    }

    /// Execute the plan. **No shape checks** — compile time validated the
    /// manifest and the caller (the registry seam) owns input validation.
    /// Bit-identical to `sim_outputs` on the same module and inputs.
    pub fn execute(&self, inputs: &[&Tensor]) -> crate::runtime::Result<Vec<Tensor>> {
        let parts: Vec<&[f32]> = inputs.iter().map(|t| t.data()).collect();
        let h = self.digest_parts(&parts);
        self.outputs
            .iter()
            .enumerate()
            .map(|(oi, o)| {
                let mut data = vec![0.0f32; o.len];
                self.fill_into(h, oi, &mut data);
                Tensor::from_vec(o.shape.clone(), data)
                    .map_err(|e| RuntimeError::Shape(format!("compiled {}: {e}", self.name)))
            })
            .collect()
    }
}

/// Greedy liveness-interval slot assignment: `intervals[i] = (def,
/// last_use, len)` per value, in definition order. Returns `(slot of
/// each value, slot sizes)`. A slot is reusable strictly **after** its
/// holder's last use (`last_use + 1`), so a value written at instruction
/// `i` can never alias an operand still being read at `i`.
pub fn assign_slots(intervals: &[(usize, usize, usize)]) -> (Vec<usize>, Vec<usize>) {
    let mut slot_sizes: Vec<usize> = Vec::new();
    let mut free_at: Vec<usize> = Vec::new();
    let mut assignment = Vec::with_capacity(intervals.len());
    for &(def, last_use, len) in intervals {
        let slot = match (0..slot_sizes.len()).find(|&s| free_at[s] <= def) {
            Some(s) => s,
            None => {
                slot_sizes.push(0);
                free_at.push(0);
                slot_sizes.len() - 1
            }
        };
        slot_sizes[slot] = slot_sizes[slot].max(len);
        free_at[slot] = last_use + 1;
        assignment.push(slot);
    }
    (assignment, slot_sizes)
}

/// One step of the model-level inference chain: a module applied to the
/// running activation plus the named parameter tensors (indices into the
/// session's canonical parameter vector).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferCall {
    pub module: String,
    pub params: Vec<usize>,
}

/// Where an instruction operand lives at run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Loc {
    /// The program input (the image batch).
    Image,
    /// The label batch (train programs only).
    Labels,
    /// A parameter tensor (index into the params slice).
    Param(usize),
    /// An arena slot (f32 offset + length).
    Slot { off: usize, len: usize },
}

/// One fused-program instruction: execute `plan` over `args`, write the
/// single output into the arena at `out_off`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct InferInstr {
    plan: usize,
    args: Vec<Loc>,
    out_off: usize,
    out_len: usize,
}

/// The whole inference forward as one flat program: shape-specialized
/// fused kernels dispatched from an instruction list, intermediate
/// activations in a liveness-planned arena recycled through a pool.
///
/// Built once per [`crate::coordinator::ExecutionCore`] when the
/// registry runs the compiled backend; bit-identical to the sequential
/// per-module path (same plans, same order).
pub struct InferProgram {
    plans: Vec<Arc<ModulePlan>>,
    instrs: Vec<InferInstr>,
    arena_len: usize,
    slot_count: usize,
    out_off: usize,
    out_len: usize,
    out_shape: Vec<usize>,
    pool: Mutex<Vec<Vec<f32>>>,
    stats: Arc<CompileStats>,
}

impl InferProgram {
    /// Compile the chain against a compiled-backend registry, running
    /// **cross-module shape inference**: each step's declared input
    /// shapes must match what the previous step produces and what the
    /// parameter layout supplies — a mismatched manifest fails here,
    /// once, with a typed error naming the module and tensor.
    pub fn build(
        reg: &ArtifactRegistry,
        chain: &[InferCall],
        param_shapes: &[Vec<usize>],
    ) -> Result<InferProgram> {
        let Some(set) = reg.compiled_set() else {
            return Err(CompileError::Unsupported {
                module: "<infer>".into(),
                reason: "registry does not run the compiled backend".into(),
            });
        };
        if chain.is_empty() {
            return Err(CompileError::Unsupported {
                module: "<infer>".into(),
                reason: "empty inference chain".into(),
            });
        }

        let mut plans: Vec<Arc<ModulePlan>> = Vec::with_capacity(chain.len());
        let mut out_shapes: Vec<Vec<usize>> = Vec::with_capacity(chain.len());
        let mut activation: Option<Vec<usize>> = None;
        for call in chain {
            let spec = reg
                .module_spec(&call.module)
                .map_err(|_| CompileError::MissingModule { module: call.module.clone() })?;
            let mut supplied: Vec<Option<&[usize]>> = Vec::with_capacity(1 + call.params.len());
            supplied.push(activation.as_deref());
            for &p in &call.params {
                let shape = param_shapes.get(p).ok_or_else(|| CompileError::Unsupported {
                    module: call.module.clone(),
                    reason: format!("chain references parameter {p} outside the layout"),
                })?;
                supplied.push(Some(shape.as_slice()));
            }
            check_module_args(spec, &supplied)?;
            if spec.outputs.len() != 1 {
                return Err(CompileError::Unsupported {
                    module: call.module.clone(),
                    reason: format!(
                        "inference chain needs single-output modules, found {}",
                        spec.outputs.len()
                    ),
                });
            }
            let plan = set.plan(&call.module).ok_or_else(|| CompileError::MissingModule {
                module: call.module.clone(),
            })?;
            plans.push(plan.clone());
            activation = Some(spec.outputs[0].shape.clone());
            out_shapes.push(spec.outputs[0].shape.clone());
        }
        let out_shape = activation.expect("non-empty chain has a final activation");

        // Liveness: value k (instr k's output) is read by instr k+1; the
        // final value is read by the output copy "instruction" at n.
        let n = chain.len();
        let intervals: Vec<(usize, usize, usize)> = out_shapes
            .iter()
            .enumerate()
            .map(|(k, shape)| (k, (k + 1).min(n), element_count(shape)))
            .collect();
        let (slots, slot_sizes) = assign_slots(&intervals);
        let mut offsets = Vec::with_capacity(slot_sizes.len());
        let mut total = 0usize;
        for &size in &slot_sizes {
            offsets.push(total);
            total += size;
        }

        let loc_of = |k: usize| Loc::Slot {
            off: offsets[slots[k]],
            len: element_count(&out_shapes[k]),
        };
        let instrs: Vec<InferInstr> = chain
            .iter()
            .enumerate()
            .map(|(k, call)| {
                let mut args = Vec::with_capacity(1 + call.params.len());
                args.push(if k == 0 { Loc::Image } else { loc_of(k - 1) });
                args.extend(call.params.iter().map(|&p| Loc::Param(p)));
                let Loc::Slot { off, len } = loc_of(k) else { unreachable!() };
                InferInstr { plan: k, args, out_off: off, out_len: len }
            })
            .collect();

        let (out_off, out_len) = (instrs[n - 1].out_off, instrs[n - 1].out_len);
        let stats = set.stats().clone();
        stats
            .arena_bytes
            .fetch_add((total * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
        Ok(InferProgram {
            plans,
            instrs,
            arena_len: total,
            slot_count: slot_sizes.len(),
            out_off,
            out_len,
            out_shape,
            pool: Mutex::new(Vec::new()),
            stats,
        })
    }

    /// Kernels dispatched per run (== chain length; used for
    /// call-accounting parity with the sequential path).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// A program always has at least one instruction.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Arena slots after liveness reuse (a linear chain ping-pongs two).
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Bytes of one arena buffer.
    pub fn arena_bytes(&self) -> usize {
        self.arena_len * std::mem::size_of::<f32>()
    }

    /// Shape of the program's output (the head input activation).
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Run the program: one pooled arena, zero steady-state allocations
    /// (the pool hands buffers back after the first run per concurrent
    /// caller), output bit-identical to the sequential module-call chain.
    pub fn run(&self, x: &Tensor, params: &[Tensor]) -> crate::runtime::Result<Tensor> {
        let mut arena = match self.pool.lock().expect("arena pool poisoned").pop() {
            Some(buf) => {
                self.stats.arena_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.stats.arena_allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; self.arena_len]
            }
        };

        for instr in &self.instrs {
            let plan = &self.plans[instr.plan];
            let mut h = plan.seed;
            for step in &plan.steps {
                match *step {
                    AbsorbStep::Len(l) => h = mix(h, l),
                    AbsorbStep::Data(i) => {
                        let part: &[f32] = match instr.args[i] {
                            Loc::Image => x.data(),
                            // Inference chains never reference labels.
                            Loc::Labels => unreachable!("labels in an inference program"),
                            Loc::Param(p) => params[p].data(),
                            Loc::Slot { off, len } => &arena[off..off + len],
                        };
                        for &v in part {
                            h = mix(h, u64::from(v.to_bits()));
                        }
                    }
                }
            }
            plan.fill_into(h, 0, &mut arena[instr.out_off..instr.out_off + instr.out_len]);
        }

        let out = Tensor::from_vec(
            self.out_shape.clone(),
            arena[self.out_off..self.out_off + self.out_len].to_vec(),
        )
        .map_err(|e| RuntimeError::Shape(format!("compiled infer output: {e}")));
        self.pool.lock().expect("arena pool poisoned").push(arena);
        out
    }
}

/// Backward lowering of one ODE block inside a [`TrainChain`].
#[derive(Debug, Clone)]
pub enum TrainBackward {
    /// One fused artifact call `(z_in, θ..., gz) -> (gz, gθ...)` — the
    /// `anode` DTO VJP and the `otd` adjoint.
    Fused { module: String },
    /// One call `(z_out, θ..., gz) -> (gz, gθ..., z0_rec)` starting from
    /// the block *output* (the `node` reverse solve); the reconstruction
    /// output is dead in training and pruned from the plan.
    FromOutput { module: String },
    /// `step_fwd`/`step_vjp` artifacts unrolled through an in-block
    /// checkpoint [`Schedule`] (`anode-revolve<m>`, `anode-equispaced<m>`,
    /// `symplectic` via its store-everything schedule):
    /// checkpoints become value aliases with extended liveness, recompute
    /// segments replay as straight-line sub-programs into the same arena.
    Checkpointed { step_fwd: String, step_vjp: String, schedule: Schedule },
    /// Stepwise `step_fwd` forward capturing a sparse `nodes`-point
    /// trajectory grid, then a `step_vjp` backward whose step inputs are
    /// barycentric mixes of the pinned node values (`interp-adjoint<p>`):
    /// node states become long-lived arena slots, interpolation
    /// coefficients are const-folded into [`TrainInstr`] terms at build
    /// time, and nothing is ever recomputed.
    Interpolated { step_fwd: String, step_vjp: String, nodes: usize },
}

/// One ODE block of the training chain: forward module, its parameter
/// indices, and how its backward lowers.
#[derive(Debug, Clone)]
pub struct TrainBlock {
    pub fwd: String,
    pub params: Vec<usize>,
    pub backward: TrainBackward,
}

/// A transition between stages: forward + VJP modules and the (w, b)
/// parameter indices.
#[derive(Debug, Clone)]
pub struct TransCall {
    pub fwd: String,
    pub vjp: String,
    pub params: (usize, usize),
}

/// One stage: its blocks plus the transition that follows it (absent on
/// the last stage).
#[derive(Debug, Clone)]
pub struct TrainStage {
    pub blocks: Vec<TrainBlock>,
    pub trans: Option<TransCall>,
}

/// The whole training step as data: stem, stages, loss/grad head. The
/// [`crate::coordinator::ExecutionCore`] assembles this from its resolved
/// module handles and parameter index; [`TrainProgram::build`] lowers it.
#[derive(Debug, Clone)]
pub struct TrainChain {
    /// Discrete time steps per ODE block (the fused backward's ledger
    /// cost is `nt` step states, matching the interpreter's accounting).
    pub nt: usize,
    pub stem_fwd: String,
    pub stem_vjp: String,
    pub stem_params: (usize, usize),
    pub stages: Vec<TrainStage>,
    pub head_loss_grad: String,
    pub head_params: (usize, usize),
}

/// One flat-program instruction of a [`TrainProgram`].
#[derive(Debug, Clone, PartialEq, Eq)]
enum TrainInstr {
    /// Run a module plan; `outs[i]` is output `i`'s arena placement, or
    /// `None` for a pruned dead fill.
    Call { plan: usize, args: Vec<Loc>, outs: Vec<Option<(usize, usize)>> },
    /// Zero an arena range (a parameter-gradient accumulator).
    Zero { off: usize, len: usize },
    /// `arena[dst..] += arena[src..]` elementwise (`axpy` with alpha =
    /// 1.0 — the interpreter's per-step gradient fold, same order).
    Acc { src: usize, dst: usize, len: usize },
    /// Barycentric node mix: zero `arena[off..off+len]`, then for each
    /// `(src, bits)` term in order add `f32::from_bits(bits) *
    /// arena[src..]` — operation-for-operation the interpreter's
    /// `Tensor::zeros` + `axpy(c_j, node_j)` reconstruction.
    Interp { off: usize, len: usize, terms: Vec<(usize, u32)> },
}

/// Where one parameter gradient lives in the arena at the end of a run.
#[derive(Debug, Clone, PartialEq, Eq)]
struct GradOut {
    param: usize,
    off: usize,
    len: usize,
    shape: Vec<usize>,
}

/// The whole training step — forward with trajectory capture, the
/// strategy's adjoint backward, and the loss/grad tail — as one flat
/// instruction list over a liveness-planned arena.
///
/// The strategy's checkpoint schedule drives slot liveness: block
/// boundaries and checkpointed/taped step states stay live from their
/// forward definition to their last backward read (the long-lived
/// O(L)+O(N_t) trajectory slots), every other intermediate recycles its
/// slot as soon as its last reader retires, and revolve's recompute
/// segments are unrolled at build time into straight-line replays over
/// the same arena. Steady-state training steps make **zero allocations**
/// beyond the returned gradient tensors (arena buffers recycle through a
/// pool; `train_arena_allocs`/`train_arena_reuses` prove it).
///
/// Bit-identity with the interpreter path is structural: same module
/// plans in the same order, and the two non-call primitives (`Zero`,
/// `Acc`) replicate `Tensor::zeros` + `axpy(1.0, g)` operation for
/// operation.
pub struct TrainProgram {
    plans: Vec<Arc<ModulePlan>>,
    instrs: Vec<TrainInstr>,
    arena_len: usize,
    slot_count: usize,
    loss_off: usize,
    correct_off: usize,
    grad_outs: Vec<GradOut>,
    /// Layout-covered params the backward never writes get interpreter-
    /// identical zero gradients.
    grad_zero: Vec<(usize, Vec<usize>)>,
    param_count: usize,
    kernel_calls: usize,
    trajectory_bytes: usize,
    recompute_segments: usize,
    pruned_fills: usize,
    /// Interior trajectory node states pinned in long-lived arena slots
    /// by interpolated-adjoint blocks (0 for every other strategy).
    interp_nodes_pinned: usize,
    /// Interpreter ledger script, forward order: one BlockInput alloc per
    /// stored boundary (x, block inputs, transition inputs, interior
    /// interpolation nodes).
    tracked_bytes: Vec<usize>,
    /// Interpreter ledger script, backward block order: one transient
    /// StepState alloc+free per block backward.
    step_state_bytes: Vec<usize>,
    pool: Mutex<Vec<Vec<f32>>>,
    stats: Arc<CompileStats>,
}

/// Build-time state of the chain → IR walk: virtual values with shapes,
/// plan deduplication, call emission with spec validation.
struct TrainBuilder<'a> {
    reg: &'a ArtifactRegistry,
    set: &'a CompiledSet,
    param_shapes: &'a [Vec<usize>],
    plans: Vec<Arc<ModulePlan>>,
    plan_ids: HashMap<String, usize>,
    ops: Vec<TrainOp>,
    shapes: Vec<Vec<usize>>,
    trajectory: Vec<bool>,
}

impl TrainBuilder<'_> {
    fn plan_id(&mut self, module: &str) -> Result<usize> {
        if let Some(&i) = self.plan_ids.get(module) {
            return Ok(i);
        }
        let plan = self
            .set
            .plan(module)
            .ok_or_else(|| CompileError::MissingModule { module: module.to_string() })?;
        self.plans.push(plan.clone());
        self.plan_ids.insert(module.to_string(), self.plans.len() - 1);
        Ok(self.plans.len() - 1)
    }

    fn value(&mut self, shape: Vec<usize>) -> usize {
        self.shapes.push(shape);
        self.trajectory.push(false);
        self.shapes.len() - 1
    }

    /// Emit one validated module call; returns the output value ids.
    fn call(&mut self, module: &str, args: Vec<TrainArg>) -> Result<Vec<usize>> {
        let spec = self
            .reg
            .module_spec(module)
            .map_err(|_| CompileError::MissingModule { module: module.to_string() })?;
        let mut supplied: Vec<Option<&[usize]>> = Vec::with_capacity(args.len());
        for a in &args {
            supplied.push(match a {
                TrainArg::Image | TrainArg::Labels => None,
                TrainArg::Param(p) => Some(
                    self.param_shapes
                        .get(*p)
                        .ok_or_else(|| CompileError::Unsupported {
                            module: module.to_string(),
                            reason: format!("chain references parameter {p} outside the layout"),
                        })?
                        .as_slice(),
                ),
                TrainArg::Val(v) => Some(self.shapes[*v].as_slice()),
            });
        }
        check_module_args(spec, &supplied)?;
        let outs: Vec<usize> =
            spec.outputs.iter().map(|o| o.shape.clone()).map(|s| self.value(s)).collect();
        let plan = self.plan_id(module)?;
        self.ops.push(TrainOp::Call { plan, args, outs: outs.iter().map(|&v| Some(v)).collect() });
        Ok(outs)
    }

    /// Emit a call expected to produce exactly `n` outputs.
    fn call_n(
        &mut self,
        module: &str,
        args: Vec<TrainArg>,
        n: usize,
        what: &str,
    ) -> Result<Vec<usize>> {
        let outs = self.call(module, args)?;
        if outs.len() != n {
            return Err(CompileError::Unsupported {
                module: module.to_string(),
                reason: format!("{what} needs {n} outputs, manifest declares {}", outs.len()),
            });
        }
        Ok(outs)
    }

    fn bytes_of(&self, v: usize) -> usize {
        element_count(&self.shapes[v]) * std::mem::size_of::<f32>()
    }
}

impl TrainProgram {
    /// Lower a [`TrainChain`] against a compiled-backend registry:
    /// forward walk, strategy backward (checkpoint schedules unrolled
    /// statically), loss/grad tail — then dead-fill pruning, liveness
    /// interval construction with trajectory slots pinned across the
    /// forward→backward gap, and [`assign_slots`] arena layout. All
    /// validation (module existence, arity, cross-module shapes,
    /// schedule executability, full gradient coverage) happens here,
    /// once; the runtime never checks again.
    pub fn build(
        reg: &ArtifactRegistry,
        chain: &TrainChain,
        param_shapes: &[Vec<usize>],
    ) -> Result<TrainProgram> {
        let Some(set) = reg.compiled_set() else {
            return Err(CompileError::Unsupported {
                module: "<train>".into(),
                reason: "registry does not run the compiled backend".into(),
            });
        };
        let unsupported = |module: &str, reason: String| CompileError::Unsupported {
            module: module.to_string(),
            reason,
        };

        let mut b = TrainBuilder {
            reg,
            set,
            param_shapes,
            plans: Vec::new(),
            plan_ids: HashMap::new(),
            ops: Vec::new(),
            shapes: Vec::new(),
            trajectory: Vec::new(),
        };

        // ---- Forward walk with trajectory capture -------------------
        // The interpreter tracks x under BlockInput right after the stem
        // call; replicate its ledger script exactly (same sizes, same
        // order) so compiled training is traffic-identical to sim serial.
        let stem_spec = reg
            .module_spec(&chain.stem_fwd)
            .map_err(|_| CompileError::MissingModule { module: chain.stem_fwd.clone() })?;
        let image_bytes = stem_spec
            .inputs
            .first()
            .map(|t| element_count(&t.shape) * std::mem::size_of::<f32>())
            .ok_or_else(|| unsupported(&chain.stem_fwd, "stem takes no inputs".into()))?;
        let (sw, sb) = chain.stem_params;
        let mut z = b.call_n(
            &chain.stem_fwd,
            vec![TrainArg::Image, TrainArg::Param(sw), TrainArg::Param(sb)],
            1,
            "stem forward",
        )?[0];
        let mut tracked_bytes = vec![image_bytes];

        // (z_in, z_out) per block, per stage — the captured trajectory —
        // plus, for interpolated-adjoint blocks, the interior node value
        // ids captured by the stepwise forward (in increasing t order).
        let mut block_bounds: Vec<Vec<(usize, usize)>> = Vec::with_capacity(chain.stages.len());
        let mut block_node_vals: Vec<Vec<Vec<usize>>> = Vec::with_capacity(chain.stages.len());
        let mut trans_inputs: Vec<usize> = Vec::new();
        let mut interp_nodes_pinned = 0usize;
        for stage in &chain.stages {
            let mut bounds = Vec::with_capacity(stage.blocks.len());
            let mut node_vals = Vec::with_capacity(stage.blocks.len());
            for blk in &stage.blocks {
                if let TrainBackward::Interpolated { step_fwd, nodes, .. } = &blk.backward {
                    // Stepwise forward so the node states exist to pin —
                    // the same walk the interpreter's coordinator runs,
                    // with the same BlockInput ledger entries (z_in, then
                    // interior nodes as they appear).
                    tracked_bytes.push(b.bytes_of(z));
                    b.trajectory[z] = true;
                    let z_in = z;
                    let node_ids = interp_nodes(chain.nt, *nodes);
                    let mut captured = Vec::new();
                    let mut cur = z;
                    for t in 0..chain.nt {
                        let mut args: Vec<TrainArg> = vec![TrainArg::Val(cur)];
                        args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                        let next =
                            b.call_n(step_fwd, args, 1, "interpolated step forward")?[0];
                        if t + 1 < chain.nt && node_ids.contains(&(t + 1)) {
                            tracked_bytes.push(b.bytes_of(next));
                            b.trajectory[next] = true;
                            captured.push(next);
                            interp_nodes_pinned += 1;
                        }
                        cur = next;
                    }
                    bounds.push((z_in, cur));
                    node_vals.push(captured);
                    z = cur;
                } else {
                    let mut args: Vec<TrainArg> = vec![TrainArg::Val(z)];
                    args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                    let z1 = b.call_n(&blk.fwd, args, 1, "block forward")?[0];
                    tracked_bytes.push(b.bytes_of(z));
                    b.trajectory[z] = true;
                    bounds.push((z, z1));
                    node_vals.push(Vec::new());
                    z = z1;
                }
            }
            block_bounds.push(bounds);
            block_node_vals.push(node_vals);
            if let Some(trans) = &stage.trans {
                tracked_bytes.push(b.bytes_of(z));
                b.trajectory[z] = true;
                trans_inputs.push(z);
                let (tw, tb) = trans.params;
                z = b.call_n(
                    &trans.fwd,
                    vec![TrainArg::Val(z), TrainArg::Param(tw), TrainArg::Param(tb)],
                    1,
                    "transition forward",
                )?[0];
            }
        }
        let z_final = z;

        // ---- Loss/grad head -----------------------------------------
        let (hw, hb) = chain.head_params;
        let head = b.call_n(
            &chain.head_loss_grad,
            vec![
                TrainArg::Val(z_final),
                TrainArg::Param(hw),
                TrainArg::Param(hb),
                TrainArg::Labels,
            ],
            5,
            "loss/grad head",
        )?;
        let (v_loss, v_correct) = (head[0], head[1]);
        for v in [v_loss, v_correct] {
            if element_count(&b.shapes[v]) != 1 {
                return Err(unsupported(
                    &chain.head_loss_grad,
                    format!("loss/correct outputs must be scalars, found {:?}", b.shapes[v]),
                ));
            }
        }
        let mut gz = head[2];
        let mut grad_of: HashMap<usize, usize> = HashMap::new();
        grad_of.insert(hw, head[3]);
        grad_of.insert(hb, head[4]);

        // ---- Strategy backward, reverse network order ---------------
        let mut step_state_bytes = Vec::new();
        let mut recompute_segments = 0usize;
        for (s, stage) in chain.stages.iter().enumerate().rev() {
            if let Some(trans) = &stage.trans {
                let (tw, tb) = trans.params;
                let outs = b.call_n(
                    &trans.vjp,
                    vec![
                        TrainArg::Val(trans_inputs[s]),
                        TrainArg::Param(tw),
                        TrainArg::Param(tb),
                        TrainArg::Val(gz),
                    ],
                    3,
                    "transition VJP",
                )?;
                gz = outs[0];
                grad_of.insert(tw, outs[1]);
                grad_of.insert(tb, outs[2]);
            }
            for (bi, blk) in stage.blocks.iter().enumerate().rev() {
                let (z_in, z_out) = block_bounds[s][bi];
                let act_bytes = b.bytes_of(z_in);
                match &blk.backward {
                    TrainBackward::Fused { module } => {
                        let mut args: Vec<TrainArg> = vec![TrainArg::Val(z_in)];
                        args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                        args.push(TrainArg::Val(gz));
                        let outs =
                            b.call_n(module, args, 1 + blk.params.len(), "fused block VJP")?;
                        gz = outs[0];
                        for (&p, &g) in blk.params.iter().zip(&outs[1..]) {
                            grad_of.insert(p, g);
                        }
                        step_state_bytes.push(chain.nt * act_bytes);
                    }
                    TrainBackward::FromOutput { module } => {
                        let mut args: Vec<TrainArg> = vec![TrainArg::Val(z_out)];
                        args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                        args.push(TrainArg::Val(gz));
                        // Trailing z0_rec output is dead in training; the
                        // prune pass drops its fill and arena slot.
                        let outs =
                            b.call_n(module, args, 2 + blk.params.len(), "reverse-solve VJP")?;
                        gz = outs[0];
                        for (&p, &g) in blk.params.iter().zip(&outs[1..1 + blk.params.len()]) {
                            grad_of.insert(p, g);
                        }
                    }
                    TrainBackward::Checkpointed { step_fwd, step_vjp, schedule } => {
                        if schedule.nt != chain.nt {
                            return Err(unsupported(
                                step_fwd,
                                format!(
                                    "schedule covers {} steps, block runs {}",
                                    schedule.nt, chain.nt
                                ),
                            ));
                        }
                        let errs = schedule.validate();
                        if !errs.is_empty() {
                            return Err(unsupported(
                                step_fwd,
                                format!("invalid checkpoint schedule: {}", errs.join("; ")),
                            ));
                        }
                        // Interpreter order: accumulators zeroed before the
                        // sweep, one axpy(1.0) per step VJP in schedule order.
                        let accs: Vec<usize> = blk
                            .params
                            .iter()
                            .map(|&p| {
                                let v = b.value(param_shapes[p].clone());
                                b.ops.push(TrainOp::Zero { out: v });
                                v
                            })
                            .collect();
                        // Static unroll of the schedule, value-aliased: a
                        // Checkpoint stores no copy — the checkpointed value
                        // simply stays live (its arena slot is pinned) until
                        // its last Restore replays a segment from it.
                        let mut cp_slots: HashMap<usize, usize> = HashMap::new();
                        let mut tape: Vec<usize> = Vec::new();
                        let mut cur = z_in;
                        let mut adj = gz;
                        for (idx, action) in schedule.actions.iter().enumerate() {
                            match *action {
                                Action::Checkpoint { slot, .. } => {
                                    b.trajectory[cur] = true;
                                    cp_slots.insert(slot, cur);
                                }
                                Action::Restore { slot, .. } => {
                                    cur = *cp_slots.get(&slot).ok_or_else(|| {
                                        unsupported(
                                            step_fwd,
                                            format!("action {idx}: restore of empty slot {slot}"),
                                        )
                                    })?;
                                    recompute_segments += 1;
                                }
                                Action::Forward { store_tape, .. } => {
                                    let mut args: Vec<TrainArg> = vec![TrainArg::Val(cur)];
                                    args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                                    let next =
                                        b.call_n(step_fwd, args, 1, "checkpoint step forward")?[0];
                                    if store_tape {
                                        b.trajectory[cur] = true;
                                        tape.push(cur);
                                    }
                                    cur = next;
                                }
                                Action::Backward { .. } => {
                                    let z_tape = tape.pop().ok_or_else(|| {
                                        unsupported(
                                            step_vjp,
                                            format!("action {idx}: backward over an empty tape"),
                                        )
                                    })?;
                                    let mut args: Vec<TrainArg> = vec![TrainArg::Val(z_tape)];
                                    args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                                    args.push(TrainArg::Val(adj));
                                    let outs = b.call_n(
                                        step_vjp,
                                        args,
                                        1 + blk.params.len(),
                                        "checkpoint step VJP",
                                    )?;
                                    adj = outs[0];
                                    for (&acc, &g) in accs.iter().zip(&outs[1..]) {
                                        b.ops.push(TrainOp::Acc { src: g, dst: acc });
                                    }
                                }
                            }
                        }
                        gz = adj;
                        for (&p, &acc) in blk.params.iter().zip(&accs) {
                            grad_of.insert(p, acc);
                        }
                        // Interpreter ledger cost: (m slots + 1 tape) states.
                        let slots = schedule.strategy.slots(schedule.nt);
                        step_state_bytes.push((slots + 1) * act_bytes);
                    }
                    TrainBackward::Interpolated { step_vjp, nodes, .. } => {
                        let node_ids = interp_nodes(chain.nt, *nodes);
                        // Node values by node index: the block endpoints
                        // plus the interior states pinned by the forward.
                        let interior = &block_node_vals[s][bi];
                        if interior.len()
                            != node_ids.iter().filter(|&&t| t != 0 && t != chain.nt).count()
                        {
                            return Err(unsupported(
                                step_vjp,
                                format!(
                                    "forward pinned {} interior nodes, backward expects {}",
                                    interior.len(),
                                    node_ids.len().saturating_sub(2)
                                ),
                            ));
                        }
                        let mut by_node: Vec<usize> = Vec::with_capacity(node_ids.len());
                        let mut next_interior = 0usize;
                        for &t in &node_ids {
                            if t == 0 {
                                by_node.push(z_in);
                            } else if t == chain.nt {
                                by_node.push(z_out);
                            } else {
                                by_node.push(interior[next_interior]);
                                next_interior += 1;
                            }
                        }
                        // Interpreter order: accumulators zeroed before the
                        // sweep, one axpy(1.0) per step VJP, t descending.
                        let accs: Vec<usize> = blk
                            .params
                            .iter()
                            .map(|&p| {
                                let v = b.value(param_shapes[p].clone());
                                b.ops.push(TrainOp::Zero { out: v });
                                v
                            })
                            .collect();
                        let mut adj = gz;
                        for t in (0..chain.nt).rev() {
                            // At a node the pinned value is read directly
                            // (bitwise); elsewhere a const-folded
                            // barycentric mix reconstructs the step input.
                            let zt = match node_ids.iter().position(|&x| x == t) {
                                Some(j) => by_node[j],
                                None => {
                                    let coeffs = interp_coeffs(&node_ids, t);
                                    let shape = b.shapes[z_in].clone();
                                    let v = b.value(shape);
                                    b.ops.push(TrainOp::Interp {
                                        out: v,
                                        terms: by_node
                                            .iter()
                                            .zip(&coeffs)
                                            .map(|(&src, &c)| (src, c.to_bits()))
                                            .collect(),
                                    });
                                    v
                                }
                            };
                            let mut args: Vec<TrainArg> = vec![TrainArg::Val(zt)];
                            args.extend(blk.params.iter().map(|&p| TrainArg::Param(p)));
                            args.push(TrainArg::Val(adj));
                            let outs = b.call_n(
                                step_vjp,
                                args,
                                1 + blk.params.len(),
                                "interpolated step VJP",
                            )?;
                            adj = outs[0];
                            for (&acc, &g) in accs.iter().zip(&outs[1..]) {
                                b.ops.push(TrainOp::Acc { src: g, dst: acc });
                            }
                        }
                        gz = adj;
                        for (&p, &acc) in blk.params.iter().zip(&accs) {
                            grad_of.insert(p, acc);
                        }
                        // Interpreter ledger cost: one reconstructed state
                        // at a time (nodes are metered as BlockInput).
                        step_state_bytes.push(act_bytes);
                    }
                }
            }
        }
        let outs = b.call_n(
            &chain.stem_vjp,
            vec![TrainArg::Image, TrainArg::Param(sw), TrainArg::Param(sb), TrainArg::Val(gz)],
            2,
            "stem VJP",
        )?;
        grad_of.insert(sw, outs[0]);
        grad_of.insert(sb, outs[1]);

        // ---- Dead-fill pruning + liveness + arena layout ------------
        let kernel_calls =
            b.ops.iter().filter(|op| matches!(op, TrainOp::Call { .. })).count();
        let mut roots = vec![v_loss, v_correct];
        roots.extend(grad_of.values().copied());
        let mut ir = TrainIr { ops: b.ops, value_count: b.shapes.len(), roots };
        let pruned_fills = prune_dead_outputs(&mut ir);

        let n_ops = ir.ops.len();
        let nvals = ir.value_count;
        let mut def = vec![0usize; nvals];
        let mut last = vec![0usize; nvals];
        let mut live = vec![false; nvals];
        for (i, op) in ir.ops.iter().enumerate() {
            match op {
                TrainOp::Call { args, outs, .. } => {
                    for a in args {
                        if let TrainArg::Val(v) = a {
                            last[*v] = i;
                        }
                    }
                    for out in outs.iter().flatten() {
                        def[*out] = i;
                        last[*out] = i;
                        live[*out] = true;
                    }
                }
                TrainOp::Zero { out } => {
                    def[*out] = i;
                    last[*out] = i;
                    live[*out] = true;
                }
                TrainOp::Acc { src, dst } => {
                    last[*src] = i;
                    last[*dst] = i;
                }
                TrainOp::Interp { out, terms } => {
                    for (src, _) in terms {
                        last[*src] = i;
                    }
                    def[*out] = i;
                    last[*out] = i;
                    live[*out] = true;
                }
            }
        }
        // Roots stay live through the epilogue (output extraction).
        for &r in &ir.roots {
            last[r] = n_ops;
        }

        let mut intervals = Vec::new();
        let mut placed: Vec<Option<(usize, usize)>> = vec![None; nvals];
        let mut interval_vals = Vec::new();
        for v in 0..nvals {
            if live[v] {
                intervals.push((def[v], last[v], element_count(&b.shapes[v])));
                interval_vals.push(v);
            }
        }
        let (slots, slot_sizes) = assign_slots(&intervals);
        let mut offsets = Vec::with_capacity(slot_sizes.len());
        let mut total = 0usize;
        for &size in &slot_sizes {
            offsets.push(total);
            total += size;
        }
        for (k, &v) in interval_vals.iter().enumerate() {
            placed[v] = Some((offsets[slots[k]], element_count(&b.shapes[v])));
        }
        let place = |v: usize| placed[v].expect("live value has an arena placement");

        let instrs: Vec<TrainInstr> = ir
            .ops
            .iter()
            .map(|op| match op {
                TrainOp::Call { plan, args, outs } => TrainInstr::Call {
                    plan: *plan,
                    args: args
                        .iter()
                        .map(|a| match *a {
                            TrainArg::Image => Loc::Image,
                            TrainArg::Labels => Loc::Labels,
                            TrainArg::Param(p) => Loc::Param(p),
                            TrainArg::Val(v) => {
                                let (off, len) = place(v);
                                Loc::Slot { off, len }
                            }
                        })
                        .collect(),
                    outs: outs.iter().map(|o| o.map(&place)).collect(),
                },
                TrainOp::Zero { out } => {
                    let (off, len) = place(*out);
                    TrainInstr::Zero { off, len }
                }
                TrainOp::Acc { src, dst } => {
                    let (src, _) = place(*src);
                    let (dst, len) = place(*dst);
                    TrainInstr::Acc { src, dst, len }
                }
                TrainOp::Interp { out, terms } => {
                    let (off, len) = place(*out);
                    TrainInstr::Interp {
                        off,
                        len,
                        terms: terms.iter().map(|&(src, bits)| (place(src).0, bits)).collect(),
                    }
                }
            })
            .collect();

        // ---- Outputs ------------------------------------------------
        let (loss_off, _) = place(v_loss);
        let (correct_off, _) = place(v_correct);
        let mut grad_outs = Vec::with_capacity(grad_of.len());
        let mut grad_zero = Vec::new();
        for (p, shape) in param_shapes.iter().enumerate() {
            match grad_of.get(&p) {
                Some(&v) => {
                    let (off, len) = place(v);
                    grad_outs.push(GradOut { param: p, off, len, shape: b.shapes[v].clone() });
                }
                None => grad_zero.push((p, shape.clone())),
            }
        }

        let trajectory_bytes: usize = (0..nvals)
            .filter(|&v| live[v] && b.trajectory[v])
            .map(|v| element_count(&b.shapes[v]) * std::mem::size_of::<f32>())
            .sum();

        let stats = set.stats().clone();
        stats
            .arena_bytes
            .fetch_add((total * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
        stats.trajectory_bytes.fetch_add(trajectory_bytes as u64, Ordering::Relaxed);
        stats.train_recompute_segments.fetch_add(recompute_segments as u64, Ordering::Relaxed);
        stats.train_interp_nodes.fetch_add(interp_nodes_pinned as u64, Ordering::Relaxed);
        Ok(TrainProgram {
            plans: b.plans,
            instrs,
            arena_len: total,
            slot_count: slot_sizes.len(),
            loss_off,
            correct_off,
            grad_outs,
            grad_zero,
            param_count: param_shapes.len(),
            kernel_calls,
            trajectory_bytes,
            recompute_segments,
            pruned_fills,
            interp_nodes_pinned,
            tracked_bytes,
            step_state_bytes,
            pool: Mutex::new(Vec::new()),
            stats,
        })
    }

    /// Kernels dispatched per run — equal to the interpreter path's
    /// module-call count for the same strategy (call-accounting parity).
    pub fn kernel_calls(&self) -> usize {
        self.kernel_calls
    }

    /// Arena slots after liveness reuse.
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Bytes of one arena buffer.
    pub fn arena_bytes(&self) -> usize {
        self.arena_len * std::mem::size_of::<f32>()
    }

    /// Bytes of arena devoted to trajectory state (block boundaries plus
    /// checkpointed/taped step states) — the planned O(L)+O(N_t) budget.
    pub fn trajectory_bytes(&self) -> usize {
        self.trajectory_bytes
    }

    /// Recompute segments unrolled from checkpoint schedules (0 for the
    /// fused/reverse-solve strategies).
    pub fn recompute_segments(&self) -> usize {
        self.recompute_segments
    }

    /// Dead output fills pruned at build time (e.g. `node`'s z0_rec).
    pub fn pruned_fills(&self) -> usize {
        self.pruned_fills
    }

    /// Interior trajectory node states pinned in long-lived arena slots by
    /// interpolated-adjoint blocks (0 for every other strategy).
    pub fn interp_nodes_pinned(&self) -> usize {
        self.interp_nodes_pinned
    }

    /// The interpreter's BlockInput ledger script (alloc sizes in forward
    /// order) — the coordinator replays it so compiled training stays
    /// traffic-identical to sim serial.
    pub(crate) fn tracked_bytes(&self) -> &[usize] {
        &self.tracked_bytes
    }

    /// The interpreter's transient StepState ledger script (alloc+free
    /// sizes in backward block order).
    pub(crate) fn step_state_bytes(&self) -> &[usize] {
        &self.step_state_bytes
    }

    /// Run one training step: `(loss, correct, grads)` over a pooled
    /// arena. Zero steady-state allocations beyond the returned gradient
    /// tensors; bit-identical to the interpreter traversal with the same
    /// strategy (same plans, same order, same accumulation arithmetic).
    pub fn run(
        &self,
        x: &Tensor,
        labels: &Tensor,
        params: &[Tensor],
    ) -> crate::runtime::Result<(f32, f32, Vec<Tensor>)> {
        let mut arena = match self.pool.lock().expect("train arena pool poisoned").pop() {
            Some(buf) => {
                self.stats.train_arena_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.stats.train_arena_allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; self.arena_len]
            }
        };

        for instr in &self.instrs {
            match instr {
                TrainInstr::Call { plan, args, outs } => {
                    let plan = &self.plans[*plan];
                    let mut h = plan.seed;
                    for step in &plan.steps {
                        match *step {
                            AbsorbStep::Len(l) => h = mix(h, l),
                            AbsorbStep::Data(i) => {
                                let part: &[f32] = match args[i] {
                                    Loc::Image => x.data(),
                                    Loc::Labels => labels.data(),
                                    Loc::Param(p) => params[p].data(),
                                    Loc::Slot { off, len } => &arena[off..off + len],
                                };
                                for &v in part {
                                    h = mix(h, u64::from(v.to_bits()));
                                }
                            }
                        }
                    }
                    for (oi, out) in outs.iter().enumerate() {
                        if let Some((off, len)) = *out {
                            plan.fill_into(h, oi, &mut arena[off..off + len]);
                        }
                    }
                }
                TrainInstr::Zero { off, len } => arena[*off..*off + *len].fill(0.0),
                TrainInstr::Acc { src, dst, len } => {
                    // Disjoint slots by liveness (the accumulator overlaps
                    // every per-step gradient's live range), so indexed
                    // copies are safe; += v is exactly axpy(1.0, v).
                    for j in 0..*len {
                        let v = arena[src + j];
                        arena[dst + j] += v;
                    }
                }
                TrainInstr::Interp { off, len, terms } => {
                    // Zero-then-accumulate in term order — exactly the
                    // interpreter's Tensor::zeros + axpy(c_j, node_j).
                    // Output and operand slots are disjoint by liveness
                    // (node slots stay live past this instruction).
                    arena[*off..*off + *len].fill(0.0);
                    for &(src, bits) in terms {
                        let c = f32::from_bits(bits);
                        for j in 0..*len {
                            let v = arena[src + j];
                            arena[*off + j] += c * v;
                        }
                    }
                }
            }
        }

        let loss = arena[self.loss_off];
        let correct = arena[self.correct_off];
        let grads = (|| -> crate::runtime::Result<Vec<Tensor>> {
            let mut grads: Vec<Option<Tensor>> = (0..self.param_count).map(|_| None).collect();
            for g in &self.grad_outs {
                let t = Tensor::from_vec(g.shape.clone(), arena[g.off..g.off + g.len].to_vec())
                    .map_err(|e| RuntimeError::Shape(format!("compiled train grad: {e}")))?;
                grads[g.param] = Some(t);
            }
            for (p, shape) in &self.grad_zero {
                grads[*p] = Some(Tensor::zeros(shape));
            }
            grads
                .into_iter()
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| RuntimeError::Shape("train program missed a gradient".into()))
        })();
        self.pool.lock().expect("train arena pool poisoned").push(arena);
        Ok((loss, correct, grads?))
    }
}

// Both programs are shared across worker threads via the execution core.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<InferProgram>();
    assert_send_sync::<TrainProgram>();
};
