//! Plan emission: typed IR → compact kernel programs, plus the fused
//! model-level inference program with its liveness-planned buffer arena.
//!
//! A [`ModulePlan`] is the unit the registry dispatches: a folded seed
//! digest, a flat list of absorb steps, and shape-specialized output
//! fills — no spec lookup, no name hashing, no shape checks on the hot
//! path. An [`InferProgram`] chains module plans into the whole
//! inference forward (stem → per-time-step blocks → transitions) with
//! every intermediate activation placed in one preallocated arena by
//! liveness analysis ([`assign_slots`]), so steady-state execution
//! performs **zero allocations** beyond the returned output tensor
//! (arena buffers recycle through a pool; the counters in
//! [`CompileStats`] prove it).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use crate::runtime::sim::{centered, mix};
use crate::runtime::{ArtifactRegistry, ModuleSpec, RuntimeError};
use crate::tensor::Tensor;

use super::ir::{element_count, AbsorbStep, ModuleIr, OpKind, ValueId};
use super::passes::run_default_passes;
use super::{CompileError, CompileStats, Result};

/// One shape-specialized output fill of a [`ModulePlan`].
#[derive(Debug, Clone, PartialEq, Eq)]
struct OutputPlan {
    shape: Vec<usize>,
    len: usize,
}

/// The compiled form of one module: a flat fused-kernel program.
///
/// Executing a plan is exactly the value model of
/// [`crate::runtime::sim::sim_outputs`] — bit-identical by construction,
/// since both build on the same `mix`/`centered` primitives — minus all
/// per-call interpretation: the constant prefix (name digest + first
/// length mix) is folded into [`seed`](Self::seed) at compile time, and
/// shapes were validated when the plan was built.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModulePlan {
    name: String,
    seed: u64,
    steps: Vec<AbsorbStep>,
    outputs: Vec<OutputPlan>,
    input_count: usize,
    fused_ops: usize,
    folded_consts: usize,
    primitives: usize,
}

/// Compile one module through the full pipeline: IR construction (all
/// validation), default passes, lowering. Never panics — corrupt specs
/// surface as typed [`CompileError`]s.
pub fn compile_module(spec: &ModuleSpec) -> Result<ModulePlan> {
    let mut ir = super::ir::build_module_ir(spec)?;
    let stats = run_default_passes(&mut ir);
    let mut plan = lower_module(&ir)?;
    plan.fused_ops = stats.fused;
    plan.folded_consts = stats.folded;
    Ok(plan)
}

/// Lower a (passed or raw) [`ModuleIr`] to a [`ModulePlan`]. The digest
/// graph must be a single chain ending in the fills — anything else is a
/// typed [`CompileError::Unsupported`], so hand-mangled IR cannot panic
/// the lowering.
pub fn lower_module(ir: &ModuleIr) -> Result<ModulePlan> {
    let unsupported = |reason: &str| CompileError::Unsupported {
        module: ir.name.clone(),
        reason: reason.to_string(),
    };

    let mut consts: std::collections::HashMap<ValueId, u64> = std::collections::HashMap::new();
    let mut seed: Option<u64> = None;
    let mut chain: Option<ValueId> = None;
    let mut steps: Vec<AbsorbStep> = Vec::new();
    let mut fills: Vec<(usize, ValueId)> = Vec::new();

    // Adopt `src` as the start of the dynamic chain (or extend it).
    fn begin_or_extend(
        name: &str,
        src: ValueId,
        id: ValueId,
        chain: &mut Option<ValueId>,
        seed: &mut Option<u64>,
        consts: &std::collections::HashMap<ValueId, u64>,
    ) -> Result<()> {
        let unsupported = |reason: &str| CompileError::Unsupported {
            module: name.to_string(),
            reason: reason.to_string(),
        };
        match (*chain, consts.get(&src)) {
            (Some(tail), _) if tail == src => {}
            (None, Some(&c)) => *seed = Some(c),
            (Some(_), Some(_)) | (Some(_), None) => {
                return Err(unsupported("digest graph is not a single chain"));
            }
            (None, None) => return Err(unsupported("op reads an undefined digest")),
        }
        *chain = Some(id);
        Ok(())
    }

    for op in &ir.ops {
        match &op.kind {
            OpKind::Const(c) => {
                consts.insert(op.id, *c);
            }
            OpKind::NameDigest => {
                consts.insert(op.id, crate::runtime::sim::name_digest(&ir.name));
            }
            OpKind::MixLen { src, len } => {
                if let Some(&c) = consts.get(src) {
                    consts.insert(op.id, mix(c, *len));
                } else {
                    begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                    steps.push(AbsorbStep::Len(*len));
                }
            }
            OpKind::AbsorbData { src, input } => {
                if *input >= ir.input_shapes.len() {
                    return Err(unsupported("absorb references a nonexistent input"));
                }
                begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                steps.push(AbsorbStep::Data(*input));
            }
            OpKind::FusedAbsorb { src, steps: fused, .. } => {
                if fused.iter().any(
                    |s| matches!(s, AbsorbStep::Data(i) if *i >= ir.input_shapes.len()),
                ) {
                    return Err(unsupported("fused absorb references a nonexistent input"));
                }
                begin_or_extend(&ir.name, *src, op.id, &mut chain, &mut seed, &consts)?;
                steps.extend(fused.iter().copied());
            }
            OpKind::Fill { src, output } => fills.push((*output, *src)),
            OpKind::FusedFill { src, outputs, .. } => {
                fills.extend(outputs.iter().map(|&o| (o, *src)));
            }
        }
    }

    // Every fill must read the final digest — either the chain tail or,
    // for a module with no runtime inputs, a fully folded constant.
    let final_digest = chain;
    for &(_, src) in &fills {
        match final_digest {
            Some(tail) if src == tail => {}
            Some(_) => return Err(unsupported("fill reads a non-final digest")),
            None => {
                let Some(&c) = consts.get(&src) else {
                    return Err(unsupported("fill reads an undefined digest"));
                };
                match seed {
                    Some(s) if s != c => {
                        return Err(unsupported("fills disagree on the seed digest"));
                    }
                    _ => seed = Some(c),
                }
            }
        }
    }
    let Some(seed) = seed else {
        return Err(unsupported("program produces no digest"));
    };

    // Exactly one fill per declared output.
    let mut outputs: Vec<Option<OutputPlan>> = vec![None; ir.output_shapes.len()];
    for (o, _) in fills {
        let Some(slot) = outputs.get_mut(o) else {
            return Err(unsupported("fill targets a nonexistent output"));
        };
        if slot.is_some() {
            return Err(unsupported("output filled twice"));
        }
        let shape = ir.output_shapes[o].clone();
        let len = element_count(&shape);
        *slot = Some(OutputPlan { shape, len });
    }
    let outputs = outputs
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| unsupported("a declared output is never filled"))?;

    Ok(ModulePlan {
        name: ir.name.clone(),
        seed,
        steps,
        outputs,
        input_count: ir.input_shapes.len(),
        fused_ops: 0,
        folded_consts: 0,
        primitives: ir.primitive_count(),
    })
}

impl ModulePlan {
    /// Module this plan was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Inputs the plan expects (the only per-call check trusted callers
    /// keep is this arity).
    pub fn input_count(&self) -> usize {
        self.input_count
    }

    /// Outputs the plan materializes.
    pub fn output_count(&self) -> usize {
        self.outputs.len()
    }

    /// Fused kernels in this plan (see [`super::passes::fuse`]).
    pub fn fused_ops(&self) -> usize {
        self.fused_ops
    }

    /// Ops constant-folded while compiling this plan.
    pub fn folded_consts(&self) -> usize {
        self.folded_consts
    }

    /// Primitive ops this plan covers (invariant under fusion).
    pub fn primitive_count(&self) -> usize {
        self.primitives
    }

    /// The digest after absorbing `parts` (one slice per declared input).
    fn digest_parts(&self, parts: &[&[f32]]) -> u64 {
        let mut h = self.seed;
        for step in &self.steps {
            match *step {
                AbsorbStep::Len(l) => h = mix(h, l),
                AbsorbStep::Data(i) => {
                    for &v in parts[i] {
                        h = mix(h, u64::from(v.to_bits()));
                    }
                }
            }
        }
        h
    }

    /// Fill output `oi` (0-based) off the final digest into `out`.
    fn fill_into(&self, h: u64, oi: usize, out: &mut [f32]) {
        let base = mix(h, oi as u64 + 1);
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = centered(mix(base, j as u64));
        }
    }

    /// Execute the plan. **No shape checks** — compile time validated the
    /// manifest and the caller (the registry seam) owns input validation.
    /// Bit-identical to `sim_outputs` on the same module and inputs.
    pub fn execute(&self, inputs: &[&Tensor]) -> crate::runtime::Result<Vec<Tensor>> {
        let parts: Vec<&[f32]> = inputs.iter().map(|t| t.data()).collect();
        let h = self.digest_parts(&parts);
        self.outputs
            .iter()
            .enumerate()
            .map(|(oi, o)| {
                let mut data = vec![0.0f32; o.len];
                self.fill_into(h, oi, &mut data);
                Tensor::from_vec(o.shape.clone(), data)
                    .map_err(|e| RuntimeError::Shape(format!("compiled {}: {e}", self.name)))
            })
            .collect()
    }
}

/// Greedy liveness-interval slot assignment: `intervals[i] = (def,
/// last_use, len)` per value, in definition order. Returns `(slot of
/// each value, slot sizes)`. A slot is reusable strictly **after** its
/// holder's last use (`last_use + 1`), so a value written at instruction
/// `i` can never alias an operand still being read at `i`.
pub fn assign_slots(intervals: &[(usize, usize, usize)]) -> (Vec<usize>, Vec<usize>) {
    let mut slot_sizes: Vec<usize> = Vec::new();
    let mut free_at: Vec<usize> = Vec::new();
    let mut assignment = Vec::with_capacity(intervals.len());
    for &(def, last_use, len) in intervals {
        let slot = match (0..slot_sizes.len()).find(|&s| free_at[s] <= def) {
            Some(s) => s,
            None => {
                slot_sizes.push(0);
                free_at.push(0);
                slot_sizes.len() - 1
            }
        };
        slot_sizes[slot] = slot_sizes[slot].max(len);
        free_at[slot] = last_use + 1;
        assignment.push(slot);
    }
    (assignment, slot_sizes)
}

/// One step of the model-level inference chain: a module applied to the
/// running activation plus the named parameter tensors (indices into the
/// session's canonical parameter vector).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferCall {
    pub module: String,
    pub params: Vec<usize>,
}

/// Where an instruction operand lives at run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Loc {
    /// The program input (the image batch).
    Image,
    /// A parameter tensor (index into the params slice).
    Param(usize),
    /// An arena slot (f32 offset + length).
    Slot { off: usize, len: usize },
}

/// One fused-program instruction: execute `plan` over `args`, write the
/// single output into the arena at `out_off`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct InferInstr {
    plan: usize,
    args: Vec<Loc>,
    out_off: usize,
    out_len: usize,
}

/// The whole inference forward as one flat program: shape-specialized
/// fused kernels dispatched from an instruction list, intermediate
/// activations in a liveness-planned arena recycled through a pool.
///
/// Built once per [`crate::coordinator::ExecutionCore`] when the
/// registry runs the compiled backend; bit-identical to the sequential
/// per-module path (same plans, same order).
pub struct InferProgram {
    plans: Vec<Arc<ModulePlan>>,
    instrs: Vec<InferInstr>,
    arena_len: usize,
    slot_count: usize,
    out_off: usize,
    out_len: usize,
    out_shape: Vec<usize>,
    pool: Mutex<Vec<Vec<f32>>>,
    stats: Arc<CompileStats>,
}

impl InferProgram {
    /// Compile the chain against a compiled-backend registry, running
    /// **cross-module shape inference**: each step's declared input
    /// shapes must match what the previous step produces and what the
    /// parameter layout supplies — a mismatched manifest fails here,
    /// once, with a typed error naming the module and tensor.
    pub fn build(
        reg: &ArtifactRegistry,
        chain: &[InferCall],
        param_shapes: &[Vec<usize>],
    ) -> Result<InferProgram> {
        let Some(set) = reg.compiled_set() else {
            return Err(CompileError::Unsupported {
                module: "<infer>".into(),
                reason: "registry does not run the compiled backend".into(),
            });
        };
        if chain.is_empty() {
            return Err(CompileError::Unsupported {
                module: "<infer>".into(),
                reason: "empty inference chain".into(),
            });
        }

        let mut plans: Vec<Arc<ModulePlan>> = Vec::with_capacity(chain.len());
        let mut out_shapes: Vec<Vec<usize>> = Vec::with_capacity(chain.len());
        let mut activation: Option<Vec<usize>> = None;
        for call in chain {
            let spec = reg
                .module_spec(&call.module)
                .map_err(|_| CompileError::MissingModule { module: call.module.clone() })?;
            if spec.inputs.len() != 1 + call.params.len() {
                return Err(CompileError::ArityMismatch {
                    module: call.module.clone(),
                    expected: spec.inputs.len(),
                    found: 1 + call.params.len(),
                });
            }
            if let Some(prev) = &activation {
                if &spec.inputs[0].shape != prev {
                    return Err(CompileError::ShapeMismatch {
                        module: call.module.clone(),
                        input: spec.inputs[0].name.clone(),
                        expected: spec.inputs[0].shape.clone(),
                        found: prev.clone(),
                    });
                }
            }
            for (j, &p) in call.params.iter().enumerate() {
                let declared = &spec.inputs[1 + j];
                let supplied = param_shapes.get(p).ok_or_else(|| CompileError::Unsupported {
                    module: call.module.clone(),
                    reason: format!("chain references parameter {p} outside the layout"),
                })?;
                if &declared.shape != supplied {
                    return Err(CompileError::ShapeMismatch {
                        module: call.module.clone(),
                        input: declared.name.clone(),
                        expected: declared.shape.clone(),
                        found: supplied.clone(),
                    });
                }
            }
            if spec.outputs.len() != 1 {
                return Err(CompileError::Unsupported {
                    module: call.module.clone(),
                    reason: format!(
                        "inference chain needs single-output modules, found {}",
                        spec.outputs.len()
                    ),
                });
            }
            let plan = set.plan(&call.module).ok_or_else(|| CompileError::MissingModule {
                module: call.module.clone(),
            })?;
            plans.push(plan.clone());
            activation = Some(spec.outputs[0].shape.clone());
            out_shapes.push(spec.outputs[0].shape.clone());
        }
        let out_shape = activation.expect("non-empty chain has a final activation");

        // Liveness: value k (instr k's output) is read by instr k+1; the
        // final value is read by the output copy "instruction" at n.
        let n = chain.len();
        let intervals: Vec<(usize, usize, usize)> = out_shapes
            .iter()
            .enumerate()
            .map(|(k, shape)| (k, (k + 1).min(n), element_count(shape)))
            .collect();
        let (slots, slot_sizes) = assign_slots(&intervals);
        let mut offsets = Vec::with_capacity(slot_sizes.len());
        let mut total = 0usize;
        for &size in &slot_sizes {
            offsets.push(total);
            total += size;
        }

        let loc_of = |k: usize| Loc::Slot {
            off: offsets[slots[k]],
            len: element_count(&out_shapes[k]),
        };
        let instrs: Vec<InferInstr> = chain
            .iter()
            .enumerate()
            .map(|(k, call)| {
                let mut args = Vec::with_capacity(1 + call.params.len());
                args.push(if k == 0 { Loc::Image } else { loc_of(k - 1) });
                args.extend(call.params.iter().map(|&p| Loc::Param(p)));
                let Loc::Slot { off, len } = loc_of(k) else { unreachable!() };
                InferInstr { plan: k, args, out_off: off, out_len: len }
            })
            .collect();

        let (out_off, out_len) = (instrs[n - 1].out_off, instrs[n - 1].out_len);
        let stats = set.stats().clone();
        stats
            .arena_bytes
            .fetch_add((total * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
        Ok(InferProgram {
            plans,
            instrs,
            arena_len: total,
            slot_count: slot_sizes.len(),
            out_off,
            out_len,
            out_shape,
            pool: Mutex::new(Vec::new()),
            stats,
        })
    }

    /// Kernels dispatched per run (== chain length; used for
    /// call-accounting parity with the sequential path).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// A program always has at least one instruction.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Arena slots after liveness reuse (a linear chain ping-pongs two).
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Bytes of one arena buffer.
    pub fn arena_bytes(&self) -> usize {
        self.arena_len * std::mem::size_of::<f32>()
    }

    /// Shape of the program's output (the head input activation).
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Run the program: one pooled arena, zero steady-state allocations
    /// (the pool hands buffers back after the first run per concurrent
    /// caller), output bit-identical to the sequential module-call chain.
    pub fn run(&self, x: &Tensor, params: &[Tensor]) -> crate::runtime::Result<Tensor> {
        let mut arena = match self.pool.lock().expect("arena pool poisoned").pop() {
            Some(buf) => {
                self.stats.arena_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.stats.arena_allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; self.arena_len]
            }
        };

        for instr in &self.instrs {
            let plan = &self.plans[instr.plan];
            let mut h = plan.seed;
            for step in &plan.steps {
                match *step {
                    AbsorbStep::Len(l) => h = mix(h, l),
                    AbsorbStep::Data(i) => {
                        let part: &[f32] = match instr.args[i] {
                            Loc::Image => x.data(),
                            Loc::Param(p) => params[p].data(),
                            Loc::Slot { off, len } => &arena[off..off + len],
                        };
                        for &v in part {
                            h = mix(h, u64::from(v.to_bits()));
                        }
                    }
                }
            }
            plan.fill_into(h, 0, &mut arena[instr.out_off..instr.out_off + instr.out_len]);
        }

        let out = Tensor::from_vec(
            self.out_shape.clone(),
            arena[self.out_off..self.out_off + self.out_len].to_vec(),
        )
        .map_err(|e| RuntimeError::Shape(format!("compiled infer output: {e}")));
        self.pool.lock().expect("arena pool poisoned").push(arena);
        out
    }
}

// The program is shared across worker threads via the execution core.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<InferProgram>();
};
