//! ANODE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train       train one (arch, solver, method) config on synthetic CIFAR
//!   figures     regenerate a paper figure/table (fig1|fig7|sec3|fig3|fig4|
//!               fig5|memory|gradcheck)
//!   memory      print the §V memory-footprint table
//!   gradcheck   DTO vs OTD vs [8] gradient-consistency sweep (§IV)
//!   modules     list AOT modules in the artifact manifest
//!   serve       single-request serving demo: deadline-batched admission
//!               queue on the persistent worker pool, p50/p95/p99 report;
//!               with --listen, serves the `anode::net` wire protocol on
//!               a TCP socket (plus GET /metrics) and drives it with
//!               loopback protocol clients
//!   rollout     continuous-training demo: train in canary windows while
//!               the serve pipeline keeps running, shadow-evaluate each
//!               candidate snapshot, promote behind the quality gate or
//!               roll back to last-good on regression
//!
//! Examples:
//!   anode train --arch sqnxt --solver euler --method anode --steps 200
//!   anode figures --fig fig1
//!   anode gradcheck --artifacts artifacts
//!   anode serve --requests 512 --max-delay-ms 5 --workers 4 --queue-cap 256
//!   anode serve --listen 127.0.0.1:0 --slo mixed --adaptive-delay 1:20
//!   anode rollout --rounds 3 --canary-every 2 --gate-threshold 0.25 --devices 2
//!
//! All heavy lifting goes through the `anode::api` façade (Engine/Session);
//! see `rust/DESIGN.md` §6.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anode::api::{open_artifacts, Engine, SessionConfig};
use anode::data::{SyntheticCifar, CIFAR_HW};
use anode::harness;
use anode::metrics::{format_table, write_csv};
use anode::models::{Arch, GradMethod, Solver};
use anode::net::{ClientReply, NetClient, NetConfig, NetServer};
use anode::rollout::RolloutConfig;
use anode::runtime::{backend_env, ArtifactRegistry, Backend};
use anode::serve::{BatchRunner, HostTailRunner, ServeConfig, ServeHandle, SloClass};
use anode::tensor::Tensor;
use anode::util::bench::LatencyPercentiles;
use anode::util::cli::Args;
use anode::util::pool::parallel_map;

fn main() {
    let args = Args::from_env();
    // --artifacts and --backend are honored by every subcommand
    // (open_registry / the engine builder), so they must never trip the
    // unknown-option warning. --csv is deliberately NOT pre-marked:
    // commands that don't write a CSV should warn rather than silently
    // swallow it.
    let _ = args.get("artifacts");
    let _ = args.get("backend");
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => cmd_train(&args),
        "figures" => cmd_figures(&args),
        "memory" => cmd_memory(&args),
        "gradcheck" => cmd_gradcheck(&args),
        "modules" => cmd_modules(&args),
        "serve" => cmd_serve(&args),
        "rollout" => cmd_rollout(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "anode — ANODE (IJCAI'19) reproduction\n\
         usage: anode <train|figures|memory|gradcheck|modules> [--options]\n\
         \n\
         train:     --arch resnet|sqnxt  --solver euler|rk2|rk45\n\
         \u{20}          --method anode|node|otd|anode-revolve<m>|anode-equispaced<m>\n\
         \u{20}          |symplectic|interp-adjoint<p>\n\
         \u{20}          --classes 10|100 --steps N --lr F --train-size N --seed N\n\
         \u{20}          --workers N (parallel evaluation sweeps; default 1)\n\
         \u{20}          --grad-accum K (micro-batches per optimizer step)\n\
         \u{20}          --grad-workers N (data-parallel gradient workers;\n\
         \u{20}          bit-identical results for every N)\n\
         \u{20}          --devices N (shard parallel paths over N devices, one\n\
         \u{20}          registry+pool per device; bit-identical for every N)\n\
         figures:   --fig fig1|fig7|sec3|fig3|fig4|fig5|memory|gradcheck [--fast]\n\
         gradcheck: --seed N\n\
         serve:     --requests N --clients N --max-delay-ms MS --workers N\n\
         \u{20}          --devices N (one worker pool per device, batches routed\n\
         \u{20}          by load)\n\
         \u{20}          --queue-cap N --method M (falls back to a host-side demo\n\
         \u{20}          model when artifacts/ is absent)\n\
         \u{20}          --batch-delay-ms MS (flush window for the batch SLO class)\n\
         \u{20}          --adaptive-delay FLOOR:CEIL (adaptive interactive window,\n\
         \u{20}          ms; arrival rate retargets it inside the range)\n\
         \u{20}          --slo interactive|batch|mixed (SLO class of the driven\n\
         \u{20}          requests; mixed = every 4th request is batch-class)\n\
         \u{20}          --listen ADDR (serve the anode::net wire protocol on\n\
         \u{20}          ADDR, e.g. 127.0.0.1:0; requests go over loopback TCP\n\
         \u{20}          and GET /metrics on the same port answers plain text)\n\
         rollout:   --rounds N (candidate rounds; default 3)\n\
         \u{20}          --canary-every N (training steps per candidate snapshot)\n\
         \u{20}          --gate-threshold F (relative held-out loss tolerance;\n\
         \u{20}          negative demands strict improvement)\n\
         \u{20}          --hysteresis N (consecutive passes before a promotion)\n\
         \u{20}          --devices N --workers N --method M (the serve pipeline\n\
         \u{20}          keeps running while candidates train and hot-swap in)\n\
         common:    --artifacts DIR (default: artifacts)\n\
         \u{20}          --backend xla|sim|compiled (execution backend; default\n\
         \u{20}          xla, or the ANODE_BACKEND env var. `compiled` lowers the\n\
         \u{20}          manifest to fused kernel plans ahead of time — values\n\
         \u{20}          bit-identical to `sim`)\n\
         \u{20}          --csv PATH (train and fig3|fig4|fig5 only)\n\
         \n\
         Malformed option values are hard errors; unknown options warn.\n\
         \n\
         library quickstart (the same façade this CLI uses):\n\
         \u{20}   use anode::api::{{Engine, SessionConfig}};\n\
         \u{20}   let engine = Engine::builder().artifacts(\"artifacts\").build()?;\n\
         \u{20}   let mut s = engine.session(SessionConfig::with_method(\"anode\"))?;\n\
         \u{20}   s.step(&images, &labels)?;   // train\n\
         \u{20}   s.evaluate(&eval_batches)?;  // measure\n\
         \u{20}   s.predict(&images)?;         // serve"
    );
}

/// Parse a named enum option or exit with a clear message.
fn parse_opt<T>(kind: &str, value: &str, parse: impl Fn(&str) -> Option<T>) -> T {
    match parse(value) {
        Some(v) => v,
        None => {
            eprintln!("error: invalid value `{value}` for --{kind}");
            std::process::exit(2);
        }
    }
}

/// Execution backend requested on the command line (`--backend`), falling
/// back to `ANODE_BACKEND`. A malformed flag value is a hard error, like
/// every other malformed option.
fn cli_backend(args: &Args) -> Backend {
    match args.get("backend") {
        Some(v) => parse_opt("backend", v, Backend::parse),
        None => backend_env().unwrap_or_default(),
    }
}

fn open_registry(args: &Args) -> Result<Arc<ArtifactRegistry>, i32> {
    let dir = args.get_or("artifacts", "artifacts");
    match cli_backend(args) {
        // The shared-registry helper keeps its PJRT default.
        Backend::Xla => open_artifacts(&dir).map_err(|e| {
            eprintln!("error: {e}");
            2
        }),
        backend => ArtifactRegistry::open_with_backend(std::path::Path::new(&dir), 0, backend)
            .map(Arc::new)
            .map_err(|e| {
                eprintln!("error: {e}");
                2
            }),
    }
}

fn cmd_train(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    let opts = harness::TrainFigOptions {
        arch: parse_opt("arch", &args.get_or("arch", "resnet"), Arch::parse),
        solver: parse_opt("solver", &args.get_or("solver", "euler"), Solver::parse),
        method: parse_opt("method", &args.get_or("method", "anode"), GradMethod::parse),
        num_classes: args.get_parse_or("classes", 10),
        train_size: args.get_parse_or("train-size", 2048),
        test_size: args.get_parse_or("test-size", 512),
        steps: args.get_parse_or("steps", 200),
        eval_every: args.get_parse_or("eval-every", 25),
        lr: args.get_parse_or("lr", 0.02),
        seed: args.get_parse_or("seed", 0),
        verbose: true,
        workers: args.get_parse_or("workers", 1),
        grad_accum: args.get_parse_or("grad-accum", 1),
        grad_workers: args.get_parse_or("grad-workers", 1),
        devices: args.get_parse_or("devices", 1),
    };
    let csv = args.get("csv").map(|s| s.to_string());
    args.warn_unknown();
    match harness::train_figure(&reg, &opts) {
        Ok(run) => {
            println!("{}", format_table(std::slice::from_ref(&run.curve)));
            println!(
                "run: diverged={} wall={:.1}s sec/step={:.3} peak_act={}",
                run.diverged,
                run.wall_seconds,
                run.sec_per_step,
                anode::memory::human_bytes(run.peak_activation_bytes)
            );
            if let Some(csv) = csv {
                write_csv(std::path::Path::new(&csv), &[run.curve]).expect("csv write");
            }
            0
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            1
        }
    }
}

fn cmd_figures(args: &Args) -> i32 {
    let fig = args.get_or("fig", "fig1");
    let fast = args.has_flag("fast");
    match fig.as_str() {
        "fig1" | "fig7" => {
            let rows = harness::fig1_reversibility(
                args.get_parse_or("seed", 3),
                args.get_parse_or("kernel-std", 3.0),
                args.get_parse_or("nt", 8),
            );
            args.warn_unknown();
            println!("Fig. 1/7 — reversibility of a random-Gaussian conv residual block");
            println!("{}", harness::format_fig1(&rows));
            0
        }
        "sec3" => {
            let rows = harness::sec3_scalar_studies(args.get_parse_or("seed", 0));
            args.warn_unknown();
            println!("§III — scalar/matrix reversibility studies");
            println!("{}", harness::format_sec3(&rows));
            0
        }
        "memory" => cmd_memory(args),
        "gradcheck" => cmd_gradcheck(args),
        "fig3" | "fig4" | "fig5" => {
            let reg = match open_registry(args) {
                Ok(r) => r,
                Err(c) => return c,
            };
            let (arch, classes, solvers): (Arch, usize, Vec<Solver>) = match fig.as_str() {
                "fig3" => (Arch::Sqnxt, 10, vec![Solver::Euler, Solver::Rk2]),
                "fig4" => (Arch::Resnet, 10, vec![Solver::Euler]),
                _ => (Arch::Resnet, 100, vec![Solver::Euler]),
            };
            let steps = args.get_parse_or("steps", if fast { 60 } else { 200 });
            let mut curves = Vec::new();
            for solver in solvers {
                for method in [GradMethod::Anode, GradMethod::Node] {
                    let o = harness::TrainFigOptions {
                        arch,
                        solver,
                        method,
                        num_classes: classes,
                        steps,
                        eval_every: args.get_parse_or("eval-every", steps.div_ceil(8)),
                        train_size: args.get_parse_or("train-size", if fast { 512 } else { 2048 }),
                        test_size: args.get_parse_or("test-size", if fast { 128 } else { 512 }),
                        seed: args.get_parse_or("seed", 0),
                        lr: args.get_parse_or("lr", 0.02),
                        verbose: true,
                        workers: args.get_parse_or("workers", 1),
                        grad_accum: args.get_parse_or("grad-accum", 1),
                        grad_workers: args.get_parse_or("grad-workers", 1),
                        devices: args.get_parse_or("devices", 1),
                    };
                    match harness::train_figure(&reg, &o) {
                        Ok(run) => curves.push(run.curve),
                        Err(e) => eprintln!("series failed: {e}"),
                    }
                }
            }
            // The paper's footnote: [8] with RK45 diverges in the first epoch.
            let o = harness::TrainFigOptions {
                arch,
                solver: Solver::Rk45,
                method: GradMethod::Node,
                num_classes: classes,
                steps: steps.min(60),
                eval_every: args.get_parse_or("eval-every", 10),
                train_size: if fast { 512 } else { 1024 },
                test_size: 128,
                seed: args.get_parse_or("seed", 0),
                lr: args.get_parse_or("lr", 0.02),
                verbose: true,
                workers: args.get_parse_or("workers", 1),
                grad_accum: args.get_parse_or("grad-accum", 1),
                grad_workers: args.get_parse_or("grad-workers", 1),
                devices: args.get_parse_or("devices", 1),
            };
            let csv = args.get("csv").map(|s| s.to_string());
            args.warn_unknown();
            match harness::train_figure(&reg, &o) {
                Ok(run) => curves.push(run.curve),
                Err(e) => eprintln!("node-rk45 series failed: {e}"),
            }
            println!("{}", format_table(&curves));
            if let Some(csv) = csv {
                write_csv(std::path::Path::new(&csv), &curves).expect("csv write");
            }
            0
        }
        other => {
            eprintln!("unknown figure {other}");
            2
        }
    }
}

fn cmd_memory(args: &Args) -> i32 {
    let act = args.get_parse_or("act-bytes", 32 * 32 * 32 * 16 * 4usize);
    args.warn_unknown();
    let rows = harness::memory_table(
        &[2, 4, 6, 8, 16],
        &[2, 5, 8, 16, 32],
        &[2, 3, 4, 8],
        act,
    );
    println!("§V — activation-memory footprint (act = one stage-0 batch activation)");
    println!("{}", harness::format_memtable(&rows));
    0
}

fn cmd_gradcheck(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    let seed = args.get_parse_or("seed", 5);
    args.warn_unknown();
    match harness::gradient_consistency(&reg, seed) {
        Ok(rows) => {
            println!("§IV — gradient consistency (tiny block, Euler, dt sweep)");
            println!("{}", harness::format_gradcheck(&rows));
            0
        }
        Err(e) => {
            eprintln!("gradcheck failed: {e}");
            1
        }
    }
}

/// Single-request serving demo: start the `anode::serve` pipeline, fire
/// `--requests` synthetic examples from `--clients` threads, and report
/// per-request latency percentiles plus flush/memory accounting. Uses the
/// engine when artifacts are present, the host-side demo model otherwise
/// (so the serving path is demonstrable on the offline stub).
fn cmd_serve(args: &Args) -> i32 {
    let requests: usize = args.get_parse_or("requests", 256);
    let clients: usize = args.get_parse_or("clients", 4usize).max(1);
    let devices: usize = args.get_parse_or("devices", 1usize).max(1);
    let mut serve_cfg = ServeConfig::default()
        .max_delay_ms(args.get_parse_or("max-delay-ms", 5u64))
        .batch_delay_ms(args.get_parse_or("batch-delay-ms", 40u64))
        .workers(args.get_parse_or("workers", 2))
        .queue_cap(args.get_parse_or("queue-cap", 256));
    if let Some(spec) = args.get("adaptive-delay") {
        match parse_adaptive(spec) {
            Some((floor, ceil)) => serve_cfg = serve_cfg.adaptive_delay_ms(floor, ceil),
            None => {
                eprintln!(
                    "error: invalid value `{spec}` for --adaptive-delay \
                     (expected FLOOR_MS:CEIL_MS, e.g. 1:20)"
                );
                return 2;
            }
        }
    }
    let slo = parse_opt("slo", &args.get_or("slo", "interactive"), SloPattern::parse);
    let listen = args.get("listen").map(|s| s.to_string());
    let method = args.get_or("method", "anode");
    let dir = args.get_or("artifacts", "artifacts");
    args.warn_unknown();
    println!(
        "serve: {} requests, {} clients, max_delay={:?} (adaptive={}), batch_delay={:?}, \
         workers={}/device x {} devices, queue_cap={}",
        requests,
        clients,
        serve_cfg.max_delay,
        serve_cfg.adaptive_delay.is_some(),
        serve_cfg.batch_delay,
        serve_cfg.workers,
        devices,
        serve_cfg.queue_cap
    );
    match Engine::builder().artifacts(&dir).devices(devices).backend(cli_backend(args)).build() {
        Ok(engine) => {
            let session = match engine.session(SessionConfig::with_method(method.as_str())) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let handle = match session.serve(serve_cfg) {
                Ok(h) => h,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let cfg = engine.config().clone();
            if cfg.image != CIFAR_HW {
                eprintln!(
                    "error: artifact image size {} is unsupported by the synthetic CIFAR \
                     request generator (renders {CIFAR_HW}x{CIFAR_HW})",
                    cfg.image
                );
                return 2;
            }
            println!(
                "model: engine-backed `{method}` ({0}x{0} images, batch {1})",
                cfg.image, cfg.batch
            );
            let ds = SyntheticCifar::new(cfg.num_classes, 3, 0.1);
            let make = move |i: usize| {
                let (imgs, _) = ds.generate(1, i as u64);
                imgs.reshape(vec![cfg.image, cfg.image, 3]).expect("example reshape")
            };
            drive(handle, listen.as_deref(), requests, clients, slo, &make)
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); serving the synthetic host-tail demo model");
            // One demo runner per simulated device: the same deadline
            // queue feeds `devices` pools through the load-aware router.
            let runners: Vec<Arc<dyn BatchRunner>> = (0..devices)
                .map(|_| Arc::new(HostTailRunner::new(32, 16, 64, 10)) as Arc<dyn BatchRunner>)
                .collect();
            let shape = runners[0].example_shape();
            let handle = match ServeHandle::spawn_sharded(runners, serve_cfg) {
                Ok(h) => h,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let make = move |i: usize| Tensor::full(&shape, 0.01 * (i % 97) as f32);
            drive(handle, listen.as_deref(), requests, clients, slo, &make)
        }
    }
}

/// Continuous-training demo: start the serve pipeline, then run the
/// `anode::rollout` orchestrator against it — train in canary windows,
/// shadow-evaluate each snapshot on a held-out split, promote behind the
/// quality gate (or roll back on regression) while the pipeline keeps
/// serving. Reports the campaign outcome plus the pipeline's rollout
/// counters and swap-window p99.
fn cmd_rollout(args: &Args) -> i32 {
    let devices: usize = args.get_parse_or("devices", 1usize).max(1);
    let serve_cfg = ServeConfig::default()
        .max_delay_ms(args.get_parse_or("max-delay-ms", 5u64))
        .workers(args.get_parse_or("workers", 2))
        .queue_cap(args.get_parse_or("queue-cap", 256));
    let rollout_cfg = RolloutConfig::default()
        .rounds(args.get_parse_or("rounds", 3))
        .canary_every(args.get_parse_or("canary-every", 2))
        .gate_threshold(args.get_parse_or("gate-threshold", 0.25f32))
        .hysteresis(args.get_parse_or("hysteresis", 1));
    let method = args.get_or("method", "anode");
    let dir = args.get_or("artifacts", "artifacts");
    args.warn_unknown();
    let engine =
        match Engine::builder().artifacts(&dir).devices(devices).backend(cli_backend(args)).build()
        {
            Ok(e) => e,
            Err(e) => {
                eprintln!("error: {e} (rollout trains a real session, so artifacts are required)");
                return 2;
            }
        };
    let cfg = engine.config().clone();
    if cfg.image != CIFAR_HW {
        eprintln!(
            "error: artifact image size {} is unsupported by the synthetic CIFAR \
             generator (renders {CIFAR_HW}x{CIFAR_HW})",
            cfg.image
        );
        return 2;
    }
    let mut session = match engine.session(SessionConfig::with_method(method.as_str())) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let handle = match session.serve(serve_cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    println!(
        "rollout: {} rounds x {} canary steps, gate threshold {:+.2} x{} hysteresis, \
         {} devices (`{method}`, batch {})",
        rollout_cfg.rounds,
        rollout_cfg.canary_every,
        rollout_cfg.gate_threshold,
        rollout_cfg.hysteresis,
        devices,
        cfg.batch
    );
    let ds = SyntheticCifar::new(cfg.num_classes, 7, 0.1);
    let (imgs, labels) = ds.generate(cfg.batch * 6, 11);
    let batches = anode::api::make_eval_batches(&imgs, &labels, cfg.batch, 6);
    let (train, eval) = batches.split_at(4);
    let outcome = session.rollout(&handle, train, eval, rollout_cfg);
    let stats = handle.stats();
    let report = match outcome {
        Ok(r) => r,
        Err(e) => {
            eprintln!("rollout failed: {e}");
            let _ = handle.shutdown();
            return 1;
        }
    };
    println!(
        "campaign: rounds={} candidates={} promotions={} rollbacks={} paused={} \
         baseline_loss={:.4} wall={:.3}s",
        report.rounds_run,
        report.candidates,
        report.promotions,
        report.rollbacks,
        report.paused,
        report.baseline_loss,
        report.wall.as_secs_f64()
    );
    if let Some(p) = report.promote_latency.last() {
        println!("snapshot->promoted latency (last): {:?}", p);
    }
    println!(
        "pipeline: candidates={} promotions={} rollbacks={} swap_p99_us={}",
        stats.rollout_candidates,
        stats.rollout_promotions,
        stats.rollout_rollbacks,
        stats.rollout_swap_p99_us
    );
    if handle.shutdown().is_err() {
        eprintln!("shutdown failed");
        return 1;
    }
    if report.rollbacks == 0 {
        0
    } else {
        1
    }
}

/// Parse `--adaptive-delay FLOOR:CEIL` (milliseconds).
fn parse_adaptive(spec: &str) -> Option<(u64, u64)> {
    let (floor, ceil) = spec.split_once(':')?;
    Some((floor.trim().parse().ok()?, ceil.trim().parse().ok()?))
}

/// Which SLO class the driver stamps on each generated request.
#[derive(Clone, Copy)]
enum SloPattern {
    Interactive,
    Batch,
    /// Every 4th request is batch-class — both deadline windows exercise.
    Mixed,
}

impl SloPattern {
    fn parse(s: &str) -> Option<SloPattern> {
        match s {
            "interactive" => Some(SloPattern::Interactive),
            "batch" => Some(SloPattern::Batch),
            "mixed" => Some(SloPattern::Mixed),
            _ => None,
        }
    }

    fn class_for(self, i: usize) -> SloClass {
        match self {
            SloPattern::Interactive => SloClass::Interactive,
            SloPattern::Batch => SloClass::Batch,
            SloPattern::Mixed => {
                if i % 4 == 3 {
                    SloClass::Batch
                } else {
                    SloClass::Interactive
                }
            }
        }
    }
}

/// Dispatch the client drive: loopback TCP through `anode::net` when
/// `--listen` was given, in-process submits otherwise.
fn drive<F>(
    handle: ServeHandle,
    listen: Option<&str>,
    requests: usize,
    clients: usize,
    slo: SloPattern,
    make: &F,
) -> i32
where
    F: Fn(usize) -> Tensor + Sync,
{
    match listen {
        Some(addr) => drive_serve_net(handle, addr, requests, clients, slo, make),
        None => drive_serve(&handle, requests, clients, slo, make),
    }
}

/// Pipelined client drive on the shared worker-pool helper
/// (`anode::util::pool` — the same substrate the serve workers run on):
/// each client runs on its own pool worker, submits its share of requests
/// (interleaved round-robin), then waits all replies; latencies are
/// aggregated across clients for the percentile report.
fn drive_serve<F>(
    handle: &ServeHandle,
    requests: usize,
    clients: usize,
    slo: SloPattern,
    make: &F,
) -> i32
where
    F: Fn(usize) -> Tensor + Sync,
{
    let t0 = Instant::now();
    let client_ids: Vec<usize> = (0..clients).collect();
    let per_client = parallel_map(&client_ids, clients, |_idx, &c| {
        let mut pendings = Vec::new();
        for i in (c..requests).step_by(clients) {
            match handle.submit_class(make(i), slo.class_for(i)) {
                Ok(pending) => pendings.push((i, pending)),
                Err(e) => eprintln!("submit {i} failed: {e}"),
            }
        }
        let mut latencies = Vec::with_capacity(pendings.len());
        for (i, pending) in pendings {
            match pending.wait() {
                Ok(reply) => latencies.push(reply.stats.total()),
                Err(e) => eprintln!("request {i} failed: {e}"),
            }
        }
        latencies
    });
    let mut latencies: Vec<Duration> = per_client.into_iter().flatten().collect();
    let wall = t0.elapsed().as_secs_f64();
    let report = match handle.shutdown() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("shutdown failed: {e}");
            return 1;
        }
    };
    let pct = LatencyPercentiles::from_unsorted(&mut latencies);
    println!(
        "served {}/{} requests in {:.3}s  ({:.0} req/s across {clients} clients)",
        latencies.len(),
        requests,
        wall,
        latencies.len() as f64 / wall.max(1e-12)
    );
    println!("latency {}", pct.report());
    println!(
        "batches={} (full={} deadline={} drain={})  workers={} devices={}",
        report.batches,
        report.full_flushes,
        report.deadline_flushes,
        report.drain_flushes,
        report.workers,
        report.devices
    );
    println!("memory: {}", report.memory.summary());
    if latencies.len() == requests {
        0
    } else {
        1
    }
}

/// Loopback wire drive: put the `anode::net` reactor on `addr`, connect
/// one protocol client per driver thread, and push every request through
/// TCP — sheds retry with the server's hint, end-to-end wire latency is
/// measured client-side, and the metrics endpoint is scraped before the
/// graceful drain.
fn drive_serve_net<F>(
    handle: ServeHandle,
    addr: &str,
    requests: usize,
    clients: usize,
    slo: SloPattern,
    make: &F,
) -> i32
where
    F: Fn(usize) -> Tensor + Sync,
{
    let server = match NetServer::bind(handle, addr, NetConfig::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let local = server.local_addr().to_string();
    println!("listening on {local} (binary frames; GET /metrics for text)");
    let t0 = Instant::now();
    let client_ids: Vec<usize> = (0..clients).collect();
    let per_client = parallel_map(&client_ids, clients, |_idx, &c| {
        let mut latencies = Vec::new();
        let mut gave_up = 0usize;
        let mut client = match NetClient::connect(&local) {
            Ok(cl) => cl,
            Err(e) => {
                eprintln!("client {c}: connect failed: {e}");
                return (latencies, gave_up);
            }
        };
        for i in (c..requests).step_by(clients) {
            let image = make(i);
            let t = Instant::now();
            match client.request_with_retry(&image, slo.class_for(i), 16) {
                Ok(ClientReply::Reply { .. }) => latencies.push(t.elapsed()),
                Ok(ClientReply::RetryAfter(_)) => gave_up += 1,
                Err(e) => eprintln!("request {i} failed: {e}"),
            }
        }
        (latencies, gave_up)
    });
    let mut latencies = Vec::new();
    let mut gave_up = 0usize;
    for (lats, g) in per_client {
        latencies.extend(lats);
        gave_up += g;
    }
    let wall = t0.elapsed().as_secs_f64();
    let window = server.handle().stats().current_max_delay;
    match NetClient::connect(&local).and_then(|mut c| c.metrics()) {
        Ok(text) => println!(
            "metrics scrape: {} lines, anode_shed_total={}",
            text.lines().count(),
            anode::net::metrics::scrape_value(&text, "shed_total").unwrap_or(0)
        ),
        Err(e) => eprintln!("metrics scrape failed: {e}"),
    }
    if server.drain_requested() {
        // A client sent the Drain admin frame (the std-only SIGTERM
        // stand-in): note it before the graceful shutdown below, which
        // drains sockets first and drops no accepted request either way.
        println!("drain requested over the wire; shutting down");
    }
    let report = match server.shutdown() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("shutdown failed: {e}");
            return 1;
        }
    };
    let pct = LatencyPercentiles::from_unsorted(&mut latencies);
    println!(
        "served {}/{} requests over the wire in {:.3}s  ({:.0} req/s across {clients} \
         connections; {gave_up} gave up after shed retries)",
        latencies.len(),
        requests,
        wall,
        latencies.len() as f64 / wall.max(1e-12)
    );
    println!("wire latency {}  (final interactive window {:?})", pct.report(), window);
    println!(
        "net: conns={} frames_in={} replies={} shed={} errors={} metrics_scrapes={}",
        report.net.connections,
        report.net.frames_in,
        report.net.replies,
        report.net.shed,
        report.net.errors,
        report.net.metrics_requests
    );
    println!(
        "batches={} (full={} deadline={} drain={})  workers={} devices={}",
        report.serve.batches,
        report.serve.full_flushes,
        report.serve.deadline_flushes,
        report.serve.drain_flushes,
        report.serve.workers,
        report.serve.devices
    );
    println!("memory: {}", report.serve.memory.summary());
    if latencies.len() == requests {
        0
    } else {
        1
    }
}

fn cmd_modules(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    args.warn_unknown();
    for name in reg.module_names() {
        println!("{name}");
    }
    0
}
