//! ANODE CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train       train one (arch, solver, method) config on synthetic CIFAR
//!   figures     regenerate a paper figure/table (fig1|fig7|sec3|fig3|fig4|
//!               fig5|memory|gradcheck)
//!   memory      print the §V memory-footprint table
//!   gradcheck   DTO vs OTD vs [8] gradient-consistency sweep (§IV)
//!   modules     list AOT modules in the artifact manifest
//!
//! Examples:
//!   anode train --arch sqnxt --solver euler --method anode --steps 200
//!   anode figures --fig fig1
//!   anode gradcheck --artifacts artifacts
//!
//! All heavy lifting goes through the `anode::api` façade (Engine/Session);
//! see `rust/DESIGN.md` §6.

use std::sync::Arc;

use anode::api::open_artifacts;
use anode::harness;
use anode::metrics::{format_table, write_csv};
use anode::models::{Arch, GradMethod, Solver};
use anode::runtime::ArtifactRegistry;
use anode::util::cli::Args;

fn main() {
    let args = Args::from_env();
    // --artifacts is honored by every subcommand (open_registry), so it
    // must never trip the unknown-option warning. --csv is deliberately
    // NOT pre-marked: commands that don't write a CSV should warn rather
    // than silently swallow it.
    let _ = args.get("artifacts");
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => cmd_train(&args),
        "figures" => cmd_figures(&args),
        "memory" => cmd_memory(&args),
        "gradcheck" => cmd_gradcheck(&args),
        "modules" => cmd_modules(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "anode — ANODE (IJCAI'19) reproduction\n\
         usage: anode <train|figures|memory|gradcheck|modules> [--options]\n\
         \n\
         train:     --arch resnet|sqnxt  --solver euler|rk2|rk45\n\
         \u{20}          --method anode|node|otd|anode-revolve<m>|anode-equispaced<m>\n\
         \u{20}          --classes 10|100 --steps N --lr F --train-size N --seed N\n\
         \u{20}          --workers N (parallel evaluation sweeps; default 1)\n\
         figures:   --fig fig1|fig7|sec3|fig3|fig4|fig5|memory|gradcheck [--fast]\n\
         gradcheck: --seed N\n\
         common:    --artifacts DIR (default: artifacts)\n\
         \u{20}          --csv PATH (train and fig3|fig4|fig5 only)\n\
         \n\
         Malformed option values are hard errors; unknown options warn.\n\
         \n\
         library quickstart (the same façade this CLI uses):\n\
         \u{20}   use anode::api::{{Engine, SessionConfig}};\n\
         \u{20}   let engine = Engine::builder().artifacts(\"artifacts\").build()?;\n\
         \u{20}   let mut s = engine.session(SessionConfig::with_method(\"anode\"))?;\n\
         \u{20}   s.step(&images, &labels)?;   // train\n\
         \u{20}   s.evaluate(&eval_batches)?;  // measure\n\
         \u{20}   s.predict(&images)?;         // serve"
    );
}

/// Parse a named enum option or exit with a clear message.
fn parse_opt<T>(kind: &str, value: &str, parse: impl Fn(&str) -> Option<T>) -> T {
    match parse(value) {
        Some(v) => v,
        None => {
            eprintln!("error: invalid value `{value}` for --{kind}");
            std::process::exit(2);
        }
    }
}

fn open_registry(args: &Args) -> Result<Arc<ArtifactRegistry>, i32> {
    let dir = args.get_or("artifacts", "artifacts");
    open_artifacts(&dir).map_err(|e| {
        eprintln!("error: {e}");
        2
    })
}

fn cmd_train(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    let opts = harness::TrainFigOptions {
        arch: parse_opt("arch", &args.get_or("arch", "resnet"), Arch::parse),
        solver: parse_opt("solver", &args.get_or("solver", "euler"), Solver::parse),
        method: parse_opt("method", &args.get_or("method", "anode"), GradMethod::parse),
        num_classes: args.get_parse_or("classes", 10),
        train_size: args.get_parse_or("train-size", 2048),
        test_size: args.get_parse_or("test-size", 512),
        steps: args.get_parse_or("steps", 200),
        eval_every: args.get_parse_or("eval-every", 25),
        lr: args.get_parse_or("lr", 0.02),
        seed: args.get_parse_or("seed", 0),
        verbose: true,
        workers: args.get_parse_or("workers", 1),
    };
    let csv = args.get("csv").map(|s| s.to_string());
    args.warn_unknown();
    match harness::train_figure(&reg, &opts) {
        Ok(run) => {
            println!("{}", format_table(std::slice::from_ref(&run.curve)));
            println!(
                "run: diverged={} wall={:.1}s sec/step={:.3} peak_act={}",
                run.diverged,
                run.wall_seconds,
                run.sec_per_step,
                anode::memory::human_bytes(run.peak_activation_bytes)
            );
            if let Some(csv) = csv {
                write_csv(std::path::Path::new(&csv), &[run.curve]).expect("csv write");
            }
            0
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            1
        }
    }
}

fn cmd_figures(args: &Args) -> i32 {
    let fig = args.get_or("fig", "fig1");
    let fast = args.has_flag("fast");
    match fig.as_str() {
        "fig1" | "fig7" => {
            let rows = harness::fig1_reversibility(
                args.get_parse_or("seed", 3),
                args.get_parse_or("kernel-std", 3.0),
                args.get_parse_or("nt", 8),
            );
            args.warn_unknown();
            println!("Fig. 1/7 — reversibility of a random-Gaussian conv residual block");
            println!("{}", harness::format_fig1(&rows));
            0
        }
        "sec3" => {
            let rows = harness::sec3_scalar_studies(args.get_parse_or("seed", 0));
            args.warn_unknown();
            println!("§III — scalar/matrix reversibility studies");
            println!("{}", harness::format_sec3(&rows));
            0
        }
        "memory" => cmd_memory(args),
        "gradcheck" => cmd_gradcheck(args),
        "fig3" | "fig4" | "fig5" => {
            let reg = match open_registry(args) {
                Ok(r) => r,
                Err(c) => return c,
            };
            let (arch, classes, solvers): (Arch, usize, Vec<Solver>) = match fig.as_str() {
                "fig3" => (Arch::Sqnxt, 10, vec![Solver::Euler, Solver::Rk2]),
                "fig4" => (Arch::Resnet, 10, vec![Solver::Euler]),
                _ => (Arch::Resnet, 100, vec![Solver::Euler]),
            };
            let steps = args.get_parse_or("steps", if fast { 60 } else { 200 });
            let mut curves = Vec::new();
            for solver in solvers {
                for method in [GradMethod::Anode, GradMethod::Node] {
                    let o = harness::TrainFigOptions {
                        arch,
                        solver,
                        method,
                        num_classes: classes,
                        steps,
                        eval_every: args.get_parse_or("eval-every", steps.div_ceil(8)),
                        train_size: args.get_parse_or("train-size", if fast { 512 } else { 2048 }),
                        test_size: args.get_parse_or("test-size", if fast { 128 } else { 512 }),
                        seed: args.get_parse_or("seed", 0),
                        lr: args.get_parse_or("lr", 0.02),
                        verbose: true,
                        workers: args.get_parse_or("workers", 1),
                    };
                    match harness::train_figure(&reg, &o) {
                        Ok(run) => curves.push(run.curve),
                        Err(e) => eprintln!("series failed: {e}"),
                    }
                }
            }
            // The paper's footnote: [8] with RK45 diverges in the first epoch.
            let o = harness::TrainFigOptions {
                arch,
                solver: Solver::Rk45,
                method: GradMethod::Node,
                num_classes: classes,
                steps: steps.min(60),
                eval_every: args.get_parse_or("eval-every", 10),
                train_size: if fast { 512 } else { 1024 },
                test_size: 128,
                seed: args.get_parse_or("seed", 0),
                lr: args.get_parse_or("lr", 0.02),
                verbose: true,
                workers: args.get_parse_or("workers", 1),
            };
            let csv = args.get("csv").map(|s| s.to_string());
            args.warn_unknown();
            match harness::train_figure(&reg, &o) {
                Ok(run) => curves.push(run.curve),
                Err(e) => eprintln!("node-rk45 series failed: {e}"),
            }
            println!("{}", format_table(&curves));
            if let Some(csv) = csv {
                write_csv(std::path::Path::new(&csv), &curves).expect("csv write");
            }
            0
        }
        other => {
            eprintln!("unknown figure {other}");
            2
        }
    }
}

fn cmd_memory(args: &Args) -> i32 {
    let act = args.get_parse_or("act-bytes", 32 * 32 * 32 * 16 * 4usize);
    args.warn_unknown();
    let rows = harness::memory_table(
        &[2, 4, 6, 8, 16],
        &[2, 5, 8, 16, 32],
        &[2, 3, 4, 8],
        act,
    );
    println!("§V — activation-memory footprint (act = one stage-0 batch activation)");
    println!("{}", harness::format_memtable(&rows));
    0
}

fn cmd_gradcheck(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    let seed = args.get_parse_or("seed", 5);
    args.warn_unknown();
    match harness::gradient_consistency(&reg, seed) {
        Ok(rows) => {
            println!("§IV — gradient consistency (tiny block, Euler, dt sweep)");
            println!("{}", harness::format_gradcheck(&rows));
            0
        }
        Err(e) => {
            eprintln!("gradcheck failed: {e}");
            1
        }
    }
}

fn cmd_modules(args: &Args) -> i32 {
    let reg = match open_registry(args) {
        Ok(r) => r,
        Err(c) => return c,
    };
    args.warn_unknown();
    for name in reg.module_names() {
        println!("{name}");
    }
    0
}
