//! Backward-pass dispatch: the three gradient methods of the paper plus the
//! checkpointed variants, composed per-block in reverse network order.

use crate::checkpoint::{plan, run_backward, Strategy};
use crate::memory::{Category, MemoryLedger};
use crate::models::GradMethod;
use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::{Coordinator, ForwardState};

/// The block-module kind a method needs (used for fail-fast probing).
pub(crate) fn primary_kind(method: GradMethod) -> &'static str {
    match method {
        GradMethod::Anode => "vjp",
        GradMethod::AnodeRevolve(_) | GradMethod::AnodeEquispaced(_) => "step_vjp",
        GradMethod::Node => "node",
        GradMethod::Otd => "otd",
    }
}

/// Backpropagate `gz` (dL/d z_final) through transitions and ODE blocks,
/// accumulating parameter gradients into `grads` (canonical order).
pub(crate) fn backward(
    co: &Coordinator,
    state: &ForwardState,
    mut gz: Tensor,
    params: &[Tensor],
    grads: &mut [Tensor],
    ledger: &mut MemoryLedger,
) -> Result<()> {
    for s in (0..co.cfg.stages()).rev() {
        // Transition after stage s (if any) comes first in reverse order.
        if s + 1 < co.cfg.stages() {
            let (tw, tb) = co.index.trans[s];
            let tin = &state.trans_inputs[s];
            let outs =
                co.call(&format!("trans{s}_vjp"), &[tin, &params[tw], &params[tb], &gz])?;
            let mut it = outs.into_iter();
            gz = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
            grads[tw] = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
            grads[tb] = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
        }
        for b in (0..co.cfg.blocks_per_stage).rev() {
            gz = block_backward(co, state, s, b, gz, params, grads, ledger)?;
        }
    }

    // Stem VJP (input-image gradient not needed).
    let (sw, sb) = co.index.stem;
    let outs = co.call("stem_vjp", &[&state.x, &params[sw], &params[sb], &gz])?;
    let mut it = outs.into_iter();
    grads[sw] = it.next().ok_or_else(|| RuntimeError::Shape("stem_vjp arity".into()))?;
    grads[sb] = it.next().ok_or_else(|| RuntimeError::Shape("stem_vjp arity".into()))?;
    Ok(())
}

/// Backward through one ODE block; returns dL/d(block input).
#[allow(clippy::too_many_arguments)]
fn block_backward(
    co: &Coordinator,
    state: &ForwardState,
    s: usize,
    b: usize,
    gz: Tensor,
    params: &[Tensor],
    grads: &mut [Tensor],
    ledger: &mut MemoryLedger,
) -> Result<Tensor> {
    let z_in = &state.block_inputs[s][b];
    let z_out = &state.block_outputs[s][b];
    let pidx = &co.index.blocks[s][b];
    let theta: Vec<&Tensor> = pidx.iter().map(|&i| &params[i]).collect();

    match co.method {
        GradMethod::Anode | GradMethod::Otd => {
            // Fused DTO VJP (or OTD adjoint): the O(Nt) trajectory lives in
            // the executable's working set; ledger models it as StepState
            // held for the duration of the call.
            let kind = if co.method == GradMethod::Anode { "vjp" } else { "otd" };
            let nt_cost = co.cfg.nt * z_in.byte_size();
            let tid = ledger.alloc(nt_cost, Category::StepState);
            let name = co.cfg.block_module(s, co.solver, kind);
            let mut args: Vec<&Tensor> = vec![z_in];
            args.extend(theta.iter().copied());
            args.push(&gz);
            let outs = co.call(&name, &args)?;
            ledger.free(tid);
            distribute(outs, pidx, grads)
        }
        GradMethod::Node => {
            // [8]: start from the block OUTPUT, reconstruct backwards.
            // No trajectory storage at all (that is its selling point — and
            // its failure mode, §III).
            let name = co.cfg.block_module(s, co.solver, "node");
            let mut args: Vec<&Tensor> = vec![z_out];
            args.extend(theta.iter().copied());
            args.push(&gz);
            let mut outs = co.call(&name, &args)?;
            // Last output is z0_rec (reconstruction); expose its error for
            // diagnostics by storing nothing — callers can call
            // reconstruction_error() explicitly in analysis harnesses.
            outs.truncate(outs.len() - 1);
            distribute(outs, pidx, grads)
        }
        GradMethod::AnodeRevolve(m) | GradMethod::AnodeEquispaced(m) => {
            let strategy = match co.method {
                GradMethod::AnodeRevolve(m) => Strategy::Revolve(m),
                _ => Strategy::Equispaced(m),
            };
            step_backward(co, s, z_in, gz, &theta, pidx, grads, strategy, m, ledger)
        }
    }
}

/// Checkpointed backward over step-level artifacts: the revolve executor
/// drives `step_fwd` / `step_vjp`, accumulating parameter gradients.
#[allow(clippy::too_many_arguments)]
fn step_backward(
    co: &Coordinator,
    s: usize,
    z_in: &Tensor,
    gz: Tensor,
    theta: &[&Tensor],
    pidx: &[usize],
    grads: &mut [Tensor],
    strategy: Strategy,
    m: usize,
    ledger: &mut MemoryLedger,
) -> Result<Tensor> {
    let nt = co.cfg.nt;
    let schedule = plan(strategy, nt);
    let errs = schedule.validate();
    if !errs.is_empty() {
        return Err(RuntimeError::Io(format!("invalid schedule: {}", errs.join("; "))));
    }

    let fwd_name = co.cfg.block_module(s, co.solver, "step_fwd");
    let vjp_name = co.cfg.block_module(s, co.solver, "step_vjp");
    let mut theta_grads: Vec<Tensor> = pidx.iter().map(|&i| Tensor::zeros(grads[i].shape())).collect();
    let mut call_err: Option<RuntimeError> = None;

    // Ledger: model peak as (m slots + 1 tape) states of this block's size.
    let act = z_in.byte_size();
    let tid = ledger.alloc((m + 1) * act, Category::StepState);

    let step = |z: &Tensor| -> Tensor {
        let mut args: Vec<&Tensor> = vec![z];
        args.extend(theta.iter().copied());
        match co.call(&fwd_name, &args) {
            Ok(mut o) => o.remove(0),
            Err(_) => Tensor::zeros(z.shape()), // surfaced via call_err below
        }
    };

    let theta_grads_cell = std::cell::RefCell::new(&mut theta_grads);
    let call_err_cell = std::cell::RefCell::new(&mut call_err);
    let step_grad = |z: &Tensor, a: &Tensor| -> Tensor {
        let mut args: Vec<&Tensor> = vec![z];
        args.extend(theta.iter().copied());
        args.push(a);
        match co.call(&vjp_name, &args) {
            Ok(mut outs) => {
                let gz_step = outs.remove(0);
                let mut tg = theta_grads_cell.borrow_mut();
                for (acc, g) in tg.iter_mut().zip(outs.into_iter()) {
                    let _ = acc.axpy(1.0, &g);
                }
                gz_step
            }
            Err(e) => {
                **call_err_cell.borrow_mut() = Some(e);
                Tensor::zeros(z.shape())
            }
        }
    };

    let g_in = run_backward(&schedule, z_in, gz, step, step_grad, |_| {})
        .map_err(RuntimeError::Io)?;
    ledger.free(tid);

    if let Some(e) = call_err {
        return Err(e);
    }
    for (&i, tg) in pidx.iter().zip(theta_grads.into_iter()) {
        grads[i] = tg;
    }
    Ok(g_in)
}

/// Split a VJP output list (gz, gθ...) into the return gz and accumulated
/// parameter gradients.
fn distribute(outs: Vec<Tensor>, pidx: &[usize], grads: &mut [Tensor]) -> Result<Tensor> {
    let mut it = outs.into_iter();
    let gz = it.next().ok_or_else(|| RuntimeError::Shape("vjp returned nothing".into()))?;
    for &i in pidx {
        let g = it
            .next()
            .ok_or_else(|| RuntimeError::Shape("vjp output arity mismatch".into()))?;
        grads[i] = g;
    }
    Ok(gz)
}
