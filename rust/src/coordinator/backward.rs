//! Backward traversal: shared chain-rule plumbing over transitions and the
//! stem, with every ODE block delegated to the session's pluggable
//! [`GradientStrategy`] object.
//!
//! This file contains no per-method dispatch — adding a gradient method
//! means registering a new strategy in [`crate::api::strategy`], not
//! editing this traversal.

use crate::api::strategy::BlockContext;
use crate::memory::MemoryLedger;
use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::{ExecutionCore, ForwardState};

/// Backpropagate `gz` (dL/d z_final) through transitions and ODE blocks,
/// accumulating parameter gradients into `grads` (canonical order).
///
/// Takes the shared core by `&` plus the caller's per-call state
/// (`ForwardState`, `grads`, ledger) — nothing here mutates the core, so
/// concurrent backward passes over one core are safe. The data-parallel
/// training step exploits this: every pool worker runs this traversal
/// simultaneously over its own micro-batch's `ForwardState`, writing into
/// its own `grads` buffer, with the cross-micro-batch reduction deferred
/// to `ExecutionCore::reduce_grads` on the calling thread.
pub(crate) fn backward(
    co: &ExecutionCore,
    state: &ForwardState,
    mut gz: Tensor,
    params: &[Tensor],
    grads: &mut [Tensor],
    ledger: &mut MemoryLedger,
) -> Result<()> {
    for s in (0..co.cfg.stages()).rev() {
        // Transition after stage s (if any) comes first in reverse order.
        if s + 1 < co.cfg.stages() {
            let (tw, tb) = co.index.trans[s];
            let tin = state.trans_inputs[s].as_ref();
            let outs = co.call(
                &co.modules.trans[s].vjp,
                &[tin, &params[tw], &params[tb], &gz],
            )?;
            let mut it = outs.into_iter();
            gz = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
            grads[tw] = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
            grads[tb] = it.next().ok_or_else(|| RuntimeError::Shape("trans_vjp arity".into()))?;
        }
        for b in (0..co.cfg.blocks_per_stage).rev() {
            let pidx = &co.index.blocks[s][b];
            let theta: Vec<&Tensor> = pidx.iter().map(|&i| &params[i]).collect();
            let ctx = BlockContext {
                exec: co,
                modules: &co.modules.stages[s],
                nt: co.cfg.nt,
                z_in: state.block_inputs[s][b].as_ref(),
                z_out: state.block_outputs[s][b].as_ref(),
                theta: &theta,
                pidx,
                nodes: &state.block_nodes[s][b],
            };
            gz = co.strategy.block_backward(&ctx, gz, grads, ledger)?;
        }
    }

    // Stem VJP (input-image gradient not needed).
    let (sw, sb) = co.index.stem;
    let outs = co.call(&co.modules.stem_vjp, &[&state.x, &params[sw], &params[sb], &gz])?;
    let mut it = outs.into_iter();
    grads[sw] = it.next().ok_or_else(|| RuntimeError::Shape("stem_vjp arity".into()))?;
    grads[sb] = it.next().ok_or_else(|| RuntimeError::Shape("stem_vjp arity".into()))?;
    Ok(())
}
