//! The ANODE execution core — the paper's §V contribution as a runtime
//! system, split into a **shared-immutable core** and per-call mutable
//! state so one core can serve many threads.
//!
//! **Internal layer.** Application code should go through [`crate::api`]
//! (`Engine` → `Session`); the execution core is the implementation detail
//! behind it, kept public for white-box integration tests and benches.
//!
//! Responsibilities:
//! - **Forward pass** over stem → (ODE blocks, transitions) → head, storing
//!   only the O(L) block-boundary activations ([`ExecutionCore::forward`]).
//! - **Inference pass** ([`ExecutionCore::forward_infer`]): the same network
//!   without gradient bookkeeping — no ledger traffic, no stored
//!   activations — used by evaluation and the serving path.
//! - **Multi-stage backward** (the private `backward` module): per ODE
//!   block, delegate to the
//!   session's pluggable [`GradientStrategy`] object; transitions and the
//!   stem are shared chain-rule plumbing.
//! - **Memory accounting**: every stored activation goes through the
//!   [`crate::memory::MemoryLedger`], so the O(L·Nt) → O(L)+O(Nt) claim is
//!   measured, not asserted.
//!
//! Thread-safety contract: the core holds only immutable model structure
//! (config, param index, typed module handles, the strategy object) plus
//! the `Arc`'d registry; everything mutable — [`ForwardState`], SGD state,
//! the [`crate::memory::MemoryLedger`] — lives per session or per call and
//! is passed in by the caller. `&ExecutionCore` methods are safe to call
//! from any number of threads concurrently. The data-parallel training
//! path leans on exactly this split: each pool worker drives
//! [`ExecutionCore::loss_and_grad`] over its own micro-batches with a
//! private `ForwardState` and ledger, and the per-micro gradients reduce
//! in fixed index order through [`ExecutionCore::reduce_grads`].
//!
//! All module references are typed [`ModuleHandle`]s resolved eagerly by
//! the [`crate::api`] layer — the core never constructs a module name from
//! strings.

mod backward;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::api::modules::{ModuleHandle, ModuleSet};
use crate::api::strategy::{CompiledBlockBackward, GradientStrategy, ModuleExec, StrategyRegistry};
use crate::compile::{
    InferCall, InferProgram, TrainBackward, TrainBlock, TrainChain, TrainProgram, TrainStage,
    TransCall,
};
use crate::memory::{Category, MemoryLedger};
use crate::models::{GradMethod, ModelConfig, ParamIndex, Solver};
use crate::runtime::{ArtifactRegistry, Backend, Result, RuntimeError};
use crate::tensor::Tensor;

/// Back-compat name for the shared core ([`ExecutionCore`] since the
/// thread-safety refactor; older tests and docs say "coordinator").
pub type Coordinator = ExecutionCore;

/// Activations stored by the forward pass (the O(L) term): inputs to every
/// ODE block and transition, plus each block's output (needed by the [8]
/// baseline, which starts its reverse solve from z1).
///
/// Stored activations are **shared**, not cloned: each boundary tensor is
/// produced once by its module call and every reader (next block's input,
/// the backward traversal, the `node` reverse solve) holds an `Arc` to
/// that one buffer — the chain's output *is* the next input, so one
/// activation per boundary exists, matching the paper's O(L) accounting.
pub struct ForwardState {
    /// x (input batch) — needed for the stem VJP.
    pub x: Tensor,
    /// block_inputs[s][b] = input activation of ODE block (s, b).
    pub block_inputs: Vec<Vec<Arc<Tensor>>>,
    /// block_outputs[s][b] = output activation (used by `node` only);
    /// shares the buffer of the next block/transition input.
    pub block_outputs: Vec<Vec<Arc<Tensor>>>,
    /// block_nodes[s][b] = interior trajectory node states of ODE block
    /// (s, b) captured by a stepwise forward, in increasing time order —
    /// populated only for strategies that request node capture via
    /// [`GradientStrategy::forward_nodes`] (the interpolated adjoint);
    /// empty vectors otherwise. Endpoints are not duplicated here: state
    /// 0 is the block input, state nt the block output.
    pub block_nodes: Vec<Vec<Vec<Arc<Tensor>>>>,
    /// trans_inputs[s] = input of transition s (shares the last block
    /// output of stage s).
    pub trans_inputs: Vec<Arc<Tensor>>,
    /// Final activation entering the head.
    pub z_final: Arc<Tensor>,
    /// Ledger ids backing the stored tensors (freed after backward).
    ledger_ids: Vec<u64>,
}

/// The shared-immutable execution core: model structure, resolved module
/// handles and the gradient-strategy object for a single (arch, solver,
/// method) config. `Send + Sync`; wrap in an `Arc` to fan it across worker
/// threads — all mutable state (parameters, ledgers, optimizer) stays with
/// the caller.
pub struct ExecutionCore {
    pub reg: Arc<ArtifactRegistry>,
    pub cfg: ModelConfig,
    pub index: ParamIndex,
    pub solver: Solver,
    pub modules: ModuleSet,
    pub strategy: Box<dyn GradientStrategy>,
    /// Calls made to each module (perf accounting; relaxed — a counter,
    /// not a synchronization point).
    pub call_count: AtomicUsize,
    /// The inference forward (stem → blocks → transitions) fused into one
    /// flat compiled program with arena-backed intermediates. Built when
    /// the registry runs [`Backend::Compiled`]; `None` otherwise.
    /// Bit-identical to the sequential module-call chain by construction.
    fused_infer: Option<InferProgram>,
    /// The full training step (forward with trajectory capture, the
    /// strategy's adjoint backward, loss/grad tail) fused into one flat
    /// compiled program over a checkpoint-aware arena. Built when the
    /// registry runs [`Backend::Compiled`] **and** the strategy opts into
    /// compiled lowering via
    /// [`GradientStrategy::compiled_backward`]; `None` otherwise (custom
    /// strategies stay on the interpreter). Bit-identical to the
    /// interpreter traversal by construction.
    fused_train: Option<TrainProgram>,
}

impl ExecutionCore {
    /// Back-compat constructor from a parsed [`GradMethod`]: resolves the
    /// module set and builds the strategy through the built-in registry.
    pub fn new(
        reg: Arc<ArtifactRegistry>,
        cfg: ModelConfig,
        solver: Solver,
        method: GradMethod,
    ) -> Result<Self> {
        let modules = ModuleSet::resolve(&reg, &cfg, solver)?;
        let strategy = StrategyRegistry::builtin().create_from_method(method)?;
        Self::with_strategy(reg, cfg, solver, modules, strategy)
    }

    /// Construct with a pre-resolved module set and strategy object (the
    /// [`crate::api::Engine`] path). Fails fast if the manifest lacks a
    /// block-module kind the strategy needs.
    pub fn with_strategy(
        reg: Arc<ArtifactRegistry>,
        cfg: ModelConfig,
        solver: Solver,
        modules: ModuleSet,
        strategy: Box<dyn GradientStrategy>,
    ) -> Result<Self> {
        let layout = reg.param_layout(&cfg.params_key())?;
        let index = ParamIndex::from_layout(layout, &cfg)?;
        for stage in &modules.stages {
            for kind in strategy.required_kinds() {
                stage.require(kind).map_err(|e| {
                    RuntimeError::Io(format!(
                        "gradient method `{}` unavailable: {e}",
                        strategy.name()
                    ))
                })?;
            }
        }
        let (fused_infer, fused_train) = if reg.backend() == Backend::Compiled {
            (
                Some(Self::build_fused_infer(&reg, &cfg, &index, &modules)?),
                Self::build_fused_train(&reg, &cfg, &index, &modules, strategy.as_ref())?,
            )
        } else {
            (None, None)
        };
        Ok(Self {
            reg,
            cfg,
            index,
            solver,
            modules,
            strategy,
            call_count: AtomicUsize::new(0),
            fused_infer,
            fused_train,
        })
    }

    /// Assemble the model-level inference chain (the module/param sequence
    /// [`Self::forward_infer`] walks) and compile it into one fused
    /// program. The chain is statically known from the config — the
    /// discretize-then-optimize structure has no data-dependent control
    /// flow — which is exactly what makes whole-forward fusion legal.
    fn build_fused_infer(
        reg: &ArtifactRegistry,
        cfg: &ModelConfig,
        index: &ParamIndex,
        modules: &ModuleSet,
    ) -> Result<InferProgram> {
        let mut chain = Vec::new();
        chain.push(InferCall {
            module: modules.stem_fwd.name().to_string(),
            params: vec![index.stem.0, index.stem.1],
        });
        for s in 0..cfg.stages() {
            let fwd = modules.stages[s].require("fwd")?;
            for b in 0..cfg.blocks_per_stage {
                chain.push(InferCall {
                    module: fwd.name().to_string(),
                    params: index.blocks[s][b].clone(),
                });
            }
            if s + 1 < cfg.stages() {
                let (tw, tb) = index.trans[s];
                chain.push(InferCall {
                    module: modules.trans[s].fwd.name().to_string(),
                    params: vec![tw, tb],
                });
            }
        }
        let param_shapes: Vec<Vec<usize>> = reg
            .param_layout(&cfg.params_key())?
            .iter()
            .map(|p| p.shape.clone())
            .collect();
        InferProgram::build(reg, &chain, &param_shapes).map_err(RuntimeError::from)
    }

    /// Assemble the full training step as a [`TrainChain`] — the same
    /// stem → blocks → transitions → head walk the interpreter runs,
    /// plus how each block's backward lowers — and compile it into one
    /// fused program over a checkpoint-aware arena. `Ok(None)` when the
    /// strategy does not opt into compiled lowering: those sessions run
    /// the interpreter even under [`Backend::Compiled`], because the
    /// compiler cannot know a plugged-in strategy's semantics.
    fn build_fused_train(
        reg: &ArtifactRegistry,
        cfg: &ModelConfig,
        index: &ParamIndex,
        modules: &ModuleSet,
        strategy: &dyn GradientStrategy,
    ) -> Result<Option<TrainProgram>> {
        let Some(lowering) = strategy.compiled_backward() else {
            return Ok(None);
        };
        let mut stages = Vec::with_capacity(cfg.stages());
        for s in 0..cfg.stages() {
            let stage = &modules.stages[s];
            let fwd = stage.require("fwd")?;
            let backward = match lowering {
                CompiledBlockBackward::Fused { kind } => {
                    TrainBackward::Fused { module: stage.require(kind)?.name().to_string() }
                }
                CompiledBlockBackward::FromOutput { kind } => {
                    TrainBackward::FromOutput { module: stage.require(kind)?.name().to_string() }
                }
                CompiledBlockBackward::Checkpointed => {
                    let schedule = strategy.checkpoint_schedule(cfg.nt).ok_or_else(|| {
                        RuntimeError::Io(format!(
                            "strategy `{}` lowers as checkpointed but plans no schedule",
                            strategy.name()
                        ))
                    })?;
                    TrainBackward::Checkpointed {
                        step_fwd: stage.require("step_fwd")?.name().to_string(),
                        step_vjp: stage.require("step_vjp")?.name().to_string(),
                        schedule,
                    }
                }
                CompiledBlockBackward::Interpolated { nodes } => TrainBackward::Interpolated {
                    step_fwd: stage.require("step_fwd")?.name().to_string(),
                    step_vjp: stage.require("step_vjp")?.name().to_string(),
                    nodes,
                },
            };
            let blocks = (0..cfg.blocks_per_stage)
                .map(|b| TrainBlock {
                    fwd: fwd.name().to_string(),
                    params: index.blocks[s][b].clone(),
                    backward: backward.clone(),
                })
                .collect();
            let trans = (s + 1 < cfg.stages()).then(|| TransCall {
                fwd: modules.trans[s].fwd.name().to_string(),
                vjp: modules.trans[s].vjp.name().to_string(),
                params: index.trans[s],
            });
            stages.push(TrainStage { blocks, trans });
        }
        let chain = TrainChain {
            nt: cfg.nt,
            stem_fwd: modules.stem_fwd.name().to_string(),
            stem_vjp: modules.stem_vjp.name().to_string(),
            stem_params: index.stem,
            stages,
            head_loss_grad: modules.head_loss_grad.name().to_string(),
            head_params: index.head,
        };
        let param_shapes: Vec<Vec<usize>> = reg
            .param_layout(&cfg.params_key())?
            .iter()
            .map(|p| p.shape.clone())
            .collect();
        TrainProgram::build(reg, &chain, &param_shapes).map(Some).map_err(RuntimeError::from)
    }

    /// The fused compiled inference program, when the registry runs the
    /// compiled backend (tests and benches inspect its arena layout).
    pub fn fused_infer(&self) -> Option<&InferProgram> {
        self.fused_infer.as_ref()
    }

    /// The fused compiled training program, when the registry runs the
    /// compiled backend and the strategy lowers (tests and benches
    /// inspect its arena layout and trajectory budget).
    pub fn fused_train(&self) -> Option<&TrainProgram> {
        self.fused_train.as_ref()
    }

    /// Canonical name of the configured gradient method.
    pub fn method_name(&self) -> String {
        self.strategy.name()
    }

    /// Module executions so far (perf accounting).
    pub fn calls_made(&self) -> usize {
        self.call_count.load(Ordering::Relaxed)
    }

    /// Initial parameters from params.bin (canonical order).
    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        self.reg.load_params(&self.cfg.params_key())
    }

    /// Execute a resolved module through the registry's **trusted** path:
    /// handles are resolved against the manifest eagerly and every tensor
    /// flowing through the core is shape-checked at the API boundary
    /// ([`crate::api::Session`]), so per-call shape re-validation here
    /// would be pure hot-loop overhead (arity is still checked).
    pub(crate) fn call(&self, handle: &ModuleHandle, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.call_count.fetch_add(1, Ordering::Relaxed);
        self.reg.call_trusted(handle.name(), inputs)
    }

    /// Gather a block's parameter tensors in artifact order.
    fn block_params<'a>(&self, params: &'a [Tensor], s: usize, b: usize) -> Vec<&'a Tensor> {
        self.index.blocks[s][b].iter().map(|&i| &params[i]).collect()
    }

    /// Forward pass storing the O(L) block boundaries. Ledger records every
    /// stored activation under `BlockInput`.
    pub fn forward(
        &self,
        x: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<ForwardState> {
        let mut ledger_ids = Vec::new();
        let track = |t: &Tensor, ledger: &mut MemoryLedger, ids: &mut Vec<u64>| {
            ids.push(ledger.alloc(t.byte_size(), Category::BlockInput));
        };

        // Strategies that reconstruct the backward from sparse trajectory
        // nodes (the interpolated adjoint) need the forward run stepwise
        // so the node states exist to capture; every other strategy keeps
        // the fused one-call-per-block forward.
        let forward_nodes = self.strategy.forward_nodes(self.cfg.nt);

        let (sw, sb) = (&params[self.index.stem.0], &params[self.index.stem.1]);
        let mut z = Arc::new(self.call(&self.modules.stem_fwd, &[x, sw, sb])?.remove(0));
        track(x, ledger, &mut ledger_ids);

        let mut block_inputs = Vec::new();
        let mut block_outputs = Vec::new();
        let mut block_nodes = Vec::new();
        let mut trans_inputs = Vec::new();
        for s in 0..self.cfg.stages() {
            let mut ins = Vec::new();
            let mut outs = Vec::new();
            let mut nodes_of = Vec::new();
            let fwd = self.modules.stages[s].require("fwd")?;
            for b in 0..self.cfg.blocks_per_stage {
                track(z.as_ref(), ledger, &mut ledger_ids);
                ins.push(Arc::clone(&z));
                let z1 = if let Some(nodes) = &forward_nodes {
                    let step_fwd = self.modules.stages[s].require("step_fwd")?;
                    let mut captured = Vec::new();
                    let mut cur = Arc::clone(&z);
                    for t in 0..self.cfg.nt {
                        let mut args: Vec<&Tensor> = vec![cur.as_ref()];
                        args.extend(self.block_params(params, s, b));
                        let next = Arc::new(self.call(step_fwd, &args)?.remove(0));
                        // Interior nodes are stored (and metered) as they
                        // appear; the endpoints are the block input/output
                        // already held above/below.
                        if t + 1 < self.cfg.nt && nodes.contains(&(t + 1)) {
                            track(next.as_ref(), ledger, &mut ledger_ids);
                            captured.push(Arc::clone(&next));
                        }
                        cur = next;
                    }
                    nodes_of.push(captured);
                    cur
                } else {
                    let mut args: Vec<&Tensor> = vec![z.as_ref()];
                    args.extend(self.block_params(params, s, b));
                    nodes_of.push(Vec::new());
                    Arc::new(self.call(fwd, &args)?.remove(0))
                };
                // Output doubles as the next block's input: one buffer,
                // two Arc readers — no deep copy.
                outs.push(Arc::clone(&z1));
                z = z1;
            }
            block_inputs.push(ins);
            block_outputs.push(outs);
            block_nodes.push(nodes_of);
            if s + 1 < self.cfg.stages() {
                let (tw, tb) = self.index.trans[s];
                track(z.as_ref(), ledger, &mut ledger_ids);
                trans_inputs.push(Arc::clone(&z));
                z = Arc::new(
                    self.call(&self.modules.trans[s].fwd, &[z.as_ref(), &params[tw], &params[tb]])?
                        .remove(0),
                );
            }
        }

        Ok(ForwardState {
            x: x.clone(),
            block_inputs,
            block_outputs,
            block_nodes,
            trans_inputs,
            z_final: z,
            ledger_ids,
        })
    }

    /// Inference-only forward: rolls a single activation through the
    /// network and returns the head input. No activations are stored and
    /// no ledger traffic is generated — evaluation and serving pay zero
    /// gradient-bookkeeping overhead.
    pub fn forward_infer(&self, x: &Tensor, params: &[Tensor]) -> Result<Tensor> {
        if let Some(prog) = &self.fused_infer {
            // One fused program instead of O(stages × blocks) dispatches;
            // count its kernels so call accounting matches the sequential
            // path exactly.
            self.call_count.fetch_add(prog.len(), Ordering::Relaxed);
            return prog.run(x, params);
        }
        let (sw, sb) = (&params[self.index.stem.0], &params[self.index.stem.1]);
        let mut z = self.call(&self.modules.stem_fwd, &[x, sw, sb])?.remove(0);
        for s in 0..self.cfg.stages() {
            let fwd = self.modules.stages[s].require("fwd")?;
            for b in 0..self.cfg.blocks_per_stage {
                let mut args: Vec<&Tensor> = vec![&z];
                args.extend(self.block_params(params, s, b));
                z = self.call(fwd, &args)?.remove(0);
            }
            if s + 1 < self.cfg.stages() {
                let (tw, tb) = self.index.trans[s];
                z = self
                    .call(&self.modules.trans[s].fwd, &[&z, &params[tw], &params[tb]])?
                    .remove(0);
            }
        }
        Ok(z)
    }

    /// Loss + gradients for one batch. Returns (loss, correct, grads).
    ///
    /// Under [`Backend::Compiled`] with a lowerable strategy this runs
    /// the fused [`TrainProgram`] — one flat dispatch over a pooled
    /// arena — instead of the interpreter traversal; results and ledger
    /// traffic are bit-identical either way.
    pub fn loss_and_grad(
        &self,
        x: &Tensor,
        labels: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        if let Some(prog) = &self.fused_train {
            return self.loss_and_grad_compiled(prog, x, labels, params, ledger);
        }
        let state = self.forward(x, params, ledger)?;
        let outcome = self.head_and_backward(&state, labels, params, ledger);
        // Release the O(L) stored activations on success AND error: the
        // caller's ledger outlives this step, so an error must not leak
        // phantom BlockInput allocations.
        for id in &state.ledger_ids {
            ledger.free(*id);
        }
        outcome
    }

    /// One fused compiled training step, with the interpreter's ledger
    /// script replayed around it: the same BlockInput allocations in
    /// forward order, the same transient StepState alloc/free per block
    /// backward. The arena is planned memory, but the paper's
    /// O(L)+O(N_t) claim is *measured* against the ledger — so both
    /// backends must tell it the same story (the sharding grid asserts
    /// traffic equality compiled vs sim).
    fn loss_and_grad_compiled(
        &self,
        prog: &TrainProgram,
        x: &Tensor,
        labels: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        self.call_count.fetch_add(prog.kernel_calls(), Ordering::Relaxed);
        let ids: Vec<u64> = prog
            .tracked_bytes()
            .iter()
            .map(|&bytes| ledger.alloc(bytes, Category::BlockInput))
            .collect();
        let outcome = prog.run(x, labels, params);
        if outcome.is_ok() {
            // The backward ran to completion: meter its per-block
            // transient step states exactly as the strategies do.
            for &bytes in prog.step_state_bytes() {
                let tid = ledger.alloc(bytes, Category::StepState);
                ledger.free(tid);
            }
        }
        // Release stored activations on success AND error, mirroring the
        // interpreter path's leak-free contract.
        for id in ids {
            ledger.free(id);
        }
        outcome
    }

    /// Head loss/grad call plus the full backward sweep (split out so
    /// `loss_and_grad` can release stored activations on every exit path).
    fn head_and_backward(
        &self,
        state: &ForwardState,
        labels: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        let (hw, hb) = self.index.head;
        let mut outs = self.call(
            &self.modules.head_loss_grad,
            &[state.z_final.as_ref(), &params[hw], &params[hb], labels],
        )?;
        let loss = outs[0].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        let correct = outs[1].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        let gz = outs.remove(2);
        let ghw = outs.remove(2);
        let ghb = outs.remove(2);

        let mut grads = ParamIndex::zero_grads(params);
        grads[hw] = ghw;
        grads[hb] = ghb;
        backward::backward(self, state, gz, params, &mut grads, ledger)?;
        Ok((loss, correct, grads))
    }

    /// Loss + correct-count for one pre-batched eval pair, via the
    /// inference forward. The per-batch unit behind [`Self::evaluate`] and
    /// the parallel evaluation path — independent across batches.
    pub fn eval_batch(&self, x: &Tensor, labels: &Tensor, params: &[Tensor]) -> Result<(f32, f32)> {
        let (hw, hb) = self.index.head;
        let z = self.forward_infer(x, params)?;
        let outs = self.call(&self.modules.head_eval, &[&z, &params[hw], &params[hb], labels])?;
        let loss = outs[0].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        let correct = outs[1].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        Ok((loss, correct))
    }

    /// Evaluation over pre-batched data: returns (mean loss, accuracy).
    ///
    /// Routed through [`ExecutionCore::forward_infer`] — no checkpoint
    /// tracking, no ledger allocs/frees — since no backward follows.
    pub fn evaluate(&self, batches: &[(Tensor, Tensor)], params: &[Tensor]) -> Result<(f32, f32)> {
        let per_batch = batches
            .iter()
            .map(|(x, y)| self.eval_batch(x, y, params))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self::reduce_eval(&per_batch, self.cfg.batch))
    }

    /// Fold per-micro-batch `(loss, correct, grads)` triples into the mean
    /// loss, the total correct count and the **mean** gradient, reducing
    /// strictly in micro-batch index order on the calling thread.
    ///
    /// This is the single reduction behind both the serial and the
    /// data-parallel training paths
    /// ([`Session::step_accumulate`](crate::api::Session::step_accumulate)):
    /// workers compute per-micro-batch gradients over private
    /// [`ForwardState`]s/ledgers and return them *unreduced* in input
    /// order (contiguous chunks reassembled by worker index), so the
    /// floating-point accumulation tree here is identical for every worker
    /// count — the discretize-then-optimize gradient stays bit-identical
    /// to the serial run, preserving the paper's "unconditionally
    /// accurate" property under parallelism.
    pub fn reduce_grads(
        per_micro: Vec<(f32, f32, Vec<Tensor>)>,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        let mut acc = GradAccumulator::new();
        for triple in per_micro {
            acc.push(triple)?;
        }
        acc.finish()
    }

    /// Fold per-batch (loss, correct) pairs into (mean loss, accuracy), in
    /// index order — the single reduction used by both the serial and the
    /// parallel evaluation paths, so their results are bit-identical.
    pub fn reduce_eval(per_batch: &[(f32, f32)], batch_size: usize) -> (f32, f32) {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for &(loss, c) in per_batch {
            loss_sum += loss as f64;
            correct += c as f64;
            n += batch_size;
        }
        let batches_n = per_batch.len().max(1) as f64;
        ((loss_sum / batches_n) as f32, (correct / n.max(1) as f64) as f32)
    }
}

/// Incremental form of [`ExecutionCore::reduce_grads`]: push per-micro
/// `(loss, correct, grads)` triples **in micro-batch index order** as they
/// become available, then [`GradAccumulator::finish`]. The accumulation is
/// operation-for-operation the loop `reduce_grads` runs over a complete
/// vector — adopt the first triple's gradient tensors, `axpy(1.0)` every
/// later one in push order, scale by `1/k` at the end, fold losses in f64
/// — so a pipelined caller (folding chunk i while chunk i+1 still
/// computes, `Session::step_accumulate`'s streaming path) produces a
/// bit-identical gradient to the all-at-once reduction and to serial.
pub struct GradAccumulator {
    loss_sum: f64,
    correct_sum: f64,
    grads: Option<Vec<Tensor>>,
    count: usize,
}

impl Default for GradAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl GradAccumulator {
    /// An empty accumulator ([`GradAccumulator::finish`] on it errors,
    /// matching `reduce_grads` over zero micro-batches).
    pub fn new() -> Self {
        Self { loss_sum: 0.0, correct_sum: 0.0, grads: None, count: 0 }
    }

    /// Micro-batches folded so far.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold one micro-batch's triple. Must be called in micro-batch index
    /// order — the caller owns the ordering (the streaming scatter in
    /// `util::pool` delivers chunks in input order by construction).
    pub fn push(&mut self, (loss, correct, g): (f32, f32, Vec<Tensor>)) -> Result<()> {
        match self.grads.as_mut() {
            None => {
                // Adopt (not add to zero): `0.0 + -0.0` would flip a sign
                // bit the all-at-once reduction preserves.
                self.loss_sum = loss as f64;
                self.correct_sum = correct as f64;
                self.grads = Some(g);
            }
            Some(acc) => {
                self.loss_sum += loss as f64;
                self.correct_sum += correct as f64;
                for (ai, gi) in acc.iter_mut().zip(g.iter()) {
                    ai.axpy(1.0, gi).map_err(|e| RuntimeError::Shape(e.to_string()))?;
                }
            }
        }
        self.count += 1;
        Ok(())
    }

    /// Close the fold: `(mean loss, total correct, mean gradient)`, with
    /// the same zero-micro-batch error as [`ExecutionCore::reduce_grads`].
    pub fn finish(self) -> Result<(f32, f32, Vec<Tensor>)> {
        let k = self.count;
        let Some(mut grads) = self.grads else {
            return Err(RuntimeError::Shape("gradient reduction over zero micro-batches".into()));
        };
        if k > 1 {
            let scale = 1.0 / k as f32;
            for g in grads.iter_mut() {
                g.scale(scale);
            }
        }
        Ok(((self.loss_sum / k as f64) as f32, self.correct_sum as f32, grads))
    }
}

impl ModuleExec for ExecutionCore {
    fn call_module(&self, handle: &ModuleHandle, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.call(handle, inputs)
    }
}

// The core is the unit shared across session/worker threads; a regression
// to non-Sync internals must fail the build here.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ExecutionCore>();
};
