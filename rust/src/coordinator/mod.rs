//! The ANODE training coordinator — the paper's §V contribution as a
//! runtime system.
//!
//! Responsibilities:
//! - **Forward pass** over stem → (ODE blocks, transitions) → head, storing
//!   only the O(L) block-boundary activations ([`Coordinator::forward`]).
//! - **Multi-stage backward** ([`Coordinator::backward`]): per ODE block,
//!   dispatch the configured gradient method:
//!   `anode` re-runs the block's discrete forward inside the fused DTO-VJP
//!   artifact (O(Nt) inside the call, freed on return); `anode-revolve(m)` /
//!   `anode-equispaced(m)` drive step-level artifacts through a
//!   [`crate::checkpoint`] schedule under an m-slot budget; `node` performs
//!   the [8] reverse-time augmented solve; `otd` the inconsistent
//!   optimize-then-discretize adjoint (§IV).
//! - **Memory accounting**: every stored activation goes through the
//!   [`crate::memory::MemoryLedger`], so the O(L·Nt) → O(L)+O(Nt) claim is
//!   measured, not asserted.
//! - **Training loop** with SGD+momentum, LR schedule, eval, divergence
//!   detection ([`Trainer`]).

mod backward;
mod trainer;

pub use trainer::{make_eval_batches, TrainOptions, TrainResult, Trainer};

use crate::memory::{Category, MemoryLedger};
use crate::models::{GradMethod, ModelConfig, ParamIndex, Solver};
use crate::runtime::{ArtifactRegistry, Result, RuntimeError};
use crate::tensor::Tensor;

/// Activations stored by the forward pass (the O(L) term): inputs to every
/// ODE block and transition, plus each block's output (needed by the [8]
/// baseline, which starts its reverse solve from z1).
pub struct ForwardState {
    /// x (input batch) — needed for the stem VJP.
    pub x: Tensor,
    /// block_inputs[s][b] = input activation of ODE block (s, b).
    pub block_inputs: Vec<Vec<Tensor>>,
    /// block_outputs[s][b] = output activation (used by `node` only).
    pub block_outputs: Vec<Vec<Tensor>>,
    /// trans_inputs[s] = input of transition s.
    pub trans_inputs: Vec<Tensor>,
    /// Final activation entering the head.
    pub z_final: Tensor,
    /// Ledger ids backing the stored tensors (freed after backward).
    ledger_ids: Vec<u64>,
}

/// The coordinator: owns the artifact registry handle, model structure and
/// gradient-method dispatch for a single (arch, solver, method) config.
pub struct Coordinator<'r> {
    pub reg: &'r ArtifactRegistry,
    pub cfg: ModelConfig,
    pub index: ParamIndex,
    pub solver: Solver,
    pub method: GradMethod,
    /// Calls made to each module (perf accounting).
    pub call_count: std::cell::Cell<usize>,
}

impl<'r> Coordinator<'r> {
    pub fn new(
        reg: &'r ArtifactRegistry,
        cfg: ModelConfig,
        solver: Solver,
        method: GradMethod,
    ) -> Result<Self> {
        let layout = reg.param_layout(&cfg.params_key())?;
        let index = ParamIndex::from_layout(layout, &cfg)?;
        // Fail fast if the manifest lacks the modules this config needs.
        let probe = cfg.block_module(0, solver, backward::primary_kind(method));
        if !reg.has_module(&probe) {
            return Err(RuntimeError::Io(format!(
                "manifest has no module {probe} for method {} — re-run `make artifacts`",
                method.name()
            )));
        }
        Ok(Self { reg, cfg, index, solver, method, call_count: std::cell::Cell::new(0) })
    }

    /// Initial parameters from params.bin (canonical order).
    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        self.reg.load_params(&self.cfg.params_key())
    }

    pub(crate) fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.call_count.set(self.call_count.get() + 1);
        self.reg.call(name, inputs)
    }

    /// Gather a block's parameter tensors in artifact order.
    fn block_params<'a>(&self, params: &'a [Tensor], s: usize, b: usize) -> Vec<&'a Tensor> {
        self.index.blocks[s][b].iter().map(|&i| &params[i]).collect()
    }

    /// Forward pass storing the O(L) block boundaries. Ledger records every
    /// stored activation under `BlockInput`.
    pub fn forward(
        &self,
        x: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<ForwardState> {
        let mut ledger_ids = Vec::new();
        let track = |t: &Tensor, ledger: &mut MemoryLedger, ids: &mut Vec<u64>| {
            ids.push(ledger.alloc(t.byte_size(), Category::BlockInput));
        };

        let (sw, sb) = (&params[self.index.stem.0], &params[self.index.stem.1]);
        let mut z = self.call("stem_fwd", &[x, sw, sb])?.remove(0);
        track(x, ledger, &mut ledger_ids);

        let mut block_inputs = Vec::new();
        let mut block_outputs = Vec::new();
        let mut trans_inputs = Vec::new();
        for s in 0..self.cfg.stages() {
            let mut ins = Vec::new();
            let mut outs = Vec::new();
            let fwd_name = self.cfg.block_module(s, self.solver, "fwd");
            for b in 0..self.cfg.blocks_per_stage {
                let mut args: Vec<&Tensor> = vec![&z];
                args.extend(self.block_params(params, s, b));
                let z1 = self.call(&fwd_name, &args)?.remove(0);
                track(&z, ledger, &mut ledger_ids);
                ins.push(z.clone());
                // Output is the next block's input; stored once (the clone
                // here is host-side bookkeeping, not device memory).
                outs.push(z1.clone());
                z = z1;
            }
            block_inputs.push(ins);
            block_outputs.push(outs);
            if s + 1 < self.cfg.stages() {
                let (tw, tb) = self.index.trans[s];
                track(&z, ledger, &mut ledger_ids);
                trans_inputs.push(z.clone());
                z = self
                    .call(&format!("trans{s}_fwd"), &[&z, &params[tw], &params[tb]])?
                    .remove(0);
            }
        }

        Ok(ForwardState {
            x: x.clone(),
            block_inputs,
            block_outputs,
            trans_inputs,
            z_final: z,
            ledger_ids,
        })
    }

    /// Loss + gradients for one batch. Returns (loss, correct, grads).
    pub fn loss_and_grad(
        &self,
        x: &Tensor,
        labels: &Tensor,
        params: &[Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        let state = self.forward(x, params, ledger)?;
        let (hw, hb) = self.index.head;
        let head_name = format!("head{}_loss_grad", self.cfg.num_classes);
        let mut outs =
            self.call(&head_name, &[&state.z_final, &params[hw], &params[hb], labels])?;
        let loss = outs[0].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        let correct = outs[1].item().map_err(|e| RuntimeError::Shape(e.to_string()))?;
        let gz = outs.remove(2);
        let ghw = outs.remove(2);
        let ghb = outs.remove(2);

        let mut grads = ParamIndex::zero_grads(params);
        grads[hw] = ghw;
        grads[hb] = ghb;
        backward::backward(self, &state, gz, params, &mut grads, ledger)?;

        // Release the O(L) stored activations.
        for id in &state.ledger_ids {
            ledger.free(*id);
        }
        Ok((loss, correct, grads))
    }

    /// Evaluation over pre-batched data: returns (mean loss, accuracy).
    pub fn evaluate(&self, batches: &[(Tensor, Tensor)], params: &[Tensor]) -> Result<(f32, f32)> {
        let (hw, hb) = self.index.head;
        let head_name = format!("head{}_eval", self.cfg.num_classes);
        let mut ledger = MemoryLedger::new();
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for (x, labels) in batches {
            let state = self.forward(x, params, &mut ledger)?;
            let outs = self.call(&head_name, &[&state.z_final, &params[hw], &params[hb], labels])?;
            loss_sum += outs[0].item().map_err(|e| RuntimeError::Shape(e.to_string()))? as f64;
            correct += outs[1].item().map_err(|e| RuntimeError::Shape(e.to_string()))? as f64;
            n += self.cfg.batch;
            for id in &state.ledger_ids {
                ledger.free(*id);
            }
        }
        let batches_n = batches.len().max(1) as f64;
        Ok(((loss_sum / batches_n) as f32, (correct / n.max(1) as f64) as f32))
    }
}
