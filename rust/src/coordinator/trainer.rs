//! Training loop: the end-to-end driver behind Figs. 3, 4, 5 and the
//! `train` CLI subcommand / `train_cifar` example.

use std::time::Instant;

use crate::data::Batcher;
use crate::memory::{Category, MemoryLedger};
use crate::metrics::{Curve, CurvePoint, Mean};
use crate::optim::{LrSchedule, Sgd};
use crate::runtime::Result;
use crate::tensor::Tensor;

use super::Coordinator;

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub eval_every: usize,
    pub lr: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    pub clip_norm: Option<f32>,
    /// Stop as soon as the loss goes non-finite (records the divergence).
    pub stop_on_divergence: bool,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self {
            steps: 200,
            eval_every: 25,
            lr: LrSchedule::Constant(0.02),
            momentum: 0.9,
            weight_decay: 5e-4,
            clip_norm: Some(5.0),
            stop_on_divergence: true,
            verbose: true,
        }
    }
}

/// Outcome of a training run.
pub struct TrainResult {
    pub curve: Curve,
    pub diverged: bool,
    pub steps_run: usize,
    pub wall_seconds: f64,
    /// Peak activation bytes observed by the ledger.
    pub peak_activation_bytes: usize,
    pub peak_block_input_bytes: usize,
    pub peak_step_state_bytes: usize,
    /// Mean seconds per training step.
    pub sec_per_step: f64,
}

/// Run the training loop for one (arch, solver, method) configuration.
pub struct Trainer<'c, 'r> {
    pub co: &'c Coordinator<'r>,
    pub opts: TrainOptions,
}

impl<'c, 'r> Trainer<'c, 'r> {
    pub fn new(co: &'c Coordinator<'r>, opts: TrainOptions) -> Self {
        Self { co, opts }
    }

    pub fn train(
        &self,
        train: &mut Batcher,
        test_batches: &[(Tensor, Tensor)],
        series_name: &str,
    ) -> Result<TrainResult> {
        let co = self.co;
        let mut params = co.load_params()?;
        let mut opt = Sgd::new(&params, self.opts.lr.at(0), self.opts.momentum, self.opts.weight_decay);
        let mut ledger = MemoryLedger::new();
        // Params + optimizer state are persistent allocations.
        let pbytes: usize = params.iter().map(|p| p.byte_size()).sum();
        ledger.alloc(pbytes, Category::Param);
        ledger.alloc(opt.state_bytes(), Category::OptState);

        let mut curve = Curve::new(series_name);
        let mut train_loss = Mean::default();
        let mut diverged = false;
        let t0 = Instant::now();
        let mut steps_run = 0;
        let batches_per_epoch = train.batches_per_epoch().max(1);

        for step in 0..self.opts.steps {
            let batch = train.next_batch();
            opt.lr = self.opts.lr.at(step);
            let (loss, _corr, mut grads) =
                co.loss_and_grad(&batch.images, &batch.labels, &params, &mut ledger)?;
            steps_run = step + 1;
            train_loss.add(loss);

            let finite = loss.is_finite() && grads.iter().all(|g| g.all_finite());
            if finite {
                if let Some(c) = self.opts.clip_norm {
                    Sgd::clip_grads(&mut grads, c);
                }
                opt.step(&mut params, &grads);
            } else {
                diverged = true;
            }

            let at_eval = (step + 1) % self.opts.eval_every == 0 || step + 1 == self.opts.steps;
            if at_eval || diverged {
                let (tl, ta) = if diverged {
                    (f32::NAN, curve.points.last().map(|p| p.test_acc).unwrap_or(0.0))
                } else {
                    co.evaluate(test_batches, &params)?
                };
                let point = CurvePoint {
                    step: step + 1,
                    epoch: (step + 1) as f32 / batches_per_epoch as f32,
                    train_loss: if diverged { f32::NAN } else { train_loss.value() },
                    test_loss: tl,
                    test_acc: ta,
                };
                if self.opts.verbose {
                    eprintln!(
                        "[{series_name}] step {:>5} epoch {:>5.2} train_loss {:>9.4} test_loss {:>9.4} test_acc {:>6.2}%{}",
                        point.step,
                        point.epoch,
                        point.train_loss,
                        point.test_loss,
                        point.test_acc * 100.0,
                        if diverged { "  << DIVERGED" } else { "" }
                    );
                }
                curve.push(point);
                train_loss.reset();
                if diverged && self.opts.stop_on_divergence {
                    break;
                }
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        Ok(TrainResult {
            diverged: diverged || curve.diverged(),
            curve,
            steps_run,
            wall_seconds: wall,
            peak_activation_bytes: ledger.peak_of(Category::BlockInput)
                + ledger.peak_of(Category::StepState),
            peak_block_input_bytes: ledger.peak_of(Category::BlockInput),
            peak_step_state_bytes: ledger.peak_of(Category::StepState),
            sec_per_step: wall / steps_run.max(1) as f64,
        })
    }
}

/// Build eval batches (fixed, unaugmented) from a dataset tensor.
pub fn make_eval_batches(
    images: &Tensor,
    labels: &[usize],
    batch: usize,
    max_batches: usize,
) -> Vec<(Tensor, Tensor)> {
    let n = labels.len();
    let per: usize = images.shape()[1..].iter().product();
    let mut out = Vec::new();
    let mut i = 0;
    while i + batch <= n && out.len() < max_batches {
        let data = images.data()[i * per..(i + batch) * per].to_vec();
        let mut shape = vec![batch];
        shape.extend_from_slice(&images.shape()[1..]);
        let x = Tensor::from_vec(shape, data).unwrap();
        let y = Tensor::from_vec(
            vec![batch],
            labels[i..i + batch].iter().map(|&l| l as f32).collect(),
        )
        .unwrap();
        out.push((x, y));
        i += batch;
    }
    out
}
