//! Optimizers and learning-rate schedules (Eq. 3 of the paper).
//!
//! Runs on the host over flat f32 tensors; parameter updates are cheap
//! relative to the ODE-block executions, so no AOT module is needed.

use crate::tensor::Tensor;

/// SGD with classical momentum and decoupled weight decay.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<Tensor>,
}

impl Sgd {
    pub fn new(params: &[Tensor], lr: f32, momentum: f32, weight_decay: f32) -> Self {
        let velocity = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
        Self { lr, momentum, weight_decay, velocity }
    }

    /// Bytes of optimizer state (for the memory ledger).
    pub fn state_bytes(&self) -> usize {
        self.velocity.iter().map(|v| v.byte_size()).sum()
    }

    /// v ← μv + g + wd·p;  p ← p − lr·v
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.velocity.len());
        for ((p, g), v) in params.iter_mut().zip(grads.iter()).zip(self.velocity.iter_mut()) {
            assert_eq!(p.shape(), g.shape(), "param/grad shape mismatch");
            let (pd, gd, vd) = (p.data_mut(), g.data(), v.data_mut());
            let (mu, wd, lr) = (self.momentum, self.weight_decay, self.lr);
            for i in 0..pd.len() {
                vd[i] = mu * vd[i] + gd[i] + wd * pd[i];
                pd[i] -= lr * vd[i];
            }
        }
    }

    /// Clip to `max_norm` (`None` disables clipping) and apply one update
    /// — the shared tail of `Session::step` and the data-parallel
    /// `Session::step_accumulate`, so both paths run byte-for-byte the
    /// same optimizer arithmetic. Returns the pre-clip global norm.
    pub fn clipped_step(
        &mut self,
        params: &mut [Tensor],
        grads: &mut [Tensor],
        max_norm: Option<f32>,
    ) -> f32 {
        let norm = Self::clip_grads(grads, max_norm.unwrap_or(f32::INFINITY));
        self.step(params, grads);
        norm
    }

    /// Global gradient-norm clipping; returns the pre-clip norm.
    pub fn clip_grads(grads: &mut [Tensor], max_norm: f32) -> f32 {
        let norm = {
            let sq: f64 = grads.iter().map(|g| {
                g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            }).sum();
            sq.sqrt() as f32
        };
        if norm > max_norm && norm > 0.0 {
            let scale = max_norm / norm;
            for g in grads.iter_mut() {
                g.scale(scale);
            }
        }
        norm
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Constant(f32),
    /// Multiply by `gamma` at each milestone step (classic CIFAR recipe).
    Step { base: f32, gamma: f32, milestones: Vec<usize> },
    /// Cosine decay from `base` to `floor` over `total` steps.
    Cosine { base: f32, floor: f32, total: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::Step { base, gamma, milestones } => {
                let k = milestones.iter().filter(|&&m| step >= m).count();
                base * gamma.powi(k as i32)
            }
            LrSchedule::Cosine { base, floor, total } => {
                let t = (step.min(*total)) as f32 / (*total).max(1) as f32;
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimizing f(p) = ½‖p‖² with gradient p must converge to 0.
    #[test]
    fn sgd_converges_on_quadratic() {
        let mut params = vec![Tensor::from_vec(vec![3], vec![1.0, -2.0, 3.0]).unwrap()];
        let mut opt = Sgd::new(&params, 0.1, 0.9, 0.0);
        for _ in 0..200 {
            let grads = vec![params[0].clone()];
            opt.step(&mut params, &grads);
        }
        assert!(params[0].norm2() < 1e-3, "norm {}", params[0].norm2());
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mu: f32| {
            let mut params = vec![Tensor::from_vec(vec![1], vec![1.0]).unwrap()];
            let mut opt = Sgd::new(&params, 0.02, mu, 0.0);
            for _ in 0..50 {
                let grads = vec![params[0].clone()];
                opt.step(&mut params, &grads);
            }
            params[0].data()[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn weight_decay_shrinks_params_with_zero_grad() {
        let mut params = vec![Tensor::from_vec(vec![1], vec![2.0]).unwrap()];
        let mut opt = Sgd::new(&params, 0.1, 0.0, 0.1);
        let zero = vec![Tensor::zeros(&[1])];
        for _ in 0..10 {
            opt.step(&mut params, &zero);
        }
        assert!(params[0].data()[0] < 2.0 && params[0].data()[0] > 0.0);
    }

    #[test]
    fn clipping_bounds_norm() {
        let mut grads = vec![Tensor::from_vec(vec![2], vec![3.0, 4.0]).unwrap()];
        let pre = Sgd::clip_grads(&mut grads, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((grads[0].norm2() - 1.0).abs() < 1e-6);
        // Below threshold: untouched.
        let mut g2 = vec![Tensor::from_vec(vec![2], vec![0.3, 0.4]).unwrap()];
        Sgd::clip_grads(&mut g2, 1.0);
        assert!((g2[0].norm2() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn clipped_step_matches_manual_clip_then_step() {
        let run = |clipped: bool| {
            let mut params = vec![Tensor::from_vec(vec![2], vec![1.0, -1.0]).unwrap()];
            let mut opt = Sgd::new(&params, 0.1, 0.9, 0.01);
            let mut grads = vec![Tensor::from_vec(vec![2], vec![3.0, 4.0]).unwrap()];
            let norm = if clipped {
                opt.clipped_step(&mut params, &mut grads, Some(1.0))
            } else {
                let n = Sgd::clip_grads(&mut grads, 1.0);
                opt.step(&mut params, &grads);
                n
            };
            (norm.to_bits(), params[0].data().to_vec())
        };
        assert_eq!(run(true), run(false));
        // None disables clipping entirely.
        let mut params = vec![Tensor::from_vec(vec![2], vec![0.0, 0.0]).unwrap()];
        let mut opt = Sgd::new(&params, 1.0, 0.0, 0.0);
        let mut grads = vec![Tensor::from_vec(vec![2], vec![3.0, 4.0]).unwrap()];
        let norm = opt.clipped_step(&mut params, &mut grads, None);
        assert!((norm - 5.0).abs() < 1e-6);
        assert_eq!(params[0].data(), &[-3.0, -4.0]);
    }

    #[test]
    fn schedules() {
        let s = LrSchedule::Step { base: 0.1, gamma: 0.1, milestones: vec![10, 20] };
        assert!((s.at(0) - 0.1).abs() < 1e-8);
        assert!((s.at(10) - 0.01).abs() < 1e-8);
        assert!((s.at(25) - 0.001).abs() < 1e-8);
        let c = LrSchedule::Cosine { base: 1.0, floor: 0.0, total: 100 };
        assert!((c.at(0) - 1.0).abs() < 1e-6);
        assert!((c.at(50) - 0.5).abs() < 1e-6);
        assert!(c.at(100) < 1e-6);
        assert_eq!(LrSchedule::Constant(0.05).at(999), 0.05);
    }

    #[test]
    fn state_bytes_counts_velocity() {
        let params = vec![Tensor::zeros(&[10]), Tensor::zeros(&[5])];
        let opt = Sgd::new(&params, 0.1, 0.9, 0.0);
        assert_eq!(opt.state_bytes(), 60);
    }
}
