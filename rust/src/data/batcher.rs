//! Mini-batch iteration with per-epoch shuffling and optional augmentation.

use crate::rng::Rng;
use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::cifar::{SyntheticCifar, CIFAR_HW};

/// One training batch: images (B,H,W,C) and f32 labels (B,)
/// (labels are f32 because the AOT head modules take uniform f32 inputs).
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Tensor,
    pub labels: Tensor,
}

/// Epoch-shuffling batcher over a dataset held in memory.
pub struct Batcher {
    images: Tensor,
    labels: Vec<usize>,
    batch_size: usize,
    augment: bool,
    rng: Rng,
    order: Vec<usize>,
    cursor: usize,
    pub epoch: usize,
}

impl Batcher {
    /// Build a batcher over an in-memory dataset. Mismatched image/label
    /// counts and degenerate batch sizes are typed errors (like the rest
    /// of the API surface), not panics — callers such as `Session::fit`
    /// drivers propagate them to the user with context.
    pub fn new(
        images: Tensor,
        labels: Vec<usize>,
        batch_size: usize,
        augment: bool,
        seed: u64,
    ) -> Result<Self> {
        let n = images.shape().first().copied().unwrap_or(0);
        if n != labels.len() {
            return Err(RuntimeError::Shape(format!(
                "batcher: {} images but {} labels",
                n,
                labels.len()
            )));
        }
        if batch_size == 0 || batch_size > labels.len() {
            return Err(RuntimeError::Shape(format!(
                "batcher: batch size {batch_size} not in 1..={} (dataset size)",
                labels.len()
            )));
        }
        let mut rng = Rng::new(seed);
        let order = rng.permutation(labels.len());
        Ok(Self { images, labels, batch_size, augment, rng, order, cursor: 0, epoch: 0 })
    }

    /// Number of full batches per epoch (remainder dropped, standard practice).
    pub fn batches_per_epoch(&self) -> usize {
        self.labels.len() / self.batch_size
    }

    /// Total examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Next batch; reshuffles and increments `epoch` at epoch end.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch_size > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch_size];
        self.cursor += self.batch_size;

        let img_dims = &self.images.shape()[1..];
        let per: usize = img_dims.iter().product();
        let mut data = Vec::with_capacity(self.batch_size * per);
        let mut labels = Vec::with_capacity(self.batch_size);
        for &i in idx {
            data.extend_from_slice(&self.images.data()[i * per..(i + 1) * per]);
            labels.push(self.labels[i] as f32);
        }
        if self.augment && per == CIFAR_HW * CIFAR_HW * 3 {
            for b in 0..self.batch_size {
                SyntheticCifar::augment(&mut data[b * per..(b + 1) * per], &mut self.rng);
            }
        }
        let mut shape = vec![self.batch_size];
        shape.extend_from_slice(img_dims);
        Batch {
            images: Tensor::from_vec(shape, data).unwrap(),
            labels: Tensor::from_vec(vec![self.batch_size], labels).unwrap(),
        }
    }
}

/// Build eval batches (fixed, unaugmented) from a dataset tensor.
pub fn make_eval_batches(
    images: &Tensor,
    labels: &[usize],
    batch: usize,
    max_batches: usize,
) -> Vec<(Tensor, Tensor)> {
    let n = labels.len();
    let per: usize = images.shape()[1..].iter().product();
    let mut out = Vec::new();
    let mut i = 0;
    while i + batch <= n && out.len() < max_batches {
        let data = images.data()[i * per..(i + batch) * per].to_vec();
        let mut shape = vec![batch];
        shape.extend_from_slice(&images.shape()[1..]);
        let x = Tensor::from_vec(shape, data).unwrap();
        let y = Tensor::from_vec(
            vec![batch],
            labels[i..i + batch].iter().map(|&l| l as f32).collect(),
        )
        .unwrap();
        out.push((x, y));
        i += batch;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> (Tensor, Vec<usize>) {
        // 2x2x1 "images" whose single distinguishing value is the index.
        let mut data = vec![0.0f32; n * 4];
        for i in 0..n {
            data[i * 4] = i as f32;
        }
        (Tensor::from_vec(vec![n, 2, 2, 1], data).unwrap(), (0..n).map(|i| i % 3).collect())
    }

    #[test]
    fn batches_have_right_shape() {
        let (imgs, labels) = toy(10);
        let mut b = Batcher::new(imgs, labels, 4, false, 0).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.images.shape(), &[4, 2, 2, 1]);
        assert_eq!(batch.labels.shape(), &[4]);
    }

    #[test]
    fn degenerate_configs_are_typed_errors() {
        let (imgs, labels) = toy(10);
        // Zero batch and batch > dataset.
        let err = Batcher::new(imgs.clone(), labels.clone(), 0, false, 0)
            .err()
            .expect("zero batch must fail")
            .to_string();
        assert!(err.contains("batch size 0"), "{err}");
        assert!(Batcher::new(imgs.clone(), labels.clone(), 11, false, 0).is_err());
        // Image/label count mismatch.
        let err = Batcher::new(imgs, labels[..9].to_vec(), 2, false, 0)
            .err()
            .expect("count mismatch must fail")
            .to_string();
        assert!(err.contains("10 images but 9 labels"), "{err}");
    }

    #[test]
    fn epoch_covers_every_example_once() {
        let (imgs, labels) = toy(12);
        let mut b = Batcher::new(imgs, labels, 4, false, 1).unwrap();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            let batch = b.next_batch();
            for k in 0..4 {
                seen.insert(batch.images.data()[k * 4] as usize);
            }
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(b.epoch, 0);
        b.next_batch();
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let (imgs, labels) = toy(12);
        let mut b1 = Batcher::new(imgs.clone(), labels.clone(), 4, false, 5).unwrap();
        let mut b2 = Batcher::new(imgs, labels, 4, false, 5).unwrap();
        for _ in 0..6 {
            assert_eq!(b1.next_batch().images.data(), b2.next_batch().images.data());
        }
    }

    #[test]
    fn labels_match_images() {
        let (imgs, labels) = toy(9);
        let expect = labels.clone();
        let mut b = Batcher::new(imgs, labels, 3, false, 2).unwrap();
        for _ in 0..3 {
            let batch = b.next_batch();
            for k in 0..3 {
                let idx = batch.images.data()[k * 4] as usize;
                assert_eq!(batch.labels.data()[k] as usize, expect[idx]);
            }
        }
    }
}
