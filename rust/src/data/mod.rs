//! Synthetic datasets (DESIGN.md §2 substitution table).
//!
//! Real CIFAR-10/100 is not available in this environment; the paper's
//! claims are about *gradient quality* (convergence vs. divergence of the
//! three gradient methods), so a learnable synthetic classification task
//! that exercises the identical code paths preserves the experiment: all
//! methods see the same data and differ only in how they backpropagate.

mod batcher;
mod cifar;
mod mnist_like;

pub use batcher::{make_eval_batches, Batch, Batcher};
pub use cifar::{SyntheticCifar, CIFAR_HW};
pub use mnist_like::render_digit;
