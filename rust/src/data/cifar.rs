//! Synthetic CIFAR-10/100: class-conditional structured 32×32×3 images.
//!
//! Each class k gets a deterministic signature: an oriented sinusoidal
//! grating (frequency + orientation + phase drawn from a class-seeded RNG),
//! a class color tint, and a blob center. Samples add per-example jitter
//! (phase/position/amplitude) plus pixel noise, so the task is learnable
//! but not trivial — a small convnet separates classes well above chance,
//! while random guessing sits at 1/K.

use crate::rng::Rng;
use crate::tensor::Tensor;

/// Image side length (CIFAR geometry).
pub const CIFAR_HW: usize = 32;

/// Class signature parameters.
#[derive(Debug, Clone)]
struct ClassSig {
    freq: f32,
    angle: f32,
    phase: f32,
    tint: [f32; 3],
    cx: f32,
    cy: f32,
}

/// Deterministic synthetic CIFAR-like dataset.
pub struct SyntheticCifar {
    pub num_classes: usize,
    sigs: Vec<ClassSig>,
    noise: f32,
}

impl SyntheticCifar {
    /// `num_classes` = 10 or 100 (any value works); `noise` is the pixel
    /// noise std (0.15 reproduces a comfortably-learnable task).
    pub fn new(num_classes: usize, seed: u64, noise: f32) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1FA_0000);
        let sigs = (0..num_classes)
            .map(|_| ClassSig {
                freq: rng.uniform_range(1.5, 6.0),
                angle: rng.uniform_range(0.0, std::f32::consts::PI),
                phase: rng.uniform_range(0.0, std::f32::consts::TAU),
                tint: [rng.uniform_range(0.2, 1.0), rng.uniform_range(0.2, 1.0), rng.uniform_range(0.2, 1.0)],
                cx: rng.uniform_range(0.3, 0.7),
                cy: rng.uniform_range(0.3, 0.7),
            })
            .collect();
        Self { num_classes, sigs, noise }
    }

    /// Render one sample of class `label` into NHWC layout at `out`
    /// (length 32*32*3), using `rng` for per-example jitter.
    pub fn render(&self, label: usize, rng: &mut Rng, out: &mut [f32]) {
        debug_assert_eq!(out.len(), CIFAR_HW * CIFAR_HW * 3);
        let sig = &self.sigs[label % self.num_classes];
        let phase = sig.phase + rng.normal() * 0.4;
        let amp = 1.0 + rng.normal() * 0.15;
        let dx = rng.normal() * 0.05;
        let dy = rng.normal() * 0.05;
        let (s, c) = sig.angle.sin_cos();
        let tau = std::f32::consts::TAU;
        for i in 0..CIFAR_HW {
            for j in 0..CIFAR_HW {
                let x = j as f32 / CIFAR_HW as f32 - (sig.cx + dx);
                let y = i as f32 / CIFAR_HW as f32 - (sig.cy + dy);
                // Oriented grating modulated by a radial envelope.
                let u = c * x + s * y;
                let r2 = x * x + y * y;
                let envelope = (-4.0 * r2).exp();
                let g = amp * (tau * sig.freq * u + phase).sin() * envelope;
                for ch in 0..3 {
                    let v = 0.5 * g * sig.tint[ch] + self.noise * rng.normal();
                    out[(i * CIFAR_HW + j) * 3 + ch] = v;
                }
            }
        }
    }

    /// Generate a full split: (images (N,32,32,3), labels (N,)). Labels are
    /// balanced round-robin, order shuffled.
    pub fn generate(&self, n: usize, seed: u64) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut labels: Vec<usize> = (0..n).map(|i| i % self.num_classes).collect();
        rng.shuffle(&mut labels);
        let mut data = vec![0.0f32; n * CIFAR_HW * CIFAR_HW * 3];
        for (i, &lab) in labels.iter().enumerate() {
            let start = i * CIFAR_HW * CIFAR_HW * 3;
            self.render(lab, &mut rng, &mut data[start..start + CIFAR_HW * CIFAR_HW * 3]);
        }
        let t = Tensor::from_vec(vec![n, CIFAR_HW, CIFAR_HW, 3], data).unwrap();
        (t, labels)
    }

    /// Standard augmentation: random horizontal flip + small shift, applied
    /// to one image slice in place (matching CIFAR training practice).
    pub fn augment(img: &mut [f32], rng: &mut Rng) {
        debug_assert_eq!(img.len(), CIFAR_HW * CIFAR_HW * 3);
        if rng.uniform() < 0.5 {
            // Horizontal flip.
            for i in 0..CIFAR_HW {
                for j in 0..CIFAR_HW / 2 {
                    for ch in 0..3 {
                        let a = (i * CIFAR_HW + j) * 3 + ch;
                        let b = (i * CIFAR_HW + (CIFAR_HW - 1 - j)) * 3 + ch;
                        img.swap(a, b);
                    }
                }
            }
        }
        // Random shift in [-2, 2] pixels, zero fill.
        let si = rng.below(5) as isize - 2;
        let sj = rng.below(5) as isize - 2;
        if si != 0 || sj != 0 {
            let src = img.to_vec();
            for i in 0..CIFAR_HW as isize {
                for j in 0..CIFAR_HW as isize {
                    let ii = i - si;
                    let jj = j - sj;
                    for ch in 0..3usize {
                        let dst = (i as usize * CIFAR_HW + j as usize) * 3 + ch;
                        img[dst] = if ii >= 0
                            && jj >= 0
                            && (ii as usize) < CIFAR_HW
                            && (jj as usize) < CIFAR_HW
                        {
                            src[(ii as usize * CIFAR_HW + jj as usize) * 3 + ch]
                        } else {
                            0.0
                        };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let ds = SyntheticCifar::new(10, 7, 0.1);
        let (a, la) = ds.generate(64, 3);
        let (b, lb) = ds.generate(64, 3);
        assert_eq!(a.data(), b.data());
        assert_eq!(la, lb);
        let (c, _) = ds.generate(64, 4);
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn labels_balanced() {
        let ds = SyntheticCifar::new(10, 7, 0.1);
        let (_, labels) = ds.generate(100, 0);
        let mut counts = [0usize; 10];
        for &l in &labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // Nearest-class-mean classification on clean-ish data must beat
        // chance by a wide margin — otherwise training curves are meaningless.
        let ds = SyntheticCifar::new(10, 7, 0.05);
        let (train, ltrain) = ds.generate(400, 1);
        let (test, ltest) = ds.generate(100, 2);
        let d = CIFAR_HW * CIFAR_HW * 3;
        let mut means = vec![vec![0.0f32; d]; 10];
        let mut counts = vec![0usize; 10];
        for (i, &l) in ltrain.iter().enumerate() {
            for k in 0..d {
                means[l][k] += train.data()[i * d + k];
            }
            counts[l] += 1;
        }
        for (m, &c) in means.iter_mut().zip(counts.iter()) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for (i, &l) in ltest.iter().enumerate() {
            let img = &test.data()[i * d..(i + 1) * d];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = img.iter().zip(&means[a]).map(|(x, m)| (x - m).powi(2)).sum();
                    let db: f32 = img.iter().zip(&means[b]).map(|(x, m)| (x - m).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == l {
                correct += 1;
            }
        }
        let acc = correct as f32 / 100.0;
        assert!(acc > 0.5, "template-matching accuracy only {acc}");
    }

    #[test]
    fn cifar100_works() {
        let ds = SyntheticCifar::new(100, 9, 0.15);
        let (imgs, labels) = ds.generate(200, 0);
        assert_eq!(imgs.shape(), &[200, 32, 32, 3]);
        assert_eq!(*labels.iter().max().unwrap(), 99);
        assert!(imgs.all_finite());
    }

    #[test]
    fn augment_preserves_shape_and_range() {
        let ds = SyntheticCifar::new(10, 7, 0.1);
        let (imgs, _) = ds.generate(4, 0);
        let d = CIFAR_HW * CIFAR_HW * 3;
        let mut img = imgs.data()[..d].to_vec();
        let before_norm: f32 = img.iter().map(|x| x * x).sum();
        let mut rng = Rng::new(11);
        SyntheticCifar::augment(&mut img, &mut rng);
        let after_norm: f32 = img.iter().map(|x| x * x).sum();
        assert!(img.iter().all(|x| x.is_finite()));
        // Shift may zero a border; norm must not grow.
        assert!(after_norm <= before_norm * 1.001);
    }
}
