//! Procedural MNIST-like digit rendering for the Fig. 1 / Fig. 7
//! reversibility experiments (DESIGN.md §2: those figures only need a
//! structured grayscale image pushed through a random conv residual block).

use crate::rng::Rng;

/// Stroke segments per digit on a [0,1]² canvas (crude seven-segment-ish
/// skeletons; visual fidelity is irrelevant, spatial structure is not).
fn strokes(digit: u8) -> &'static [((f32, f32), (f32, f32))] {
    const S: f32 = 0.22;
    const E: f32 = 0.78;
    const M: f32 = 0.5;
    // Segments: top, top-left, top-right, middle, bottom-left, bottom-right, bottom.
    const TOP: ((f32, f32), (f32, f32)) = ((S, S), (E, S));
    const TL: ((f32, f32), (f32, f32)) = ((S, S), (S, M));
    const TR: ((f32, f32), (f32, f32)) = ((E, S), (E, M));
    const MID: ((f32, f32), (f32, f32)) = ((S, M), (E, M));
    const BL: ((f32, f32), (f32, f32)) = ((S, M), (S, E));
    const BR: ((f32, f32), (f32, f32)) = ((E, M), (E, E));
    const BOT: ((f32, f32), (f32, f32)) = ((S, E), (E, E));
    match digit % 10 {
        0 => &[TOP, TL, TR, BL, BR, BOT],
        1 => &[TR, BR],
        2 => &[TOP, TR, MID, BL, BOT],
        3 => &[TOP, TR, MID, BR, BOT],
        4 => &[TL, TR, MID, BR],
        5 => &[TOP, TL, MID, BR, BOT],
        6 => &[TOP, TL, MID, BL, BR, BOT],
        7 => &[TOP, TR, BR],
        8 => &[TOP, TL, TR, MID, BL, BR, BOT],
        _ => &[TOP, TL, TR, MID, BR, BOT],
    }
}

/// Render `digit` into an h×w grayscale image with stroke width ~w/10,
/// mild per-call jitter, and values in [0, 1].
pub fn render_digit(digit: u8, h: usize, w: usize, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; h * w];
    let jx = rng.normal() * 0.02;
    let jy = rng.normal() * 0.02;
    let width = 0.06f32;
    for &((x0, y0), (x1, y1)) in strokes(digit) {
        let (x0, y0, x1, y1) = (x0 + jx, y0 + jy, x1 + jx, y1 + jy);
        for i in 0..h {
            for j in 0..w {
                let px = (j as f32 + 0.5) / w as f32;
                let py = (i as f32 + 0.5) / h as f32;
                // Distance from pixel to segment.
                let (dx, dy) = (x1 - x0, y1 - y0);
                let len2 = dx * dx + dy * dy;
                let t = if len2 > 0.0 {
                    (((px - x0) * dx + (py - y0) * dy) / len2).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                let cx = x0 + t * dx;
                let cy = y0 + t * dy;
                let d = ((px - cx).powi(2) + (py - cy).powi(2)).sqrt();
                let v = (1.0 - (d / width).powi(2)).max(0.0);
                let cell = &mut img[i * w + j];
                *cell = cell.max(v);
            }
        }
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_structured_images() {
        let mut rng = Rng::new(0);
        for d in 0..10u8 {
            let img = render_digit(d, 28, 28, &mut rng);
            assert_eq!(img.len(), 28 * 28);
            let on = img.iter().filter(|&&v| v > 0.5).count();
            // Strokes light up some but not most pixels.
            assert!(on > 20 && on < 500, "digit {d}: {on} lit pixels");
            assert!(img.iter().all(|v| (0.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn digits_differ() {
        let mut rng = Rng::new(1);
        let a = render_digit(1, 28, 28, &mut rng);
        let mut rng = Rng::new(1);
        let b = render_digit(8, 28, 28, &mut rng);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 10.0);
    }
}
