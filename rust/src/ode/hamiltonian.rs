//! Hamiltonian (symplectic) dynamics — the paper's §III counterpoint.
//!
//! ANODE's analysis shows generic residual-block ODEs cannot be reversed
//! numerically. The paper contrasts this with Hamiltonian ODEs and their
//! discrete counterparts ([5, 20]; leapfrog/Verlet integration), which are
//! reversible **to machine precision** because the discrete map itself is
//! a bijection with an explicit inverse — at the cost of constraining the
//! architecture (and, per the paper, so far not matching SOTA accuracy).
//!
//! This module implements the leapfrog map for a separable Hamiltonian
//! network block H(q, p) = T(p) + V(q) with V's gradient given by an
//! arbitrary closure (e.g. a small conv/MLP force), plus its *exact*
//! inverse, and tests that verify machine-precision reversibility where
//! the generic blocks of [`super::revblock`] fail.

/// One leapfrog step for dq/dt = p, dp/dt = f(q) (f = -∇V):
///   p½ = p + (h/2) f(q);  q' = q + h p½;  p' = p½ + (h/2) f(q').
pub fn leapfrog_step<F: Fn(&[f32], &mut [f32])>(
    force: &F,
    h: f32,
    q: &mut [f32],
    p: &mut [f32],
    scratch: &mut [f32],
) {
    let n = q.len();
    debug_assert_eq!(p.len(), n);
    force(q, scratch);
    for i in 0..n {
        p[i] += 0.5 * h * scratch[i];
    }
    for i in 0..n {
        q[i] += h * p[i];
    }
    force(q, scratch);
    for i in 0..n {
        p[i] += 0.5 * h * scratch[i];
    }
}

/// The exact inverse of [`leapfrog_step`] — NOT a reverse-time integration
/// but the algebraic inverse of the discrete map (negate momentum, step,
/// negate back — leapfrog is time-symmetric).
pub fn leapfrog_step_inverse<F: Fn(&[f32], &mut [f32])>(
    force: &F,
    h: f32,
    q: &mut [f32],
    p: &mut [f32],
    scratch: &mut [f32],
) {
    for v in p.iter_mut() {
        *v = -*v;
    }
    leapfrog_step(force, h, q, p, scratch);
    for v in p.iter_mut() {
        *v = -*v;
    }
}

/// Integrate `nt` leapfrog steps forward; returns (q, p).
pub fn leapfrog<F: Fn(&[f32], &mut [f32])>(
    force: &F,
    q0: &[f32],
    p0: &[f32],
    t_horizon: f32,
    nt: usize,
) -> (Vec<f32>, Vec<f32>) {
    let h = t_horizon / nt as f32;
    let mut q = q0.to_vec();
    let mut p = p0.to_vec();
    let mut scratch = vec![0.0f32; q.len()];
    for _ in 0..nt {
        leapfrog_step(force, h, &mut q, &mut p, &mut scratch);
    }
    (q, p)
}

/// Reverse `nt` leapfrog steps exactly.
pub fn leapfrog_reverse<F: Fn(&[f32], &mut [f32])>(
    force: &F,
    q1: &[f32],
    p1: &[f32],
    t_horizon: f32,
    nt: usize,
) -> (Vec<f32>, Vec<f32>) {
    let h = t_horizon / nt as f32;
    let mut q = q1.to_vec();
    let mut p = p1.to_vec();
    let mut scratch = vec![0.0f32; q.len()];
    for _ in 0..nt {
        leapfrog_step_inverse(force, h, &mut q, &mut p, &mut scratch);
    }
    (q, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{conv3x3_single, reversibility_error};
    use crate::rng::Rng;

    /// Nonlinear force from a random conv — the SAME kind of operator that
    /// makes the generic residual block irreversible (Fig. 1).
    fn conv_force(h: usize, w: usize, kernel: [f32; 9]) -> impl Fn(&[f32], &mut [f32]) {
        move |q: &[f32], out: &mut [f32]| {
            conv3x3_single(q, h, w, &kernel, out);
            for o in out.iter_mut() {
                *o = -o.tanh(); // bounded nonlinear force
            }
        }
    }

    #[test]
    fn leapfrog_reverses_to_machine_precision() {
        // The paper's §III contrast: the SAME random-Gaussian conv
        // nonlinearity, but inside a Hamiltonian block — reversible exactly.
        let mut rng = Rng::new(0xAB);
        let (hh, ww) = (16, 16);
        let mut kernel = [0.0f32; 9];
        for k in kernel.iter_mut() {
            *k = rng.normal() * 3.0; // strong dynamics, like the Fig. 1 case
        }
        let force = conv_force(hh, ww, kernel);
        let q0: Vec<f32> = (0..hh * ww).map(|_| rng.uniform()).collect();
        let p0: Vec<f32> = (0..hh * ww).map(|_| rng.normal() * 0.1).collect();

        let (q1, p1) = leapfrog(&force, &q0, &p0, 1.0, 32);
        let (qr, pr) = leapfrog_reverse(&force, &q1, &p1, 1.0, 32);
        let rho_q = reversibility_error(&q0, &qr);
        let rho_p = reversibility_error(&p0, &pr);
        assert!(rho_q < 1e-5, "q reversal error {rho_q}");
        assert!(rho_p < 1e-4, "p reversal error {rho_p}");
    }

    #[test]
    fn generic_block_fails_where_hamiltonian_succeeds() {
        // Side-by-side with the Fig. 1 block at the same kernel strength.
        use crate::ode::{odeint, Activation, FixedSolver, RevBlock};
        let mut rng = Rng::new(0xAC);
        let block = RevBlock::random(16, 16, Activation::Relu, 3.0, &mut rng);
        let z0: Vec<f32> = (0..256).map(|_| rng.uniform()).collect();
        let z1 = odeint(&block, FixedSolver::Euler, &z0, 1.0, 32);
        let zr = odeint(&block, FixedSolver::Euler, &z1, -1.0, 32);
        let rho_generic = reversibility_error(&z0, &zr);
        assert!(
            rho_generic > 1e-2,
            "generic block should be irreversible here: {rho_generic}"
        );
        // (Hamiltonian counterpart verified above at < 1e-5.)
    }

    #[test]
    fn energy_is_approximately_conserved() {
        // Symplectic integrators bound the energy error — a structural
        // sanity check on the leapfrog implementation.
        let force = |q: &[f32], out: &mut [f32]| {
            for (o, qi) in out.iter_mut().zip(q) {
                *o = -qi; // harmonic oscillator, V = q²/2
            }
        };
        let energy = |q: &[f32], p: &[f32]| -> f64 {
            q.iter().zip(p).map(|(q, p)| 0.5 * (q * q + p * p) as f64).sum()
        };
        let q0 = vec![1.0f32, -0.5];
        let p0 = vec![0.0f32, 0.3];
        let e0 = energy(&q0, &p0);
        let (q1, p1) = leapfrog(&force, &q0, &p0, 10.0, 1000);
        let e1 = energy(&q1, &p1);
        assert!((e1 - e0).abs() / e0 < 1e-3, "energy drift {e0} -> {e1}");
    }

    #[test]
    fn inverse_is_exact_per_step() {
        let force = |q: &[f32], out: &mut [f32]| {
            for (o, qi) in out.iter_mut().zip(q) {
                *o = -(qi * 1.7).sin();
            }
        };
        let mut q = vec![0.3f32, -0.8, 1.2];
        let mut p = vec![0.1f32, 0.0, -0.4];
        let (q0, p0) = (q.clone(), p.clone());
        let mut s = vec![0.0f32; 3];
        leapfrog_step(&force, 0.25, &mut q, &mut p, &mut s);
        leapfrog_step_inverse(&force, 0.25, &mut q, &mut p, &mut s);
        for i in 0..3 {
            assert!((q[i] - q0[i]).abs() < 1e-6);
            assert!((p[i] - p0[i]).abs() < 1e-6);
        }
    }
}
