//! Native ODE integrators and reversibility analysis (§III of the paper).
//!
//! These pure-Rust integrators drive the *analysis* experiments — the scalar
//! / linear-system / random-matrix reversibility studies of §III and the
//! image residual-block demonstrations of Figs. 1 and 7 — where the point is
//! the numerics of the solver itself, not the trained network. (Training
//! uses the AOT-compiled JAX solvers via [`crate::runtime`].)

mod fixed;
mod hamiltonian;
mod revblock;
mod rk45;

pub use fixed::{odeint, step, FixedSolver};
pub use hamiltonian::{leapfrog, leapfrog_reverse, leapfrog_step, leapfrog_step_inverse};
pub use revblock::{conv3x3_single, Activation, RevBlock};
pub use rk45::{odeint_rk45, Rk45Options, Rk45Result};

/// Right-hand side of an autonomous ODE dz/dt = f(z) over a flat state.
pub trait Rhs {
    fn eval(&self, z: &[f32], out: &mut [f32]);
    fn dim(&self) -> usize;
}

impl<F: Fn(&[f32], &mut [f32])> Rhs for (F, usize) {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        (self.0)(z, out)
    }
    fn dim(&self) -> usize {
        self.1
    }
}

/// Reversibility error metric of Eq. 6:
/// ρ = ‖φ(φ(z0, t), −t) − z0‖₂ / ‖z0‖₂.
pub fn reversibility_error(z0: &[f32], z_roundtrip: &[f32]) -> f32 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in z_roundtrip.iter().zip(z0.iter()) {
        num += ((a - b) as f64).powi(2);
        den += (*b as f64).powi(2);
    }
    if den == 0.0 {
        num.sqrt() as f32
    } else {
        (num.sqrt() / den.sqrt()) as f32
    }
}

impl<R: Rhs> Rhs for &R {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        (*self).eval(z, out)
    }
    fn dim(&self) -> usize {
        (*self).dim()
    }
}

/// Negated RHS wrapper: integrating dz/ds = −f(z) forwards in s is the
/// "solve the forward ODE backwards" operation of [8].
pub struct Negated<R: Rhs>(pub R);

impl<R: Rhs> Rhs for Negated<R> {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        self.0.eval(z, out);
        for o in out.iter_mut() {
            *o = -*o;
        }
    }
    fn dim(&self) -> usize {
        self.0.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rho_zero_for_identity() {
        let z = vec![1.0, 2.0, 3.0];
        assert_eq!(reversibility_error(&z, &z), 0.0);
    }

    #[test]
    fn rho_is_relative() {
        let z0 = vec![2.0, 0.0];
        let zr = vec![0.0, 2.0];
        let e = reversibility_error(&z0, &zr);
        assert!((e - std::f32::consts::SQRT_2).abs() < 1e-6);
    }

    #[test]
    fn negated_flips_sign() {
        let f = (|z: &[f32], o: &mut [f32]| o.copy_from_slice(z), 2usize);
        let n = Negated(f);
        let mut out = vec![0.0; 2];
        n.eval(&[3.0, -1.0], &mut out);
        assert_eq!(out, vec![-3.0, 1.0]);
    }
}
