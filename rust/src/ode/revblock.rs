//! The Fig. 1 / Fig. 7 experiment substrate: a single-convolution residual
//! block RHS f(z) = act(conv3x3(z)) over a grayscale image, with random
//! Gaussian weights — the exact setup the paper uses to demonstrate that
//! solving the forward ODE backwards destroys the input.

use super::Rhs;
use crate::rng::Rng;

/// Activation after the convolution (the four rows of Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    LeakyRelu,
    Softplus,
}

impl Activation {
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::None => x,
            Activation::Relu => x.max(0.0),
            Activation::LeakyRelu => {
                if x > 0.0 {
                    x
                } else {
                    0.1 * x
                }
            }
            Activation::Softplus => {
                // Stable softplus.
                if x > 20.0 {
                    x
                } else {
                    (1.0 + x.exp()).ln()
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
            Activation::LeakyRelu => "leaky_relu",
            Activation::Softplus => "softplus",
        }
    }

    pub fn all() -> [Activation; 4] {
        [Activation::None, Activation::Relu, Activation::LeakyRelu, Activation::Softplus]
    }
}

/// 3x3 SAME convolution of a single-channel H×W image (zero padding).
pub fn conv3x3_single(img: &[f32], h: usize, w: usize, kernel: &[f32; 9], out: &mut [f32]) {
    debug_assert_eq!(img.len(), h * w);
    debug_assert_eq!(out.len(), h * w);
    for i in 0..h {
        for j in 0..w {
            let mut acc = 0.0f32;
            for di in 0..3usize {
                for dj in 0..3usize {
                    let ii = i as isize + di as isize - 1;
                    let jj = j as isize + dj as isize - 1;
                    if ii >= 0 && jj >= 0 && (ii as usize) < h && (jj as usize) < w {
                        acc += kernel[di * 3 + dj] * img[ii as usize * w + jj as usize];
                    }
                }
            }
            out[i * w + j] = acc;
        }
    }
}

/// f(z) = act(conv3x3(z)) with fixed random Gaussian weights.
pub struct RevBlock {
    pub h: usize,
    pub w: usize,
    pub kernel: [f32; 9],
    pub act: Activation,
}

impl RevBlock {
    /// Random Gaussian kernel, std `std` (paper: random Gaussian init).
    pub fn random(h: usize, w: usize, act: Activation, std: f32, rng: &mut Rng) -> Self {
        let mut kernel = [0.0f32; 9];
        for k in kernel.iter_mut() {
            *k = rng.normal() * std;
        }
        Self { h, w, kernel, act }
    }
}

impl Rhs for RevBlock {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        conv3x3_single(z, self.h, self.w, &self.kernel, out);
        for o in out.iter_mut() {
            *o = self.act.apply(*o);
        }
    }

    fn dim(&self) -> usize {
        self.h * self.w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{odeint, reversibility_error, FixedSolver};

    #[test]
    fn conv_identity_kernel() {
        let img: Vec<f32> = (0..16).map(|x| x as f32).collect();
        let mut out = vec![0.0; 16];
        let mut k = [0.0f32; 9];
        k[4] = 1.0; // delta kernel
        conv3x3_single(&img, 4, 4, &k, &mut out);
        assert_eq!(out, img);
    }

    #[test]
    fn conv_shift_kernel() {
        // Kernel tap (di=0, dj=1) reads the pixel ABOVE... verify exact
        // offset semantics: out[i,j] = sum k[di,dj] * img[i+di-1, j+dj-1].
        let img = vec![1.0, 0.0, 0.0, 0.0]; // pixel at (0,0)
        let mut out = vec![0.0; 4];
        let mut k = [0.0f32; 9];
        k[0] = 1.0; // (di=0,dj=0): out[i,j] = img[i-1, j-1]
        conv3x3_single(&img, 2, 2, &k, &mut out);
        assert_eq!(out, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn activations() {
        assert_eq!(Activation::Relu.apply(-1.0), 0.0);
        assert_eq!(Activation::Relu.apply(2.0), 2.0);
        assert!((Activation::LeakyRelu.apply(-1.0) + 0.1).abs() < 1e-7);
        assert_eq!(Activation::None.apply(-3.0), -3.0);
        let sp = Activation::Softplus.apply(0.0);
        assert!((sp - (2.0f32).ln()).abs() < 1e-6);
        assert!((Activation::Softplus.apply(30.0) - 30.0).abs() < 1e-4);
    }

    #[test]
    fn fig1_roundtrip_fails_for_random_gaussian_block() {
        // The Fig. 1 phenomenon: forward Euler solve then reverse solve of a
        // random-Gaussian conv+ReLU residual block does NOT recover the input.
        let mut rng = Rng::new(0xF16);
        let block = RevBlock::random(16, 16, Activation::Relu, 0.5, &mut rng);
        let z0: Vec<f32> = (0..256).map(|_| rng.uniform()).collect();
        let z1 = odeint(&block, FixedSolver::Euler, &z0, 1.0, 8);
        let zr = odeint(&block, FixedSolver::Euler, &z1, -1.0, 8);
        let rho = reversibility_error(&z0, &zr);
        assert!(rho > 0.01, "expected O(1) reversal error, got {rho}");
    }

    #[test]
    fn roundtrip_ok_for_tiny_lipschitz_constant() {
        // With a very small kernel std (small Lipschitz constant) the block
        // IS numerically reversible — matching §III's theory.
        let mut rng = Rng::new(0xF17);
        let block = RevBlock::random(16, 16, Activation::None, 0.01, &mut rng);
        let z0: Vec<f32> = (0..256).map(|_| rng.uniform() + 0.5).collect();
        let z1 = odeint(&block, FixedSolver::Rk4, &z0, 1.0, 64);
        let zr = odeint(&block, FixedSolver::Rk4, &z1, -1.0, 64);
        let rho = reversibility_error(&z0, &zr);
        assert!(rho < 1e-3, "small-λ block should reverse, rho={rho}");
    }
}
