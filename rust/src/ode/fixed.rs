//! Fixed-step explicit integrators (Euler, RK2/Heun-trapezoid, RK4) —
//! the same schemes the L2 JAX solvers bake into the artifacts.

use super::Rhs;

/// Fixed-step solver family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FixedSolver {
    Euler,
    /// Explicit trapezoidal (Heun) — the paper's "RK2 (Trapezoidal method)".
    Rk2,
    Rk4,
}

impl FixedSolver {
    /// Classical order of accuracy.
    pub fn order(&self) -> u32 {
        match self {
            FixedSolver::Euler => 1,
            FixedSolver::Rk2 => 2,
            FixedSolver::Rk4 => 4,
        }
    }

    /// RHS evaluations per step.
    pub fn stages(&self) -> usize {
        match self {
            FixedSolver::Euler => 1,
            FixedSolver::Rk2 => 2,
            FixedSolver::Rk4 => 4,
        }
    }

    /// Parse from CLI string.
    pub fn parse(s: &str) -> Option<FixedSolver> {
        match s {
            "euler" => Some(FixedSolver::Euler),
            "rk2" => Some(FixedSolver::Rk2),
            "rk4" => Some(FixedSolver::Rk4),
            _ => None,
        }
    }
}

fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// One step of `solver` with step size `h` (may be negative), in place.
pub fn step<R: Rhs>(rhs: &R, solver: FixedSolver, h: f32, z: &mut [f32]) {
    let n = z.len();
    match solver {
        FixedSolver::Euler => {
            let mut k1 = vec![0.0; n];
            rhs.eval(z, &mut k1);
            axpy(z, h, &k1);
        }
        FixedSolver::Rk2 => {
            let mut k1 = vec![0.0; n];
            let mut k2 = vec![0.0; n];
            let mut z1 = z.to_vec();
            rhs.eval(z, &mut k1);
            axpy(&mut z1, h, &k1);
            rhs.eval(&z1, &mut k2);
            axpy(z, h / 2.0, &k1);
            axpy(z, h / 2.0, &k2);
        }
        FixedSolver::Rk4 => {
            let mut k = vec![vec![0.0; n]; 4];
            let mut tmp = z.to_vec();
            rhs.eval(z, &mut k[0]);
            tmp.copy_from_slice(z);
            axpy(&mut tmp, h / 2.0, &k[0].clone());
            rhs.eval(&tmp, &mut k[1]);
            tmp.copy_from_slice(z);
            axpy(&mut tmp, h / 2.0, &k[1].clone());
            rhs.eval(&tmp, &mut k[2]);
            tmp.copy_from_slice(z);
            axpy(&mut tmp, h, &k[2].clone());
            rhs.eval(&tmp, &mut k[3]);
            axpy(z, h / 6.0, &k[0]);
            axpy(z, h / 3.0, &k[1]);
            axpy(z, h / 3.0, &k[2]);
            axpy(z, h / 6.0, &k[3]);
        }
    }
}

/// Integrate dz/dt = f(z) from z0 over horizon T with `nt` fixed steps.
/// T may be negative. Returns z(T).
pub fn odeint<R: Rhs>(rhs: &R, solver: FixedSolver, z0: &[f32], t_horizon: f32, nt: usize) -> Vec<f32> {
    assert!(nt > 0, "nt must be positive");
    let h = t_horizon / nt as f32;
    let mut z = z0.to_vec();
    for _ in 0..nt {
        step(rhs, solver, h, &mut z);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    /// dz/dt = λ z has exact solution z0·exp(λT).
    fn linear(lambda: f32) -> impl Rhs {
        (move |z: &[f32], o: &mut [f32]| {
            for (oi, zi) in o.iter_mut().zip(z.iter()) {
                *oi = lambda * zi;
            }
        }, 1usize)
    }

    #[test]
    fn euler_converges_first_order() {
        let rhs = linear(-1.0);
        let exact = (-1.0f64).exp() as f32;
        let e1 = (odeint(&rhs, FixedSolver::Euler, &[1.0], 1.0, 100)[0] - exact).abs();
        let e2 = (odeint(&rhs, FixedSolver::Euler, &[1.0], 1.0, 200)[0] - exact).abs();
        let ratio = e1 / e2;
        assert!((ratio - 2.0).abs() < 0.2, "order-1 ratio {ratio}");
    }

    #[test]
    fn rk2_converges_second_order() {
        let rhs = linear(-1.0);
        let exact = (-1.0f64).exp() as f32;
        let e1 = (odeint(&rhs, FixedSolver::Rk2, &[1.0], 1.0, 50)[0] - exact).abs();
        let e2 = (odeint(&rhs, FixedSolver::Rk2, &[1.0], 1.0, 100)[0] - exact).abs();
        let ratio = e1 / e2;
        assert!((ratio - 4.0).abs() < 0.8, "order-2 ratio {ratio}");
    }

    #[test]
    fn rk4_is_very_accurate() {
        let rhs = linear(-1.0);
        let exact = (-1.0f64).exp() as f32;
        let e = (odeint(&rhs, FixedSolver::Rk4, &[1.0], 1.0, 20)[0] - exact).abs();
        assert!(e < 1e-6, "rk4 error {e}");
    }

    #[test]
    fn negative_horizon_reverses() {
        // Forward then "reverse ODE solve" with fine steps on a mild λ
        // recovers the initial condition (the well-conditioned case).
        let rhs = linear(-1.0);
        let z1 = odeint(&rhs, FixedSolver::Rk4, &[1.0], 1.0, 100);
        let z0 = odeint(&rhs, FixedSolver::Rk4, &z1, -1.0, 100);
        assert!((z0[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn stiff_lambda_reverse_is_unstable_with_coarse_steps() {
        // §III: λ = -100 forward is fine, reverse with few steps explodes.
        let rhs = linear(-100.0);
        let z1 = odeint(&rhs, FixedSolver::Rk4, &[1.0], 1.0, 10_000);
        let z0 = odeint(&rhs, FixedSolver::Rk4, &z1, -1.0, 50);
        assert!(
            !z0[0].is_finite() || (z0[0] - 1.0).abs() > 0.5,
            "coarse reverse of stiff ODE should fail, got {}",
            z0[0]
        );
    }

    #[test]
    fn solver_metadata() {
        assert_eq!(FixedSolver::Euler.order(), 1);
        assert_eq!(FixedSolver::Rk4.stages(), 4);
        assert_eq!(FixedSolver::parse("rk2"), Some(FixedSolver::Rk2));
        assert_eq!(FixedSolver::parse("x"), None);
    }
}
