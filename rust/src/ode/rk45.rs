//! Adaptive Dormand–Prince RK45 — the "ode45"-style solver used for the
//! Fig. 7 reversibility study and the §III scalar experiments.

use super::Rhs;

/// Options for the adaptive integrator.
#[derive(Debug, Clone, Copy)]
pub struct Rk45Options {
    pub rtol: f32,
    pub atol: f32,
    pub max_steps: usize,
    /// Initial step as a fraction of the horizon.
    pub initial_frac: f32,
}

impl Default for Rk45Options {
    fn default() -> Self {
        Self { rtol: 1e-6, atol: 1e-9, max_steps: 10_000, initial_frac: 0.125 }
    }
}

/// Outcome of an adaptive solve.
#[derive(Debug, Clone)]
pub struct Rk45Result {
    pub z: Vec<f32>,
    /// Accepted steps.
    pub steps: usize,
    /// Rejected (re-tried) steps.
    pub rejects: usize,
    /// Time actually reached (== horizon iff converged).
    pub t_reached: f32,
    pub converged: bool,
}

// Dormand–Prince 5(4) tableau.
const A: [[f64; 6]; 6] = [
    [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
    [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
    [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
    [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0],
    [9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0],
    [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0],
];
const B5: [f64; 7] =
    [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0, 0.0];
const B4: [f64; 7] = [
    5179.0 / 57600.0,
    0.0,
    7571.0 / 16695.0,
    393.0 / 640.0,
    -92097.0 / 339200.0,
    187.0 / 2100.0,
    1.0 / 40.0,
];

/// Integrate dz/dt = f(z) from 0 to `t_horizon` (may be negative) with
/// adaptive step-size control.
pub fn odeint_rk45<R: Rhs>(rhs: &R, z0: &[f32], t_horizon: f32, opts: Rk45Options) -> Rk45Result {
    let n = z0.len();
    let sign = if t_horizon >= 0.0 { 1.0f32 } else { -1.0 };
    let mut z = z0.to_vec();
    let mut t = 0.0f32;
    let mut h = t_horizon * opts.initial_frac;
    let mut steps = 0;
    let mut rejects = 0;

    let mut k = vec![vec![0.0f32; n]; 7];
    let mut ztmp = vec![0.0f32; n];

    for _ in 0..opts.max_steps {
        if sign * t >= sign * t_horizon - 1e-12 * t_horizon.abs().max(1.0) {
            break;
        }
        // Clamp to the horizon.
        let h_eff = if sign * (t + h) > sign * t_horizon { t_horizon - t } else { h };

        rhs.eval(&z, &mut k[0]);
        for i in 0..6 {
            ztmp.copy_from_slice(&z);
            for (j, &aij) in A[i].iter().enumerate().take(i + 1) {
                if aij != 0.0 {
                    let kj = &k[j];
                    for (zt, kv) in ztmp.iter_mut().zip(kj.iter()) {
                        *zt += h_eff * aij as f32 * kv;
                    }
                }
            }
            let (head, tail) = k.split_at_mut(i + 1);
            let _ = head;
            rhs.eval(&ztmp, &mut tail[0]);
        }

        // 5th-order solution and embedded error estimate.
        let mut err_inf = 0.0f64;
        let mut z_inf = 0.0f64;
        let mut z5 = z.clone();
        for (idx, z5i) in z5.iter_mut().enumerate() {
            let mut d5 = 0.0f64;
            let mut d4 = 0.0f64;
            for s in 0..7 {
                d5 += B5[s] * k[s][idx] as f64;
                d4 += B4[s] * k[s][idx] as f64;
            }
            *z5i += (h_eff as f64 * d5) as f32;
            err_inf = err_inf.max((h_eff as f64 * (d5 - d4)).abs());
            z_inf = z_inf.max((*z5i as f64).abs().max((z[idx] as f64).abs()));
        }
        let scale = opts.atol as f64 + opts.rtol as f64 * z_inf;
        let ratio = if scale > 0.0 { err_inf / scale } else { f64::INFINITY };

        if !ratio.is_finite() {
            // State blew up — unrecoverable (the §III instability).
            return Rk45Result { z: z5, steps, rejects, t_reached: t, converged: false };
        }

        if ratio <= 1.0 {
            z = z5;
            t += h_eff;
            steps += 1;
        } else {
            rejects += 1;
        }
        let factor = (0.9 * ratio.max(1e-10).powf(-0.2)).clamp(0.2, 5.0);
        h = h_eff * factor as f32;
        if h.abs() < 1e-12 * t_horizon.abs().max(1.0) {
            // Step size underflow: cannot make progress.
            return Rk45Result { z, steps, rejects, t_reached: t, converged: false };
        }
    }

    let converged = sign * t >= sign * t_horizon - 1e-6 * t_horizon.abs().max(1.0);
    Rk45Result { z, steps, rejects, t_reached: t, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear(lambda: f32) -> impl Rhs {
        (move |z: &[f32], o: &mut [f32]| {
            for (oi, zi) in o.iter_mut().zip(z.iter()) {
                *oi = lambda * zi;
            }
        }, 1usize)
    }

    #[test]
    fn matches_exponential() {
        let r = odeint_rk45(&linear(-1.0), &[1.0], 1.0, Rk45Options::default());
        assert!(r.converged);
        let exact = (-1.0f64).exp() as f32;
        assert!((r.z[0] - exact).abs() < 1e-5, "{} vs {exact}", r.z[0]);
    }

    #[test]
    fn adapts_step_count_to_tolerance() {
        let tight = odeint_rk45(
            &linear(-10.0),
            &[1.0],
            1.0,
            Rk45Options { rtol: 1e-9, atol: 1e-12, ..Default::default() },
        );
        let loose = odeint_rk45(
            &linear(-10.0),
            &[1.0],
            1.0,
            Rk45Options { rtol: 1e-3, atol: 1e-6, ..Default::default() },
        );
        assert!(tight.converged && loose.converged);
        assert!(tight.steps > loose.steps, "{} vs {}", tight.steps, loose.steps);
    }

    #[test]
    fn nonlinear_cubic_blowup_detected() {
        // §III example: dz/dt = z^3 with z0 chosen so the solution blows up
        // before t = 1 (flow only defined for t < 1/(2 z0²) = 0.5).
        let rhs = (|z: &[f32], o: &mut [f32]| o[0] = z[0].powi(3), 1usize);
        let r = odeint_rk45(&rhs, &[1.0], 1.0, Rk45Options { max_steps: 2000, ..Default::default() });
        assert!(!r.converged, "blow-up must not converge (t_reached {})", r.t_reached);
        assert!(r.t_reached < 0.75);
    }

    #[test]
    fn negative_horizon_integrates_backwards() {
        let fwd = odeint_rk45(&linear(-1.0), &[1.0], 1.0, Rk45Options::default());
        let back = odeint_rk45(&linear(-1.0), &fwd.z, -1.0, Rk45Options::default());
        assert!(back.converged);
        assert!((back.z[0] - 1.0).abs() < 1e-4, "{}", back.z[0]);
    }

    #[test]
    fn stiff_reverse_needs_many_steps_or_fails() {
        // §III: reversing dz/dt = -100 z over unit horizon is the hard case.
        let fwd = odeint_rk45(&linear(-100.0), &[1.0], 1.0, Rk45Options::default());
        assert!(fwd.converged);
        let back = odeint_rk45(
            &linear(-100.0),
            &fwd.z,
            -1.0,
            Rk45Options { max_steps: 100_000, ..Default::default() },
        );
        // Either it fails to converge, or the recovered value is wrong, or it
        // needed a huge number of steps — all three manifest the paper's point.
        let err = (back.z[0] - 1.0).abs();
        assert!(
            !back.converged || err > 1e-3 || back.steps + back.rejects > 2_000,
            "converged={} err={err} steps={}",
            back.converged,
            back.steps
        );
    }
}
