//! # anode — Adjoint-based Neural ODEs with checkpointed DTO gradients
//!
//! A Rust + JAX + Pallas reproduction of *ANODE: Unconditionally Accurate
//! Memory-Efficient Gradients for Neural ODEs* (Gholami, Keutzer, Biros —
//! IJCAI 2019).
//!
//! Architecture (see DESIGN.md):
//! - **L3 (this crate)** — the checkpointing training coordinator: stores
//!   only ODE-block *input* activations (O(L)), re-runs each block forward
//!   during backprop (O(Nt)) and backpropagates through the discrete time
//!   stepper (Discretize-Then-Optimize), with optional Griewank–Walther
//!   revolve schedules for tighter memory budgets.
//! - **L2 (python/compile, build time)** — JAX ODE-block graphs AOT-lowered
//!   to HLO text, executed here via PJRT ([`runtime`]).
//! - **L1 (python/compile/kernels)** — Pallas conv kernels inside the block
//!   RHS, interpret-mode lowered into the same HLO.

pub mod checkpoint;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod ode;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod util;
