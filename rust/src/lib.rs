//! # anode — Adjoint-based Neural ODEs with checkpointed DTO gradients
//!
//! A Rust + JAX + Pallas reproduction of *ANODE: Unconditionally Accurate
//! Memory-Efficient Gradients for Neural ODEs* (Gholami, Keutzer, Biros —
//! IJCAI 2019).
//!
//! **Start at [`api`]** — the typed Engine/Session façade is the crate's
//! public surface:
//!
//! ```no_run
//! use anode::api::{Engine, SessionConfig};
//!
//! let engine = Engine::builder().artifacts("artifacts").build()?;
//! let mut session = engine.session(SessionConfig::with_method("anode"))?;
//! // session.step(&images, &labels)?   — train
//! // session.evaluate(&eval_batches)?  — measure
//! // session.predict(&images)?         — serve (batched inference + stats)
//! // session.serve(Default::default())? — single-request serving front end
//! # Ok::<(), anode::runtime::RuntimeError>(())
//! ```
//!
//! For production-style traffic, [`serve`] adds a deadline-batched
//! admission queue over a persistent worker pool: single requests are
//! coalesced into the AOT batch size and demultiplexed back with
//! per-request latency stats (see rust/DESIGN.md §6b). [`net`] puts a
//! socket front end on that pipeline — a length-prefixed binary
//! protocol with typed load shedding and a scrapeable metrics endpoint
//! (rust/DESIGN.md §6e). [`rollout`] closes the loop: a train→canary→
//! promote/rollback orchestrator that hot-swaps shadow-evaluated
//! parameter snapshots into the live pipeline behind a quality gate
//! (rust/DESIGN.md §6g).
//!
//! Architecture (see DESIGN.md):
//! - **L3 (this crate)** — [`api`] on top of the checkpointing training
//!   coordinator: stores only ODE-block *input* activations (O(L)), re-runs
//!   each block forward during backprop (O(Nt)) and backpropagates through
//!   the discrete time stepper (Discretize-Then-Optimize), with optional
//!   Griewank–Walther revolve schedules for tighter memory budgets. The
//!   adjoint method is a pluggable [`api::GradientStrategy`].
//! - **L2 (python/compile, build time)** — JAX ODE-block graphs AOT-lowered
//!   to HLO text, executed here via PJRT ([`runtime`]).
//! - **L1 (python/compile/kernels)** — Pallas conv kernels inside the block
//!   RHS, interpret-mode lowered into the same HLO.

pub mod api;
pub mod checkpoint;
pub mod compile;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod net;
pub mod ode;
pub mod optim;
pub mod rng;
pub mod rollout;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
