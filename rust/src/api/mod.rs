//! # `anode::api` — the crate's public surface
//!
//! A typed Engine/Session façade over the artifact registry and the
//! checkpointing coordinator:
//!
//! ```text
//! EngineBuilder ──build()──▶ Engine ──session(cfg)──▶ Session
//!   artifacts dir             owns ArtifactRegistry     owns params + SGD
//!   arch/classes/solver       eager manifest check      step / fit / evaluate
//!                             typed ModuleHandles       predict / gradcheck
//!                             StrategyRegistry
//! ```
//!
//! * **Eager validation** — `EngineBuilder::build` opens the manifest once
//!   and resolves every module the configuration can touch into typed
//!   [`ModuleHandle`]s. A missing artifact is a build-time error naming the
//!   module, not a mid-training lookup failure.
//! * **Pluggable gradients** — the adjoint method is a
//!   [`GradientStrategy`] object resolved by name through the engine's
//!   [`StrategyRegistry`]. The five paper methods (`anode`,
//!   `anode-revolve<m>`, `anode-equispaced<m>`, `node`, `otd`) are built
//!   in; new methods register a factory and require no coordinator edits.
//! * **Serving path** — [`Session::predict`] runs batched inference over
//!   pre-batched tensors with per-call latency/memory stats, via an
//!   inference-only forward that pays zero gradient bookkeeping.
//!   [`Session::predict_batches`] and [`Session::evaluate`] fan
//!   micro-batches across a persistent worker pool cached on the session
//!   (`SessionConfig::workers`; no per-call spawn), each worker metering
//!   its own [`crate::memory::MemoryLedger`], merged afterward into
//!   aggregate peak/traffic stats. For *single-request* traffic,
//!   [`Session::serve`] starts the [`crate::serve`] front end: a
//!   deadline-batched admission queue coalescing requests into the AOT
//!   batch size on a persistent worker pool, with per-request latency
//!   stats, bit-identical values to the pre-batched path, and
//!   [`Session::push_params`] hot-swapping trained weights into the
//!   running pipeline between batches.
//! * **Data-parallel training** — [`Session::step_accumulate`] (and
//!   `fit` with `SessionConfig::grad_accum`/`grad_workers`) accumulates
//!   gradients over micro-batches across the same pool, reducing in
//!   fixed micro-batch order so parameters and losses stay bit-identical
//!   to the serial run for every worker count (rust/DESIGN.md §6c).
//! * **Multi-device sharding** — [`EngineBuilder::devices`] opens one
//!   registry (PJRT client + executable cache) per device; sessions run
//!   one pool of device-pinned workers per device and route contiguous
//!   chunks to the least-loaded device, so every parallel path above
//!   scales across devices with results still bit-identical to serial
//!   for every (devices × workers) grid point. `EngineBuilder::simulate`
//!   backs the devices with the deterministic [`crate::runtime::sim`]
//!   harness so the whole stack runs offline (rust/DESIGN.md §6d).
//!
//! ## Quickstart
//!
//! ```no_run
//! use anode::api::{Engine, SessionConfig};
//!
//! let engine = Engine::builder().artifacts("artifacts").build()?;
//! let mut session = engine.session(SessionConfig::with_method("anode"))?;
//! // session.step(&images, &labels)?;     // train
//! // session.evaluate(&eval_batches)?;    // measure
//! // session.predict(&images)?;           // serve
//! # Ok::<(), anode::runtime::RuntimeError>(())
//! ```

pub mod modules;
pub mod session;
pub mod strategy;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::models::{ModelConfig, ParamIndex};
use crate::runtime::{backend_env, sim_devices_env, ArtifactRegistry, DeviceSet};

pub use crate::data::make_eval_batches;
pub use crate::models::{Arch, GradMethod, Solver};
pub use crate::optim::LrSchedule;
pub use crate::runtime::{Backend, Result, RuntimeError};
pub use modules::{ModuleHandle, ModuleSet, StageModules};
pub use session::{
    argmax_rows, head_logits, BatchPredictReport, EvalStats, FitOptions, FitReport,
    GradCheckReport, PredictStats, Prediction, Session, SessionConfig, StepStats,
};
pub use strategy::{
    BlockContext, CompiledBlockBackward, GradientStrategy, ModuleExec, StrategyRegistry,
};

/// Open an artifact registry for sharing across several engines — and,
/// since the registry is `Send + Sync`, across threads (the
/// compiled-module cache is per-registry, so multi-config drivers should
/// open once and pass the handle to each [`EngineBuilder::registry`]).
pub fn open_artifacts(dir: impl AsRef<Path>) -> Result<Arc<ArtifactRegistry>> {
    Ok(Arc::new(ArtifactRegistry::open(dir.as_ref())?))
}

/// Builder for [`Engine`]: where the artifacts live and which model
/// configuration to validate against.
pub struct EngineBuilder {
    artifacts: PathBuf,
    registry: Option<Arc<ArtifactRegistry>>,
    arch: Arch,
    num_classes: usize,
    solver: Solver,
    strategies: StrategyRegistry,
    devices: Option<usize>,
    simulate: bool,
    backend: Option<Backend>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            registry: None,
            arch: Arch::Resnet,
            num_classes: 10,
            solver: Solver::Euler,
            strategies: StrategyRegistry::builtin(),
            devices: None,
            simulate: false,
            backend: None,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Directory holding `manifest.json`, `params.bin` and the HLO
    /// artifacts (default: `artifacts`). Ignored if
    /// [`EngineBuilder::registry`] supplies an open registry.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Share an already-open registry (and its compiled-module cache)
    /// instead of opening `artifacts` again.
    pub fn registry(mut self, reg: Arc<ArtifactRegistry>) -> Self {
        self.registry = Some(reg);
        self
    }

    /// Architecture family (default: ResNet-like).
    pub fn arch(mut self, arch: Arch) -> Self {
        self.arch = arch;
        self
    }

    /// Classifier width (default: 10).
    pub fn classes(mut self, num_classes: usize) -> Self {
        self.num_classes = num_classes;
        self
    }

    /// ODE solver baked into the block artifacts (default: Euler).
    pub fn solver(mut self, solver: Solver) -> Self {
        self.solver = solver;
        self
    }

    /// Replace the strategy registry (e.g. to add custom gradient
    /// methods before any session exists).
    pub fn strategies(mut self, strategies: StrategyRegistry) -> Self {
        self.strategies = strategies;
        self
    }

    /// Number of devices to shard over (default 1; see rust/DESIGN.md
    /// §6d). The engine opens one registry — one PJRT client and one
    /// executable cache — per device; sessions route their parallel paths
    /// across per-device worker pools. When no explicit count (and no
    /// shared [`EngineBuilder::registry`]) is given, `ANODE_SIM_DEVICES`
    /// sets the default, so the whole suite can run against a simulated
    /// multi-device topology.
    pub fn devices(mut self, devices: usize) -> Self {
        self.devices = Some(devices.max(1));
        self
    }

    /// Execute through the deterministic simulation backend
    /// ([`crate::runtime::sim`]) instead of PJRT — the offline
    /// multi-device harness: values depend only on (module, inputs), so
    /// train/predict/serve run on the vendored xla stub with bit-stable
    /// numbers. Ignored when [`EngineBuilder::registry`] supplies an open
    /// registry (the supplied registry's mode wins).
    pub fn simulate(mut self, yes: bool) -> Self {
        self.simulate = yes;
        self
    }

    /// Select the execution backend explicitly. Resolution order when
    /// building without a shared registry: this call, else the
    /// `ANODE_BACKEND` env var (`compiled` | `sim` | `xla` — how CI flips
    /// the whole suite onto the compiled backend), else
    /// [`EngineBuilder::simulate`] (a legacy alias for
    /// [`Backend::Sim`]), else PJRT. A shared [`EngineBuilder::registry`]
    /// keeps its own backend regardless.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Open (or adopt) the registry, validate the manifest against the
    /// requested configuration, and resolve every module name into typed
    /// handles. All validation is eager: a broken or incomplete artifact
    /// set fails here, with the offending module/param named.
    pub fn build(self) -> Result<Engine> {
        let devices = match self.registry {
            Some(r) => {
                // A shared registry pins device 0; extra devices (explicit
                // only — the env default never multiplies a shared
                // registry) open from the same artifact dir and mode.
                match self.devices.unwrap_or(1) {
                    0 | 1 => DeviceSet::single(r),
                    n => DeviceSet::with_primary(r, n)?,
                }
            }
            None => {
                let count = self.devices.or_else(sim_devices_env).unwrap_or(1);
                let backend = self.backend.or_else(backend_env).unwrap_or(if self.simulate {
                    Backend::Sim
                } else {
                    Backend::Xla
                });
                DeviceSet::open_with_backend(&self.artifacts, count, backend)?
            }
        };
        let reg = devices.primary();
        let cfg = ModelConfig::from_registry(reg, self.arch, self.num_classes)?;
        // Params: key exists and its layout matches the model structure.
        let layout = reg.param_layout(&cfg.params_key())?;
        let _ = ParamIndex::from_layout(layout, &cfg)?;
        // Modules: every reachable name resolves, with arity captured.
        let modules = ModuleSet::resolve(reg, &cfg, self.solver)?;
        Ok(Engine { devices, cfg, solver: self.solver, modules, strategies: self.strategies })
    }
}

/// A validated, ready-to-serve model configuration: the open artifact
/// registries (one per device — see [`DeviceSet`]), the resolved module
/// handles, and the gradient-strategy registry. Sessions borrow the
/// engine, so one engine can back many concurrent sessions sharing one
/// compiled-module cache per device — and since the engine is `Sync`,
/// those sessions can live on different threads (see the "Concurrency
/// model" section of rust/DESIGN.md; multi-device sharding is §6d).
pub struct Engine {
    devices: DeviceSet,
    cfg: ModelConfig,
    solver: Solver,
    modules: ModuleSet,
    strategies: StrategyRegistry,
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Open a training/inference session with its own parameters and
    /// optimizer state. Fails fast if the manifest lacks the block-module
    /// kinds the configured gradient strategy needs.
    pub fn session(&self, config: SessionConfig) -> Result<Session<'_>> {
        Session::new(self, config)
    }

    /// Model shape (read from the manifest at build time).
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The ODE solver this engine's block artifacts were lowered with.
    pub fn solver(&self) -> Solver {
        self.solver
    }

    /// Resolved module handles.
    pub fn modules(&self) -> &ModuleSet {
        &self.modules
    }

    /// The gradient-strategy registry.
    pub fn strategies(&self) -> &StrategyRegistry {
        &self.strategies
    }

    /// Mutable registry access, to plug in strategies after build.
    pub fn strategies_mut(&mut self) -> &mut StrategyRegistry {
        &mut self.strategies
    }

    /// Borrow the underlying artifact registry (advanced: direct module
    /// calls outside the model structure, e.g. the tiny gradcheck blocks).
    /// With multiple devices this is the **primary** (device 0) registry.
    pub fn registry(&self) -> &ArtifactRegistry {
        self.devices.primary()
    }

    /// Share the primary registry with another engine builder (or another
    /// thread).
    pub fn shared_registry(&self) -> Arc<ArtifactRegistry> {
        self.devices.primary().clone()
    }

    /// The engine's device topology: one registry (client + executable
    /// cache) per device. Single-device engines have a one-entry set.
    pub fn device_set(&self) -> &DeviceSet {
        &self.devices
    }

    /// Devices this engine shards over (>= 1).
    pub fn device_count(&self) -> usize {
        self.devices.count()
    }
}

// Sessions on worker threads hold `&Engine`; losing Sync here would
// silently serialize the whole serving path, so assert it at compile time.
const _: () = {
    const fn assert_sync<T: Sync>() {}
    assert_sync::<Engine>();
};
