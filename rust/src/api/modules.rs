//! Typed module resolution — the `api` layer that replaces the seed's
//! scattered `format!("trans{s}_fwd")` string lookups.
//!
//! [`ModuleSet::resolve`] walks the model structure once, at engine build
//! time, and turns every module the model can ever need into a validated
//! [`ModuleHandle`]. Anything missing from the manifest is reported
//! eagerly, with the module name and the config that wanted it, instead of
//! surfacing as a mid-training lookup failure.

use std::collections::HashMap;

use crate::models::{ModelConfig, Solver};
use crate::runtime::{ArtifactRegistry, Result, RuntimeError};

/// A module name that has been checked against the artifact manifest.
///
/// Holding a `ModuleHandle` is proof that the module exists and records its
/// manifest arity, so call sites get typed errors instead of stringly-typed
/// lookups.
#[derive(Debug, Clone)]
pub struct ModuleHandle {
    name: String,
    n_inputs: usize,
    n_outputs: usize,
}

impl ModuleHandle {
    /// Resolve `name` against the manifest, capturing its arity.
    pub fn resolve(reg: &ArtifactRegistry, name: &str) -> Result<Self> {
        let spec = reg.module_spec(name).map_err(|_| {
            RuntimeError::Io(format!(
                "manifest has no module `{name}` — re-run `make artifacts`"
            ))
        })?;
        Ok(Self {
            name: name.to_string(),
            n_inputs: spec.inputs.len(),
            n_outputs: spec.outputs.len(),
        })
    }

    /// The manifest module name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of inputs the manifest declares.
    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Number of outputs the manifest declares.
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }
}

/// The block-module kinds a gradient strategy can ask for.
///
/// `fwd` is required of every config; the rest are resolved when present
/// and demanded lazily by [`StageModules::require`].
pub const BLOCK_KINDS: [&str; 6] = ["fwd", "vjp", "step_fwd", "step_vjp", "node", "otd"];

/// Resolved ODE-block modules for one stage, keyed by kind.
#[derive(Debug, Clone)]
pub struct StageModules {
    stage: usize,
    kinds: HashMap<&'static str, ModuleHandle>,
}

impl StageModules {
    /// Handle for `kind` if the manifest provides it.
    pub fn get(&self, kind: &str) -> Option<&ModuleHandle> {
        self.kinds.get(kind)
    }

    /// Handle for `kind`, or a typed error naming the stage and kind —
    /// raised when a gradient strategy demands artifacts the manifest
    /// was not built with.
    pub fn require(&self, kind: &str) -> Result<&ModuleHandle> {
        self.kinds.get(kind).ok_or_else(|| {
            RuntimeError::Io(format!(
                "stage {}: no `{kind}` block module in manifest — \
                 re-run `make artifacts` with this kind enabled",
                self.stage
            ))
        })
    }

    /// Kinds the manifest provides for this stage (sorted).
    pub fn available_kinds(&self) -> Vec<&'static str> {
        let mut v: Vec<&'static str> = self.kinds.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

/// Transition modules between two stages.
#[derive(Debug, Clone)]
pub struct TransModules {
    pub fwd: ModuleHandle,
    pub vjp: ModuleHandle,
}

/// Every module a `(arch, solver, num_classes)` configuration can touch,
/// resolved and arity-checked against the manifest in one eager pass.
#[derive(Debug, Clone)]
pub struct ModuleSet {
    pub stem_fwd: ModuleHandle,
    pub stem_vjp: ModuleHandle,
    /// trans[s] sits between stage s and s+1.
    pub trans: Vec<TransModules>,
    pub head_loss_grad: ModuleHandle,
    pub head_eval: ModuleHandle,
    /// stages[s] = the ODE-block modules of stage s, by kind.
    pub stages: Vec<StageModules>,
}

impl ModuleSet {
    /// Resolve the full module surface for `cfg` under `solver`.
    ///
    /// Required: stem fwd/vjp, every transition fwd/vjp, both head modules
    /// and each stage's `fwd` block. Optional kinds (`vjp`, `step_fwd`,
    /// `step_vjp`, `node`, `otd`) are resolved when present; gradient
    /// strategies demand them at session creation via
    /// [`StageModules::require`].
    pub fn resolve(reg: &ArtifactRegistry, cfg: &ModelConfig, solver: Solver) -> Result<Self> {
        let stem_fwd = ModuleHandle::resolve(reg, "stem_fwd")?;
        let stem_vjp = ModuleHandle::resolve(reg, "stem_vjp")?;

        let mut trans = Vec::new();
        for s in 0..cfg.stages().saturating_sub(1) {
            trans.push(TransModules {
                fwd: ModuleHandle::resolve(reg, &format!("trans{s}_fwd"))?,
                vjp: ModuleHandle::resolve(reg, &format!("trans{s}_vjp"))?,
            });
        }

        let head_loss_grad =
            ModuleHandle::resolve(reg, &format!("head{}_loss_grad", cfg.num_classes))?;
        let head_eval = ModuleHandle::resolve(reg, &format!("head{}_eval", cfg.num_classes))?;

        let mut stages = Vec::new();
        for s in 0..cfg.stages() {
            let mut kinds = HashMap::new();
            for kind in BLOCK_KINDS {
                let name = cfg.block_module(s, solver, kind);
                if reg.has_module(&name) {
                    kinds.insert(kind, ModuleHandle::resolve(reg, &name)?);
                } else if kind == "fwd" {
                    return Err(RuntimeError::Io(format!(
                        "manifest has no module `{name}` (required forward block for \
                         arch={} solver={} stage={s}) — re-run `make artifacts`",
                        cfg.arch.name(),
                        solver.name()
                    )));
                }
            }
            stages.push(StageModules { stage: s, kinds });
        }

        Ok(Self { stem_fwd, stem_vjp, trans, head_loss_grad, head_eval, stages })
    }

    /// Total number of resolved handles (diagnostics).
    pub fn handle_count(&self) -> usize {
        4 + 2 * self.trans.len() + self.stages.iter().map(|s| s.kinds.len()).sum::<usize>()
    }
}
