//! Pluggable gradient strategies — the open axis that replaces the seed's
//! closed `GradMethod` match in `coordinator/backward.rs`.
//!
//! Each adjoint method of the paper is one [`GradientStrategy`] object:
//!
//! * `anode` — fused DTO VJP per block (O(Nt) inside the call);
//! * `anode-revolve<m>` / `anode-equispaced<m>` — step-level artifacts
//!   driven through a [`crate::checkpoint`] schedule under an m-slot budget;
//! * `node` — the [8] reverse-time augmented solve;
//! * `otd` — the inconsistent optimize-then-discretize adjoint (§IV).
//!
//! Strategies are constructed by name through a [`StrategyRegistry`], so new
//! adjoint methods (symplectic adjoints, interpolation schemes, ...) plug in
//! by registering a factory — no coordinator edits required.
//!
//! Strategies and factories are `Send + Sync`: one strategy object lives in
//! the shared [`crate::coordinator::ExecutionCore`] and is invoked from
//! whichever thread runs the backward pass, so all per-call scratch state
//! stays on the stack of `block_backward`.

use std::sync::Arc;

use crate::checkpoint::{
    interp_coeffs, interp_nodes, plan, run_backward, Schedule, Strategy as CheckpointStrategy,
};
use crate::memory::{Category, MemoryLedger};
use crate::models::{parse_budget, GradMethod};
use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::modules::{ModuleHandle, StageModules};

/// Executes resolved modules. Implemented by the coordinator; the
/// indirection keeps strategies independent of coordinator internals.
pub trait ModuleExec {
    fn call_module(&self, handle: &ModuleHandle, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;
}

/// Everything a strategy needs to backpropagate through one ODE block.
pub struct BlockContext<'a> {
    /// Module executor (the coordinator).
    pub exec: &'a dyn ModuleExec,
    /// Resolved block modules of this stage, by kind.
    pub modules: &'a StageModules,
    /// Discrete time steps per block.
    pub nt: usize,
    /// Block input activation z(0) (stored by the forward pass).
    pub z_in: &'a Tensor,
    /// Block output activation z(1) (used by `node` only).
    pub z_out: &'a Tensor,
    /// This block's parameter tensors, in artifact order.
    pub theta: &'a [&'a Tensor],
    /// Canonical parameter indices matching `theta` (into `grads`).
    pub pidx: &'a [usize],
    /// Interior trajectory node states captured by a stepwise forward
    /// (strategies returning `Some` from
    /// [`GradientStrategy::forward_nodes`]), in increasing time order,
    /// endpoints excluded (`z_in`/`z_out` are always held). Empty for
    /// every other strategy.
    pub nodes: &'a [Arc<Tensor>],
}

/// How a strategy's block backward lowers into a compiled
/// [`crate::compile::TrainProgram`] — the shape of the calls, decoupled
/// from the `required_kinds` strings (a custom strategy may declare
/// `["vjp"]` yet compute something else entirely, so the compiled
/// backend never guesses from kinds; it only lowers strategies that
/// opt in through this seam).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompiledBlockBackward {
    /// One fused call `(z_in, θ..., gz) -> (gz, gθ...)` on the module of
    /// this kind (`anode`, `otd`).
    Fused { kind: &'static str },
    /// One call `(z_out, θ..., gz) -> (gz, gθ..., z0_rec)` starting from
    /// the block output (`node`); the reconstruction is dead in training.
    FromOutput { kind: &'static str },
    /// `step_fwd`/`step_vjp` unrolled through the strategy's
    /// [`GradientStrategy::checkpoint_schedule`].
    Checkpointed,
    /// Stepwise `step_fwd` forward capturing a sparse trajectory-node grid,
    /// then a `step_vjp` backward whose step inputs are barycentric
    /// interpolations of the pinned node states (`interp-adjoint<p>`).
    /// The interpolation coefficients are const-folded into the plan;
    /// `nodes` is the requested node count p.
    Interpolated { nodes: usize },
}

/// One adjoint method, dispatched per ODE block in reverse network order.
///
/// `Send + Sync` is part of the contract: the strategy object is owned by
/// the shared execution core and may be called from any worker thread, so
/// implementations must keep per-call state local to `block_backward`.
pub trait GradientStrategy: Send + Sync {
    /// Canonical spec name (`anode-revolve3`, ...) — round-trips through
    /// [`StrategyRegistry::create`].
    fn name(&self) -> String;

    /// Block-module kinds this strategy calls; validated against the
    /// manifest when a session is created (fail-fast, not mid-backward).
    fn required_kinds(&self) -> &'static [&'static str];

    /// The checkpoint schedule this strategy drives its backward with,
    /// for a block of `nt` steps — `None` for strategies that do not
    /// checkpoint (fused VJP, reverse-time solve). The compiled backend
    /// uses this to turn checkpointed activations into long-lived arena
    /// slots and recompute segments into statically unrolled replays.
    fn checkpoint_schedule(&self, _nt: usize) -> Option<Schedule> {
        None
    }

    /// Trajectory node indices (into states `0..=nt`) this strategy needs
    /// captured during the FORWARD pass. `Some` switches the coordinator
    /// to a stepwise block forward via `step_fwd`, storing the listed
    /// interior states into `ForwardState` (the endpoints are always
    /// held as block inputs/outputs). `None` — the default — keeps the
    /// fused single-call forward.
    fn forward_nodes(&self, _nt: usize) -> Option<Vec<usize>> {
        None
    }

    /// How this strategy lowers into a compiled training plan. `None`
    /// (the default) keeps sessions on the interpreter path even under
    /// `Backend::Compiled` — correct for plugged-in custom strategies
    /// the compiler cannot know the semantics of.
    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        None
    }

    /// Backward through one ODE block: consume dL/d(z_out), write this
    /// block's parameter gradients into `grads[ctx.pidx]`, return
    /// dL/d(z_in).
    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor>;
}

/// Split a VJP output list (gz, gθ...) into the returned gz and the block's
/// parameter gradients. Arity must match exactly.
fn distribute(outs: Vec<Tensor>, pidx: &[usize], grads: &mut [Tensor]) -> Result<Tensor> {
    if outs.len() != pidx.len() + 1 {
        return Err(RuntimeError::Shape(format!(
            "vjp output arity mismatch: got {} outputs, expected {} (gz + {} param grads)",
            outs.len(),
            pidx.len() + 1,
            pidx.len()
        )));
    }
    let mut it = outs.into_iter();
    let gz = it.next().ok_or_else(|| RuntimeError::Shape("vjp returned nothing".into()))?;
    for &i in pidx {
        let g = it
            .next()
            .ok_or_else(|| RuntimeError::Shape("vjp output arity mismatch".into()))?;
        grads[i] = g;
    }
    Ok(gz)
}

/// ANODE (the paper): fused DTO VJP, the O(Nt) trajectory lives in the
/// executable's working set for the duration of the call.
pub struct AnodeStrategy;

impl GradientStrategy for AnodeStrategy {
    fn name(&self) -> String {
        "anode".into()
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["vjp"]
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::Fused { kind: "vjp" })
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        fused_backward(ctx, "vjp", gz, grads, ledger)
    }
}

/// Optimize-then-discretize adjoint (§IV) — same call shape as `anode`,
/// inconsistent gradient (O(dt) error).
pub struct OtdStrategy;

impl GradientStrategy for OtdStrategy {
    fn name(&self) -> String {
        "otd".into()
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["otd"]
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::Fused { kind: "otd" })
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        fused_backward(ctx, "otd", gz, grads, ledger)
    }
}

/// Shared body of the fused strategies: one artifact call whose working set
/// the ledger models as StepState held for the duration.
fn fused_backward(
    ctx: &BlockContext<'_>,
    kind: &str,
    gz: Tensor,
    grads: &mut [Tensor],
    ledger: &mut MemoryLedger,
) -> Result<Tensor> {
    let handle = ctx.modules.require(kind)?;
    let nt_cost = ctx.nt * ctx.z_in.byte_size();
    let tid = ledger.alloc(nt_cost, Category::StepState);
    let mut args: Vec<&Tensor> = vec![ctx.z_in];
    args.extend(ctx.theta.iter().copied());
    args.push(&gz);
    let outs = ctx.exec.call_module(handle, &args);
    // Free before propagating: the session's ledger outlives this call, so
    // an error must not leak a phantom StepState allocation.
    ledger.free(tid);
    distribute(outs?, ctx.pidx, grads)
}

/// Neural-ODE [8]: start from the block OUTPUT and reconstruct backwards.
/// No trajectory storage at all — that is its selling point, and its
/// failure mode (§III).
pub struct NodeStrategy;

impl GradientStrategy for NodeStrategy {
    fn name(&self) -> String {
        "node".into()
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["node"]
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::FromOutput { kind: "node" })
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        _ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        let handle = ctx.modules.require("node")?;
        let mut args: Vec<&Tensor> = vec![ctx.z_out];
        args.extend(ctx.theta.iter().copied());
        args.push(&gz);
        let mut outs = ctx.exec.call_module(handle, &args)?;
        if outs.len() != ctx.pidx.len() + 2 {
            return Err(RuntimeError::Shape(format!(
                "{}: returned {} outputs, expected {} (gz + {} param grads + z0_rec)",
                handle.name(),
                outs.len(),
                ctx.pidx.len() + 2,
                ctx.pidx.len()
            )));
        }
        // Last output is z0_rec (the reconstruction); analysis harnesses
        // inspect its error explicitly, the training path drops it.
        outs.truncate(outs.len() - 1);
        distribute(outs, ctx.pidx, grads)
    }
}

/// ANODE with an in-block checkpoint schedule: `step_fwd` / `step_vjp`
/// artifacts driven by the revolve executor under an m-slot budget.
pub struct CheckpointedStrategy {
    schedule: CheckpointStrategy,
    m: usize,
}

impl CheckpointedStrategy {
    /// Griewank–Walther revolve under an m-slot budget.
    pub fn revolve(m: usize) -> Result<Self> {
        Self::new(CheckpointStrategy::Revolve(m), m)
    }

    /// Equispaced checkpoints under an m-slot budget.
    pub fn equispaced(m: usize) -> Result<Self> {
        Self::new(CheckpointStrategy::Equispaced(m), m)
    }

    fn new(schedule: CheckpointStrategy, m: usize) -> Result<Self> {
        if m < 1 {
            return Err(RuntimeError::Io(format!(
                "checkpoint budget must be >= 1 slot, got m={m}"
            )));
        }
        Ok(Self { schedule, m })
    }
}

impl GradientStrategy for CheckpointedStrategy {
    fn name(&self) -> String {
        match self.schedule {
            CheckpointStrategy::Revolve(m) => format!("anode-revolve{m}"),
            CheckpointStrategy::Equispaced(m) => format!("anode-equispaced{m}"),
            _ => format!("anode-checkpointed{}", self.m),
        }
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["step_fwd", "step_vjp"]
    }

    fn checkpoint_schedule(&self, nt: usize) -> Option<Schedule> {
        Some(plan(self.schedule, nt))
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::Checkpointed)
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        // Single source of truth with the compiled lowering: both paths
        // drive the exact schedule this seam hands out.
        let schedule = self
            .checkpoint_schedule(ctx.nt)
            .expect("checkpointed strategy always has a schedule");
        scheduled_backward(ctx, &schedule, gz, grads, ledger)
    }
}

/// Shared body of the schedule-driven strategies (`anode-revolve<m>`,
/// `anode-equispaced<m>`, `symplectic`): `step_fwd`/`step_vjp` artifacts
/// driven through a checkpoint schedule by the revolve executor.
fn scheduled_backward(
    ctx: &BlockContext<'_>,
    schedule: &Schedule,
    gz: Tensor,
    grads: &mut [Tensor],
    ledger: &mut MemoryLedger,
) -> Result<Tensor> {
    let errs = schedule.validate();
    if !errs.is_empty() {
        return Err(RuntimeError::Io(format!("invalid schedule: {}", errs.join("; "))));
    }

    let fwd = ctx.modules.require("step_fwd")?;
    let vjp = ctx.modules.require("step_vjp")?;
    let mut theta_grads: Vec<Tensor> =
        ctx.pidx.iter().map(|&i| Tensor::zeros(grads[i].shape())).collect();
    // The revolve executor's callbacks are infallible; the first module
    // error is parked here and re-raised after the sweep. Call-local
    // state, so it has no bearing on the strategy object's Sync-ness;
    // a OnceCell keeps exactly the first error with no locking.
    let call_err: std::cell::OnceCell<RuntimeError> = std::cell::OnceCell::new();
    let record = |e: RuntimeError| {
        let _ = call_err.set(e);
    };

    // Ledger: model peak as (schedule slots + 1 tape) states of this
    // block's size — m+1 for revolve/equispaced(m), nt+2 for the
    // store-everything schedule.
    let act = ctx.z_in.byte_size();
    let tid =
        ledger.alloc((schedule.strategy.slots(schedule.nt) + 1) * act, Category::StepState);

    let step = |z: &Tensor| -> Tensor {
        let mut args: Vec<&Tensor> = vec![z];
        args.extend(ctx.theta.iter().copied());
        match ctx.exec.call_module(fwd, &args) {
            Ok(mut o) => o.remove(0),
            Err(e) => {
                record(e);
                Tensor::zeros(z.shape())
            }
        }
    };

    let step_grad = |z: &Tensor, a: &Tensor| -> Tensor {
        let mut args: Vec<&Tensor> = vec![z];
        args.extend(ctx.theta.iter().copied());
        args.push(a);
        match ctx.exec.call_module(vjp, &args) {
            Ok(mut outs) => {
                if outs.len() != ctx.pidx.len() + 1 {
                    record(RuntimeError::Shape(format!(
                        "{}: returned {} outputs, expected {} (gz + {} param grads)",
                        vjp.name(),
                        outs.len(),
                        ctx.pidx.len() + 1,
                        ctx.pidx.len()
                    )));
                    return Tensor::zeros(z.shape());
                }
                let gz_step = outs.remove(0);
                for (acc, g) in theta_grads.iter_mut().zip(outs.into_iter()) {
                    if let Err(e) = acc.axpy(1.0, &g) {
                        record(RuntimeError::Shape(format!("{}: {e}", vjp.name())));
                    }
                }
                gz_step
            }
            Err(e) => {
                record(e);
                Tensor::zeros(z.shape())
            }
        }
    };

    let swept =
        run_backward(schedule, ctx.z_in, gz, step, step_grad, |_| {}).map_err(RuntimeError::Io);
    // Free before propagating: the session's ledger outlives this call.
    ledger.free(tid);

    if let Some(e) = call_err.into_inner() {
        return Err(e);
    }
    let g_in = swept?;
    for (&i, tg) in ctx.pidx.iter().zip(theta_grads.into_iter()) {
        grads[i] = tg;
    }
    Ok(g_in)
}

/// Symplectic adjoint (Matsubara et al., 2021 — see PAPERS.md): the
/// backward sweep consumes the exact stored forward trajectory through the
/// paired integrator, so gradients are exact to machine precision with
/// zero recomputed steps. In this discrete harness that is precisely the
/// step-level adjoint under a store-everything schedule: `step_fwd` tapes
/// all `nt` states once, `step_vjp` replays them in reverse — the
/// no-recompute endpoint of the `anode-revolve<m>` memory/compute axis.
pub struct SymplecticStrategy;

impl GradientStrategy for SymplecticStrategy {
    fn name(&self) -> String {
        "symplectic".into()
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["step_fwd", "step_vjp"]
    }

    fn checkpoint_schedule(&self, nt: usize) -> Option<Schedule> {
        Some(plan(CheckpointStrategy::StoreAll, nt))
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::Checkpointed)
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        let schedule = self
            .checkpoint_schedule(ctx.nt)
            .expect("symplectic strategy always has a schedule");
        scheduled_backward(ctx, &schedule, gz, grads, ledger)
    }
}

/// Interpolated adjoint (Daulbaev et al., 2020 — see PAPERS.md): the
/// forward pass stores a sparse `p`-node grid of trajectory states
/// (captured stepwise via [`GradientStrategy::forward_nodes`]); the
/// backward reconstructs every step input by barycentric Lagrange
/// interpolation over those nodes — no recomputation, O(p) extra storage
/// per block instead of O(Nt), accuracy set by the interpolation error
/// (`p == nt + 1` is exact).
pub struct InterpAdjointStrategy {
    p: usize,
}

impl InterpAdjointStrategy {
    /// `p`-node interpolation grid. Both endpoints are always nodes, so
    /// `p >= 2` is required.
    pub fn new(p: usize) -> Result<Self> {
        if p < 2 {
            return Err(RuntimeError::Io(format!(
                "interp-adjoint needs >= 2 interpolation nodes (both endpoints), got p={p}"
            )));
        }
        Ok(Self { p })
    }
}

impl GradientStrategy for InterpAdjointStrategy {
    fn name(&self) -> String {
        format!("interp-adjoint{}", self.p)
    }

    fn required_kinds(&self) -> &'static [&'static str] {
        &["step_fwd", "step_vjp"]
    }

    fn forward_nodes(&self, nt: usize) -> Option<Vec<usize>> {
        Some(interp_nodes(nt, self.p))
    }

    fn compiled_backward(&self) -> Option<CompiledBlockBackward> {
        Some(CompiledBlockBackward::Interpolated { nodes: self.p })
    }

    fn block_backward(
        &self,
        ctx: &BlockContext<'_>,
        gz: Tensor,
        grads: &mut [Tensor],
        ledger: &mut MemoryLedger,
    ) -> Result<Tensor> {
        let vjp = ctx.modules.require("step_vjp")?;
        let nodes = interp_nodes(ctx.nt, self.p);
        // Interior node states come from the stepwise forward; endpoints
        // are the block input/output the coordinator holds anyway.
        let interior = nodes.iter().filter(|&&t| t != 0 && t != ctx.nt).count();
        if ctx.nodes.len() != interior {
            return Err(RuntimeError::Shape(format!(
                "{}: forward captured {} interior node states, expected {}",
                self.name(),
                ctx.nodes.len(),
                interior
            )));
        }
        let mut by_node: Vec<&Tensor> = Vec::with_capacity(nodes.len());
        let mut next_interior = 0usize;
        for &t in &nodes {
            if t == 0 {
                by_node.push(ctx.z_in);
            } else if t == ctx.nt {
                by_node.push(ctx.z_out);
            } else {
                by_node.push(ctx.nodes[next_interior].as_ref());
                next_interior += 1;
            }
        }

        let mut theta_grads: Vec<Tensor> =
            ctx.pidx.iter().map(|&i| Tensor::zeros(grads[i].shape())).collect();
        // Backward transient: one reconstructed state at a time (the node
        // storage itself is metered as BlockInput by the forward pass).
        let act = ctx.z_in.byte_size();
        let tid = ledger.alloc(act, Category::StepState);
        // Immediately-invoked so the ledger free below runs on every exit
        // path — the session's ledger outlives this call.
        let swept = (|| -> Result<Tensor> {
            let mut adj = gz;
            for t in (0..ctx.nt).rev() {
                // At a node the stored tensor is used directly (bitwise),
                // matching the compiled plan's aliasing of node slots.
                let zt_owned;
                let zt: &Tensor = match nodes.iter().position(|&x| x == t) {
                    Some(j) => by_node[j],
                    None => {
                        let coeffs = interp_coeffs(&nodes, t);
                        let mut acc = Tensor::zeros(ctx.z_in.shape());
                        for (&c, &node) in coeffs.iter().zip(by_node.iter()) {
                            acc.axpy(c, node).map_err(|e| {
                                RuntimeError::Shape(format!("{}: node mix: {e}", self.name()))
                            })?;
                        }
                        zt_owned = acc;
                        &zt_owned
                    }
                };
                let mut args: Vec<&Tensor> = vec![zt];
                args.extend(ctx.theta.iter().copied());
                args.push(&adj);
                let mut outs = ctx.exec.call_module(vjp, &args)?;
                if outs.len() != ctx.pidx.len() + 1 {
                    return Err(RuntimeError::Shape(format!(
                        "{}: returned {} outputs, expected {} (gz + {} param grads)",
                        vjp.name(),
                        outs.len(),
                        ctx.pidx.len() + 1,
                        ctx.pidx.len()
                    )));
                }
                adj = outs.remove(0);
                for (acc, g) in theta_grads.iter_mut().zip(outs.into_iter()) {
                    acc.axpy(1.0, &g)
                        .map_err(|e| RuntimeError::Shape(format!("{}: {e}", vjp.name())))?;
                }
            }
            Ok(adj)
        })();
        ledger.free(tid);

        let g_in = swept?;
        for (&i, tg) in ctx.pidx.iter().zip(theta_grads.into_iter()) {
            grads[i] = tg;
        }
        Ok(g_in)
    }
}

/// A factory tries to construct a strategy from a spec string. `None`
/// means "not my pattern"; `Some(Err)` means "my pattern, invalid value"
/// (e.g. a zero checkpoint budget). Factories are `Send + Sync` so one
/// engine (and its registry) can serve sessions on many threads.
type Factory = Box<dyn Fn(&str) -> Option<Result<Box<dyn GradientStrategy>>> + Send + Sync>;

/// Name-indexed registry of gradient-strategy factories.
pub struct StrategyRegistry {
    factories: Vec<(String, Factory)>,
}

impl StrategyRegistry {
    /// Empty registry (no built-ins).
    pub fn empty() -> Self {
        Self { factories: Vec::new() }
    }

    /// Registry with the seven built-in methods: the paper's five plus
    /// the symplectic (Matsubara 2021) and interpolated (Daulbaev 2020)
    /// adjoints from the related literature.
    pub fn builtin() -> Self {
        let mut reg = Self::empty();
        reg.register("anode", |spec| {
            (spec == "anode").then(|| Ok(Box::new(AnodeStrategy) as Box<dyn GradientStrategy>))
        });
        reg.register("node", |spec| {
            (spec == "node").then(|| Ok(Box::new(NodeStrategy) as Box<dyn GradientStrategy>))
        });
        reg.register("otd", |spec| {
            (spec == "otd").then(|| Ok(Box::new(OtdStrategy) as Box<dyn GradientStrategy>))
        });
        reg.register("anode-revolve<m>", |spec| {
            parse_budget(spec, "anode-revolve").map(|m| {
                m.and_then(|m| {
                    CheckpointedStrategy::revolve(m)
                        .map(|s| Box::new(s) as Box<dyn GradientStrategy>)
                })
            })
        });
        reg.register("anode-equispaced<m>", |spec| {
            parse_budget(spec, "anode-equispaced").map(|m| {
                m.and_then(|m| {
                    CheckpointedStrategy::equispaced(m)
                        .map(|s| Box::new(s) as Box<dyn GradientStrategy>)
                })
            })
        });
        reg.register("symplectic", |spec| {
            (spec == "symplectic")
                .then(|| Ok(Box::new(SymplecticStrategy) as Box<dyn GradientStrategy>))
        });
        reg.register("interp-adjoint<p>", |spec| {
            parse_budget(spec, "interp-adjoint").map(|p| {
                p.and_then(|p| {
                    InterpAdjointStrategy::new(p).map(|s| Box::new(s) as Box<dyn GradientStrategy>)
                })
            })
        });
        reg
    }

    /// Register a factory under a human-readable pattern name. Later
    /// registrations are tried first, so callers can shadow built-ins.
    pub fn register(
        &mut self,
        pattern: &str,
        factory: impl Fn(&str) -> Option<Result<Box<dyn GradientStrategy>>> + Send + Sync + 'static,
    ) {
        self.factories.insert(0, (pattern.to_string(), Box::new(factory)));
    }

    /// Human-readable pattern names, in lookup order.
    pub fn names(&self) -> Vec<&str> {
        self.factories.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Construct the strategy named by `spec` (e.g. `"anode-revolve3"`).
    pub fn create(&self, spec: &str) -> Result<Box<dyn GradientStrategy>> {
        for (_, factory) in &self.factories {
            if let Some(result) = factory(spec) {
                return result;
            }
        }
        Err(RuntimeError::Io(format!(
            "unknown gradient method `{spec}` — registered: {}",
            self.names().join(", ")
        )))
    }

    /// Construct from a parsed [`GradMethod`] (the CLI enum).
    pub fn create_from_method(&self, method: GradMethod) -> Result<Box<dyn GradientStrategy>> {
        self.create(&method.name())
    }
}

impl Default for StrategyRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_round_trip_all_seven() {
        let reg = StrategyRegistry::builtin();
        for spec in [
            "anode",
            "node",
            "otd",
            "anode-revolve3",
            "anode-equispaced2",
            "symplectic",
            "interp-adjoint3",
        ] {
            let s = reg.create(spec).unwrap();
            assert_eq!(s.name(), spec, "round-trip failed for {spec}");
        }
    }

    #[test]
    fn create_from_method_matches_enum_name() {
        let reg = StrategyRegistry::builtin();
        for m in [
            GradMethod::Anode,
            GradMethod::Node,
            GradMethod::Otd,
            GradMethod::AnodeRevolve(4),
            GradMethod::AnodeEquispaced(5),
            GradMethod::Symplectic,
            GradMethod::InterpAdjoint(3),
        ] {
            assert_eq!(reg.create_from_method(m).unwrap().name(), m.name());
        }
    }

    #[test]
    fn degenerate_budgets_rejected() {
        let reg = StrategyRegistry::builtin();
        for spec in ["anode-revolve0", "anode-equispaced0", "interp-adjoint0"] {
            let err = reg.create(spec).unwrap_err();
            assert!(err.to_string().contains(">= 1"), "{spec}: {err}");
        }
        // A single node cannot hold both endpoints.
        let err = reg.create("interp-adjoint1").unwrap_err();
        assert!(err.to_string().contains(">= 2"), "{err}");
        assert!(CheckpointedStrategy::revolve(0).is_err());
        assert!(CheckpointedStrategy::equispaced(0).is_err());
        assert!(CheckpointedStrategy::revolve(1).is_ok());
        assert!(InterpAdjointStrategy::new(1).is_err());
        assert!(InterpAdjointStrategy::new(2).is_ok());
    }

    #[test]
    fn unknown_spec_lists_registered() {
        let reg = StrategyRegistry::builtin();
        let err = reg.create("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown gradient method"), "{err}");
        assert!(err.contains("anode-revolve<m>"), "{err}");
        // Non-numeric budget suffixes are unknown, not degenerate.
        assert!(reg.create("anode-revolveX").is_err());
    }

    #[test]
    fn custom_strategy_plugs_in_without_dispatch_edits() {
        struct Custom;
        impl GradientStrategy for Custom {
            fn name(&self) -> String {
                "custom".into()
            }
            fn required_kinds(&self) -> &'static [&'static str] {
                &["vjp"]
            }
            fn block_backward(
                &self,
                _ctx: &BlockContext<'_>,
                gz: Tensor,
                _grads: &mut [Tensor],
                _ledger: &mut MemoryLedger,
            ) -> Result<Tensor> {
                Ok(gz)
            }
        }
        let mut reg = StrategyRegistry::builtin();
        reg.register("custom", |spec| {
            (spec == "custom").then(|| Ok(Box::new(Custom) as Box<dyn GradientStrategy>))
        });
        assert_eq!(reg.create("custom").unwrap().name(), "custom");
        // Built-ins still resolve.
        assert_eq!(reg.create("anode").unwrap().name(), "anode");
    }

    #[test]
    fn compiled_seam_covers_builtins_and_defaults_off_for_custom() {
        let reg = StrategyRegistry::builtin();
        assert_eq!(
            reg.create("anode").unwrap().compiled_backward(),
            Some(CompiledBlockBackward::Fused { kind: "vjp" })
        );
        assert_eq!(
            reg.create("otd").unwrap().compiled_backward(),
            Some(CompiledBlockBackward::Fused { kind: "otd" })
        );
        assert_eq!(
            reg.create("node").unwrap().compiled_backward(),
            Some(CompiledBlockBackward::FromOutput { kind: "node" })
        );
        for spec in ["anode-revolve3", "anode-equispaced2", "symplectic"] {
            let s = reg.create(spec).unwrap();
            assert_eq!(s.compiled_backward(), Some(CompiledBlockBackward::Checkpointed));
            let schedule = s.checkpoint_schedule(8).expect("checkpointed strategies plan");
            assert_eq!(schedule.nt, 8);
            assert!(schedule.validate().is_empty(), "{spec} emits a valid schedule");
        }
        // Symplectic's schedule is store-everything: zero recomputation.
        let symp = reg.create("symplectic").unwrap().checkpoint_schedule(8).unwrap();
        assert_eq!(symp.strategy, CheckpointStrategy::StoreAll);
        assert_eq!(symp.forward_evals(), 8, "symplectic never recomputes a step");
        // The interpolated adjoint lowers through its own seam: node
        // count in the variant, stepwise forward capture, no schedule.
        let interp = reg.create("interp-adjoint3").unwrap();
        assert_eq!(
            interp.compiled_backward(),
            Some(CompiledBlockBackward::Interpolated { nodes: 3 })
        );
        assert!(interp.checkpoint_schedule(8).is_none());
        assert_eq!(interp.forward_nodes(8), Some(vec![0, 4, 8]));
        // Fused/solve/scheduled strategies run a fused forward.
        for spec in ["anode", "node", "otd", "anode-revolve3", "symplectic"] {
            assert!(reg.create(spec).unwrap().forward_nodes(8).is_none(), "{spec}");
        }
        // Fused/solve strategies do not checkpoint.
        assert!(reg.create("anode").unwrap().checkpoint_schedule(8).is_none());
        assert!(reg.create("node").unwrap().checkpoint_schedule(8).is_none());

        // A plugged-in strategy with a familiar kind string must NOT be
        // lowered by kind-matching: the default seam keeps it on the
        // interpreter, where its (arbitrary) semantics are honored.
        struct Custom;
        impl GradientStrategy for Custom {
            fn name(&self) -> String {
                "custom".into()
            }
            fn required_kinds(&self) -> &'static [&'static str] {
                &["vjp"]
            }
            fn block_backward(
                &self,
                _ctx: &BlockContext<'_>,
                gz: Tensor,
                _grads: &mut [Tensor],
                _ledger: &mut MemoryLedger,
            ) -> Result<Tensor> {
                Ok(gz)
            }
        }
        assert_eq!(Custom.compiled_backward(), None);
        assert!(Custom.checkpoint_schedule(8).is_none());
    }

    #[test]
    fn required_kinds_per_strategy() {
        let reg = StrategyRegistry::builtin();
        assert_eq!(reg.create("anode").unwrap().required_kinds(), &["vjp"]);
        assert_eq!(reg.create("node").unwrap().required_kinds(), &["node"]);
        assert_eq!(reg.create("otd").unwrap().required_kinds(), &["otd"]);
        assert_eq!(
            reg.create("anode-revolve2").unwrap().required_kinds(),
            &["step_fwd", "step_vjp"]
        );
        // Both new adjoints drive the same step-level artifact pair.
        assert_eq!(
            reg.create("symplectic").unwrap().required_kinds(),
            &["step_fwd", "step_vjp"]
        );
        assert_eq!(
            reg.create("interp-adjoint4").unwrap().required_kinds(),
            &["step_fwd", "step_vjp"]
        );
    }

    #[test]
    fn interp_forward_nodes_clamp_to_grid() {
        let reg = StrategyRegistry::builtin();
        let s = reg.create("interp-adjoint16").unwrap();
        // p > nt+1 clamps to every state being a node (exact adjoint).
        assert_eq!(s.forward_nodes(4), Some(vec![0, 1, 2, 3, 4]));
        // Endpoints are always present.
        let nodes = s.forward_nodes(32).unwrap();
        assert_eq!(nodes.first(), Some(&0));
        assert_eq!(nodes.last(), Some(&32));
        assert!(nodes.windows(2).all(|w| w[0] < w[1]));
    }
}
