//! Sessions — stateful handles over an [`Engine`](super::Engine) that own
//! parameters and optimizer state, and expose training (`step`, `fit`,
//! `evaluate`), gradient validation (`gradcheck`), the batched
//! inference paths (`predict`, `predict_batches`) with per-call
//! latency/memory stats, and the single-request serving front end
//! ([`Session::serve`] → [`crate::serve`]).
//!
//! A session splits into the shared-immutable [`ExecutionCore`] (one per
//! engine device: config, module handles, strategy — behind an `Arc`,
//! safe to fan across worker threads) and the per-session mutable state
//! it owns (parameters, SGD momentum, the memory ledger). `evaluate` and
//! `predict_batches` exploit the split: contiguous chunks fan out over
//! lazily-created **persistent** per-device worker pools cached on the
//! session ([`SessionConfig::workers`] threads per device, pinned to
//! their device's core at spawn; no per-call thread-spawn tax), routed to
//! the least-loaded device, each chunk metering its own [`MemoryLedger`],
//! folded afterward into aggregate stats (merge within a device, max
//! across devices — rust/DESIGN.md §6d). Training fans out the same way:
//! [`Session::step_accumulate`] runs forward + strategy backward per
//! micro-batch across [`SessionConfig::grad_workers`] workers per device
//! and reduces gradients in fixed micro-batch order, so the update is
//! bit-identical to serial for every (devices, workers) combination.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::{ExecutionCore, GradAccumulator};
use crate::data::Batcher;
use crate::memory::{Category, MemoryLedger};
use crate::metrics::{Curve, CurvePoint, Mean};
use crate::optim::{LrSchedule, Sgd};
use crate::runtime::{Result, RuntimeError};
use crate::serve::{BatchRunner, ServeConfig, ServeHandle, SessionRunner};
use crate::tensor::Tensor;
use crate::util::pool::{
    run_inline, sharded_fold_with, sharded_map_with, PersistentPool, ShardRouter,
};

use super::modules::ModuleSet;
use super::Engine;

/// Per-session configuration: which gradient strategy backs `step`, the
/// optimizer hyperparameters, and the serving-path worker count.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Gradient-strategy spec resolved through the engine's
    /// [`StrategyRegistry`](super::strategy::StrategyRegistry), e.g.
    /// `"anode"`, `"anode-revolve3"`, `"node"`.
    pub method: String,
    pub lr: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Global gradient-norm clip; `None` disables clipping.
    pub clip_norm: Option<f32>,
    /// Worker threads **per device** for the data-parallel serving paths
    /// ([`Session::evaluate`], [`Session::predict_batches`]). `1` (the
    /// default) runs inline on the caller's thread when the engine has a
    /// single device; with several devices the session shards chunks
    /// across one pool per device. Results are bit-identical for every
    /// (devices, workers) combination.
    pub workers: usize,
    /// Micro-batches accumulated per optimizer step by [`Session::fit`]
    /// (each micro-batch is one AOT-compiled batch; the gradient is their
    /// fixed-order mean). `1` (the default) is the classic single-batch
    /// step.
    pub grad_accum: usize,
    /// Worker threads **per device** for the data-parallel gradient path
    /// ([`Session::step_accumulate`]). Parameters and losses are
    /// bit-identical for every (devices, workers) combination — only
    /// wall-clock changes.
    pub grad_workers: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            method: "anode".into(),
            lr: LrSchedule::Constant(0.02),
            momentum: 0.9,
            weight_decay: 5e-4,
            clip_norm: Some(5.0),
            workers: 1,
            grad_accum: 1,
            grad_workers: 1,
        }
    }
}

impl SessionConfig {
    /// Default hyperparameters with the given gradient method.
    pub fn with_method(method: impl Into<String>) -> Self {
        Self { method: method.into(), ..Self::default() }
    }
}

/// Outcome of one optimizer step.
#[derive(Debug, Clone)]
pub struct StepStats {
    /// 1-based step index after this call.
    pub step: usize,
    pub loss: f32,
    /// Fraction of the batch classified correctly (pre-update parameters).
    pub batch_accuracy: f32,
    /// Pre-clip global gradient norm (0 when the step was skipped).
    pub grad_norm: f32,
    pub lr: f32,
    pub seconds: f64,
    /// False when loss/grads were non-finite; the update was skipped.
    pub finite: bool,
}

/// Outcome of an evaluation sweep.
#[derive(Debug, Clone)]
pub struct EvalStats {
    /// Mean per-batch loss.
    pub loss: f32,
    pub accuracy: f32,
    pub batches: usize,
    pub seconds: f64,
}

/// Per-call serving stats for [`Session::predict`].
#[derive(Debug, Clone)]
pub struct PredictStats {
    /// Examples in the batch.
    pub batch: usize,
    pub seconds: f64,
    pub examples_per_sec: f64,
    /// Modeled peak of the rolling activation (max stage activation from
    /// the manifest shapes) — a closed-form bound, not a per-call
    /// measurement; `seconds`/`examples_per_sec` are the measured fields.
    pub peak_activation_bytes: usize,
}

/// Result of one batched inference call.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// Predicted class per example.
    pub classes: Vec<usize>,
    /// Raw logits, shape (batch, num_classes).
    pub logits: Tensor,
    pub stats: PredictStats,
}

/// Aggregate outcome of a [`Session::predict_batches`] fan-out: per-batch
/// predictions (input order), wall-clock throughput, and the merged
/// per-worker memory ledger.
#[derive(Debug)]
pub struct BatchPredictReport {
    /// One prediction per input batch, in input order.
    pub predictions: Vec<Prediction>,
    /// Worker threads actually used.
    pub workers: usize,
    /// Wall-clock for the whole fan-out.
    pub seconds: f64,
    pub examples_per_sec: f64,
    /// The aggregate ledger: per-chunk ledgers merge **within** each
    /// device ([`MemoryLedger::merge`] — one memory space, peaks sum; an
    /// upper bound, since chunks beyond a device's worker count ran
    /// sequentially yet still sum), then devices fold with
    /// [`MemoryLedger::absorb_sharded`] (separate memories, peak = max
    /// over devices). Traffic is additive throughout and equal to the
    /// serial run over the same batches.
    pub memory: MemoryLedger,
    /// The per-device folds behind `memory`, device-id order (one entry
    /// for single-device sessions).
    pub device_memory: Vec<MemoryLedger>,
}

/// Result of [`Session::gradcheck`]: this session's gradient vs the fused
/// DTO reference on one batch.
#[derive(Debug, Clone)]
pub struct GradCheckReport {
    /// Strategy under test.
    pub method: String,
    /// Reference strategy (the fused `anode` DTO VJP).
    pub reference: String,
    /// |loss − loss_ref|.
    pub loss_gap: f32,
    /// Max over parameter tensors of ‖g − g_ref‖/‖g_ref‖.
    pub max_rel_err: f32,
    /// Mean over parameter tensors of the same.
    pub mean_rel_err: f32,
}

/// Options for one [`Session::fit`] run.
#[derive(Debug, Clone)]
pub struct FitOptions {
    pub steps: usize,
    pub eval_every: usize,
    /// Stop as soon as the loss goes non-finite (records the divergence).
    pub stop_on_divergence: bool,
    pub verbose: bool,
}

impl Default for FitOptions {
    fn default() -> Self {
        Self { steps: 200, eval_every: 25, stop_on_divergence: true, verbose: true }
    }
}

/// Outcome of a [`Session::fit`] run.
pub struct FitReport {
    pub curve: Curve,
    pub diverged: bool,
    pub steps_run: usize,
    pub wall_seconds: f64,
    /// Peak activation bytes observed by the ledger.
    pub peak_activation_bytes: usize,
    pub peak_block_input_bytes: usize,
    pub peak_step_state_bytes: usize,
    /// Mean seconds per training step.
    pub sec_per_step: f64,
}

/// A stateful training/inference handle over an [`Engine`].
///
/// Owns the per-session mutable state — parameter vector, optimizer state,
/// memory ledger — over a shared [`ExecutionCore`] (`Arc`'d: config,
/// module handles, strategy). Borrows the engine (and through it the
/// artifact registry and compiled-module cache), so many sessions can
/// share one engine; the engine is `Sync`, so those sessions can train on
/// separate threads concurrently.
pub struct Session<'e> {
    engine: &'e Engine,
    core: Arc<ExecutionCore>,
    /// One execution core per engine device (`cores[0] == core`), each
    /// resolved against its own device's registry — the device pin the
    /// sharded paths hand to device-pinned pool workers.
    cores: Vec<Arc<ExecutionCore>>,
    config: SessionConfig,
    params: Vec<Tensor>,
    opt: Sgd,
    ledger: MemoryLedger,
    step_idx: usize,
    /// Lazily-created per-device worker pools + load-aware router cached
    /// across calls — the execution substrate for `evaluate`,
    /// `predict_batches` and `step_accumulate` fan-outs (grown on demand,
    /// joined when the session drops; a single device with `workers <= 1`
    /// never creates it).
    shard: Mutex<Option<Arc<ShardSet>>>,
}

impl<'e> Session<'e> {
    /// Create a session: resolve the strategy, validate its module needs
    /// against the manifest (per device), load initial parameters.
    pub(super) fn new(engine: &'e Engine, config: SessionConfig) -> Result<Self> {
        let mut cores = Vec::with_capacity(engine.device_count());
        for d in 0..engine.device_count() {
            let strategy = engine.strategies().create(&config.method)?;
            let modules = if d == 0 {
                engine.modules().clone()
            } else {
                let reg = engine.device_set().registry(d);
                ModuleSet::resolve(reg, engine.config(), engine.solver())?
            };
            cores.push(Arc::new(ExecutionCore::with_strategy(
                engine.device_set().registry(d).clone(),
                engine.config().clone(),
                engine.solver(),
                modules,
                strategy,
            )?));
        }
        let core = cores[0].clone();
        let params = core.load_params()?;
        let opt = Sgd::new(&params, config.lr.at(0), config.momentum, config.weight_decay);
        let mut ledger = MemoryLedger::new();
        // Params + optimizer state are persistent allocations.
        let pbytes: usize = params.iter().map(|p| p.byte_size()).sum();
        ledger.alloc(pbytes, Category::Param);
        ledger.alloc(opt.state_bytes(), Category::OptState);
        Ok(Self {
            engine,
            core,
            cores,
            config,
            params,
            opt,
            ledger,
            step_idx: 0,
            shard: Mutex::new(None),
        })
    }

    /// Devices this session shards its parallel paths over.
    pub fn device_count(&self) -> usize {
        self.cores.len()
    }

    /// The engine this session runs on.
    pub fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// Canonical name of the configured gradient method.
    pub fn method_name(&self) -> String {
        self.core.method_name()
    }

    /// The shared execution core (advanced: fan it to custom worker
    /// threads; it is `Send + Sync` and holds no mutable state).
    pub fn core(&self) -> &Arc<ExecutionCore> {
        &self.core
    }

    /// Session configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Current parameters (canonical order).
    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    /// Mutable parameters (e.g. to load a checkpoint).
    pub fn params_mut(&mut self) -> &mut [Tensor] {
        &mut self.params
    }

    /// Optimizer steps taken so far.
    pub fn steps_taken(&self) -> usize {
        self.step_idx
    }

    /// The session's memory ledger (peaks, live bytes).
    pub fn memory(&self) -> &MemoryLedger {
        &self.ledger
    }

    /// Total module executions so far (perf accounting), summed across
    /// every device core.
    pub fn module_calls(&self) -> usize {
        self.cores.iter().map(|core| core.calls_made()).sum()
    }

    /// Validate an input batch against the model's compiled shape.
    fn check_batch(&self, images: &Tensor) -> Result<()> {
        let cfg = &self.core.cfg;
        let want = [cfg.batch, cfg.image, cfg.image, 3];
        if images.shape() != &want[..] {
            return Err(RuntimeError::Shape(format!(
                "input batch shape {:?} does not match the compiled model \
                 (batch, H, W, C) = {want:?} — artifacts are AOT-compiled for a \
                 fixed batch; re-batch the input or rebuild artifacts",
                images.shape()
            )));
        }
        Ok(())
    }

    fn check_labels(&self, labels: &Tensor) -> Result<()> {
        let want = [self.core.cfg.batch];
        if labels.shape() != &want[..] {
            return Err(RuntimeError::Shape(format!(
                "label shape {:?} does not match {want:?} (f32 class indices)",
                labels.shape()
            )));
        }
        Ok(())
    }

    /// Loss + gradients for one batch without applying an update (the
    /// building block behind `step`, exposed for analysis workloads).
    pub fn loss_and_grad(
        &mut self,
        images: &Tensor,
        labels: &Tensor,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        self.check_batch(images)?;
        self.check_labels(labels)?;
        self.core.loss_and_grad(images, labels, &self.params, &mut self.ledger)
    }

    /// One training step: forward, strategy backward, clip, SGD update.
    /// Non-finite losses/gradients skip the update and report
    /// `finite: false` instead of corrupting the parameters.
    ///
    /// Under [`Backend::Compiled`](crate::runtime::Backend::Compiled) the
    /// whole loss-and-grad body dispatches as one fused
    /// [`TrainProgram`](crate::compile::TrainProgram) over a
    /// checkpoint-aware arena (zero steady-state allocations); losses,
    /// parameters and ledger traffic stay bit-identical to the sim
    /// interpreter for every built-in strategy.
    pub fn step(&mut self, images: &Tensor, labels: &Tensor) -> Result<StepStats> {
        self.check_batch(images)?;
        self.check_labels(labels)?;
        let t0 = Instant::now();
        let lr = self.config.lr.at(self.step_idx);
        self.opt.lr = lr;
        let (loss, correct, mut grads) =
            self.core.loss_and_grad(images, labels, &self.params, &mut self.ledger)?;
        let finite = loss.is_finite() && grads.iter().all(|g| g.all_finite());
        let mut grad_norm = 0.0;
        if finite {
            grad_norm = self.opt.clipped_step(&mut self.params, &mut grads, self.config.clip_norm);
        }
        self.step_idx += 1;
        Ok(StepStats {
            step: self.step_idx,
            loss,
            batch_accuracy: correct / self.core.cfg.batch.max(1) as f32,
            grad_norm,
            lr,
            seconds: t0.elapsed().as_secs_f64(),
            finite,
        })
    }

    /// One optimizer step over several micro-batches with **data-parallel
    /// gradient accumulation**: each of [`SessionConfig::grad_workers`]
    /// pool workers runs forward + the session's gradient strategy
    /// backward over a contiguous chunk of `micro_batches` (private
    /// [`ForwardState`](crate::coordinator::ForwardState) and
    /// [`MemoryLedger`] per chunk), the per-micro-batch gradients reduce
    /// in fixed micro-batch order on this thread
    /// ([`ExecutionCore::reduce_grads`]), and a single clipped SGD update
    /// applies the mean gradient.
    ///
    /// Parameters, loss and gradients are **bit-identical to the serial
    /// run for every worker count** — the reduction order never depends on
    /// the chunking — so ANODE's unconditionally-accurate-gradient
    /// property survives parallelism (asserted across all registered
    /// strategies in `rust/tests/concurrency.rs`). Every micro-batch must
    /// have the AOT-compiled batch shape.
    ///
    /// Under [`Backend::Compiled`](crate::runtime::Backend::Compiled)
    /// each worker's per-micro-batch loss-and-grad runs the fused
    /// [`TrainProgram`](crate::compile::TrainProgram) (arena buffers pool
    /// per concurrent caller), and the unchanged fixed-order reduction
    /// keeps the result bitwise equal to sim serial across the whole
    /// (devices × workers × strategies) grid.
    pub fn step_accumulate(&mut self, micro_batches: &[(Tensor, Tensor)]) -> Result<StepStats> {
        self.step_accumulate_with_workers(micro_batches, self.config.grad_workers)
    }

    /// [`Session::step_accumulate`] with an explicit worker count (benches
    /// and tests sweep this without rebuilding the session).
    pub fn step_accumulate_with_workers(
        &mut self,
        micro_batches: &[(Tensor, Tensor)],
        workers: usize,
    ) -> Result<StepStats> {
        if micro_batches.is_empty() {
            return Err(RuntimeError::Shape(
                "step_accumulate needs at least one micro-batch".into(),
            ));
        }
        for (images, labels) in micro_batches {
            self.check_batch(images)?;
            self.check_labels(labels)?;
        }
        let t0 = Instant::now();
        let lr = self.config.lr.at(self.step_idx);
        self.opt.lr = lr;
        let params = &self.params;
        // Pipelined reduce: the streaming fold consumes chunk i's
        // gradients on this thread while chunk i+1 is still computing on
        // the pools. The accumulator's push order is the fixed micro-batch
        // index order (the streaming scatter delivers chunks in input
        // order), so the result is bit-identical to the old
        // gather-everything-then-reduce_grads path and to serial —
        // asserted on the concurrency grid in rust/tests/concurrency.rs.
        let mut acc = GradAccumulator::new();
        let mut first_err: Option<RuntimeError> = None;
        let states = sharded_exec_fold(
            &self.shard,
            &self.cores,
            workers,
            micro_batches,
            MemoryLedger::new,
            |core, ledger, _i, xy: &(Tensor, Tensor)| {
                core.loss_and_grad(&xy.0, &xy.1, params, ledger)
            },
            |_base, results: Vec<Result<(f32, f32, Vec<Tensor>)>>| {
                for r in results {
                    // The first error (in micro-batch order) wins, exactly
                    // like the old collect::<Result<Vec<_>>>; later
                    // gradients are discarded once an error is latched.
                    match r {
                        Ok(triple) if first_err.is_none() => {
                            if let Err(e) = acc.push(triple) {
                                first_err = Some(e);
                            }
                        }
                        Ok(_) => {}
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
            },
        );
        // Fold the phase into the session ledger before error propagation:
        // traffic stays additive (equal to the serial run) even when one
        // micro-batch failed. One device: the classic concurrent-worker
        // fold. Sharded: workers merge per device (one memory each), then
        // the cross-device candidate is the max over devices (§6d).
        if self.cores.len() <= 1 {
            let ledgers: Vec<MemoryLedger> = states.into_iter().map(|(_, l)| l).collect();
            self.ledger.absorb_parallel(&ledgers);
        } else {
            self.ledger.absorb_sharded(&ledgers_by_device(self.cores.len(), &states));
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let (loss, correct, mut grads) = acc.finish()?;
        let finite = loss.is_finite() && grads.iter().all(|g| g.all_finite());
        let mut grad_norm = 0.0;
        if finite {
            grad_norm = self.opt.clipped_step(&mut self.params, &mut grads, self.config.clip_norm);
        }
        self.step_idx += 1;
        let examples = micro_batches.len() * self.core.cfg.batch;
        Ok(StepStats {
            step: self.step_idx,
            loss,
            batch_accuracy: correct / examples.max(1) as f32,
            grad_norm,
            lr,
            seconds: t0.elapsed().as_secs_f64(),
            finite,
        })
    }

    /// Evaluate over pre-batched data via the inference path (no gradient
    /// bookkeeping, no ledger traffic). Fans batches across
    /// [`SessionConfig::workers`] threads of the session's cached
    /// persistent pool (no per-call spawn); the reduction runs in batch
    /// order on the calling thread, so the result is bit-identical to the
    /// serial sweep for every worker count.
    pub fn evaluate(&self, batches: &[(Tensor, Tensor)]) -> Result<EvalStats> {
        self.evaluate_with_workers(batches, self.config.workers)
    }

    /// [`Session::evaluate`] with an explicit worker count (serving drivers
    /// and benches sweep this without rebuilding the session).
    pub fn evaluate_with_workers(
        &self,
        batches: &[(Tensor, Tensor)],
        workers: usize,
    ) -> Result<EvalStats> {
        let t0 = Instant::now();
        let params = &self.params;
        let (per_batch, _) = sharded_exec(
            &self.shard,
            &self.cores,
            workers,
            batches,
            || (),
            |core, _state, _i, xy: &(Tensor, Tensor)| core.eval_batch(&xy.0, &xy.1, params),
        );
        let per_batch = per_batch.into_iter().collect::<Result<Vec<_>>>()?;
        let (loss, accuracy) = ExecutionCore::reduce_eval(&per_batch, self.core.cfg.batch);
        Ok(EvalStats { loss, accuracy, batches: batches.len(), seconds: t0.elapsed().as_secs_f64() })
    }

    /// Batched inference: one pre-batched image tensor in, per-example
    /// class predictions and logits out, with per-call latency and memory
    /// stats — the serving-shaped path.
    pub fn predict(&self, images: &Tensor) -> Result<Prediction> {
        self.check_batch(images)?;
        let cfg = &self.core.cfg;
        let t0 = Instant::now();
        let z = self.core.forward_infer(images, &self.params)?;
        let (hw, hb) = self.core.index.head;
        let logits = head_logits(&z, &self.params[hw], &self.params[hb])?;
        let classes = argmax_rows(&logits);
        let seconds = t0.elapsed().as_secs_f64();
        // Inference holds one rolling activation; peak is the largest stage.
        let peak_activation_bytes = cfg.rolling_act_bytes();
        Ok(Prediction {
            classes,
            logits,
            stats: PredictStats {
                batch: cfg.batch,
                seconds,
                examples_per_sec: cfg.batch as f64 / seconds.max(1e-12),
                peak_activation_bytes,
            },
        })
    }

    /// Many-batch inference: fan pre-batched image tensors across
    /// [`SessionConfig::workers`] threads of the session's cached
    /// persistent pool. Each worker meters its rolling activation on a
    /// **private** [`MemoryLedger`]; the report carries the merged
    /// aggregate (traffic additive — equal to the serial run — peaks
    /// summed across concurrent workers), so the paper's O-bounds stay
    /// measurable per worker.
    pub fn predict_batches(&self, batches: &[Tensor]) -> Result<BatchPredictReport> {
        self.predict_batches_with_workers(batches, self.config.workers)
    }

    /// [`Session::predict_batches`] with an explicit worker count.
    pub fn predict_batches_with_workers(
        &self,
        batches: &[Tensor],
        workers: usize,
    ) -> Result<BatchPredictReport> {
        for images in batches {
            self.check_batch(images)?;
        }
        let t0 = Instant::now();
        let params = &self.params;
        let cfg = &self.core.cfg;
        let (results, states) = sharded_exec(
            &self.shard,
            &self.cores,
            workers,
            batches,
            MemoryLedger::new,
            |core, ledger: &mut MemoryLedger, _i, images: &Tensor| {
                infer_batch(core, params, images, ledger)
            },
        );
        let chunks = states.len();
        let device_memory = ledgers_by_device(self.cores.len(), &states);
        // Cross-device fold: peaks combine by max (separate memories),
        // traffic stays additive — equal to the serial sweep. A single
        // device degenerates to the classic merged-worker aggregate.
        let mut memory = MemoryLedger::new();
        memory.absorb_sharded(&device_memory);
        let predictions = results.into_iter().collect::<Result<Vec<_>>>()?;
        let seconds = t0.elapsed().as_secs_f64();
        let examples = predictions.len() * cfg.batch;
        Ok(BatchPredictReport {
            predictions,
            workers: chunks,
            seconds,
            examples_per_sec: examples as f64 / seconds.max(1e-12),
            memory,
            device_memory,
        })
    }

    /// Start the single-request serving front end over this session's
    /// model: a deadline-batched admission queue (requests coalesce into
    /// the AOT batch size, flushing when full or when the oldest request
    /// has waited `config.max_delay`) feeding one persistent worker pool
    /// **per engine device**, filled batches routed to the least-loaded
    /// device (rust/DESIGN.md §6d).
    ///
    /// The returned [`ServeHandle`] is cloneable and independent of this
    /// session's lifetime — it snapshots the current parameters over the
    /// shared execution cores, so later `step`s do not affect a running
    /// pipeline. Roll new weights out with [`Session::push_params`] (an
    /// atomic between-batches hot-swap across every device; no drain).
    /// Served values are bit-identical to [`Session::predict_batches`]
    /// over the same examples — routing never changes values, because the
    /// per-batch computation is device-independent. See `anode::serve` and
    /// rust/DESIGN.md §6b.
    pub fn serve(&self, config: ServeConfig) -> Result<ServeHandle> {
        // One shared snapshot: every device runner holds the same Arc, so
        // serving D devices costs one parameter copy, not D.
        let snapshot = Arc::new(self.params.clone());
        let runners: Vec<Arc<dyn BatchRunner>> = self
            .cores
            .iter()
            .map(|core| {
                Arc::new(SessionRunner::new(core.clone(), snapshot.clone()))
                    as Arc<dyn BatchRunner>
            })
            .collect();
        ServeHandle::spawn_sharded(runners, config)
    }

    /// Start the serving pipeline of [`Session::serve`] *and* put the
    /// `anode::net` socket front end on it: bind `addr` (use
    /// `"127.0.0.1:0"` for an OS-assigned loopback port) and spawn the
    /// connection reactor. Clients speak the length-prefixed binary
    /// protocol of [`crate::net::proto`]; `GET /metrics` on the same
    /// port answers with scrapeable plain text. Shutting the returned
    /// [`NetServer`] down drains the sockets first (no accepted request
    /// is dropped), then the serve pipeline, and returns both reports.
    pub fn serve_net(
        &self,
        config: ServeConfig,
        net: crate::net::NetConfig,
        addr: &str,
    ) -> Result<crate::net::NetServer> {
        crate::net::NetServer::bind(self.serve(config)?, addr, net)
    }

    /// Roll this session's *current* parameters out to a running serve
    /// pipeline: an atomic hot-swap of the weight snapshot, applied
    /// between batches — a checkpoint trained by [`Session::fit`] reaches
    /// serving without draining the queue. The handle's runner validates
    /// tensor count/shapes (so a pipeline over a different model rejects
    /// the swap).
    pub fn push_params(&self, handle: &ServeHandle) -> Result<()> {
        handle.swap_params(Arc::new(self.params.clone()))
    }

    /// Run one train→canary→promote/rollback campaign against a running
    /// serve pipeline (rust/DESIGN.md §6g): train `canary_every` steps,
    /// snapshot a candidate (one `Arc` allocation shared across every
    /// device runner), shadow-evaluate it on `eval` through the session's
    /// cached per-device pools, and promote it to `handle` when the
    /// quality gate passes — or roll back to the last-good snapshot on a
    /// regression event. The pipeline keeps serving throughout; swaps are
    /// atomic and between-batches (zero drain).
    ///
    /// This is the one-shot convenience over
    /// [`crate::rollout::RolloutOrchestrator`]; hold the orchestrator
    /// yourself when rollback state must survive across campaigns.
    pub fn rollout(
        &mut self,
        handle: &ServeHandle,
        train: &[(Tensor, Tensor)],
        eval: &[(Tensor, Tensor)],
        config: crate::rollout::RolloutConfig,
    ) -> Result<crate::rollout::RolloutReport> {
        let initial = Arc::new(self.params.clone());
        crate::rollout::RolloutOrchestrator::new(handle.clone(), initial, config)
            .run(self, train, eval)
    }

    /// Compare this session's gradient against the fused DTO reference
    /// (`anode`) on one batch — the §IV consistency check as a serving API.
    pub fn gradcheck(&mut self, images: &Tensor, labels: &Tensor) -> Result<GradCheckReport> {
        self.check_batch(images)?;
        self.check_labels(labels)?;
        let reference = "anode";
        let ref_strategy = self.engine.strategies().create(reference)?;
        let ref_core = ExecutionCore::with_strategy(
            self.engine.shared_registry(),
            self.core.cfg.clone(),
            self.core.solver,
            self.engine.modules().clone(),
            ref_strategy,
        )?;
        let mut scratch = MemoryLedger::new();
        let (loss_ref, _, g_ref) =
            ref_core.loss_and_grad(images, labels, &self.params, &mut scratch)?;
        let (loss, _, g) =
            self.core.loss_and_grad(images, labels, &self.params, &mut self.ledger)?;
        let mut max_rel = 0.0f32;
        let mut sum_rel = 0.0f64;
        for (a, b) in g.iter().zip(&g_ref) {
            let e = a.rel_err(b).unwrap_or(f32::INFINITY);
            max_rel = max_rel.max(e);
            sum_rel += e as f64;
        }
        Ok(GradCheckReport {
            method: self.method_name(),
            reference: reference.into(),
            loss_gap: (loss - loss_ref).abs(),
            max_rel_err: max_rel,
            mean_rel_err: (sum_rel / g.len().max(1) as f64) as f32,
        })
    }

    /// Run the full training loop: `opts.steps` optimizer steps with
    /// periodic evaluation, divergence detection and curve recording.
    ///
    /// With [`SessionConfig::grad_accum`] > 1 (or `grad_workers` > 1)
    /// every optimizer step draws `grad_accum` micro-batches and applies
    /// their fixed-order mean gradient via [`Session::step_accumulate`] —
    /// the curve depends on `grad_accum` (data consumed per step) but is
    /// bit-identical across `grad_workers` counts.
    pub fn fit(
        &mut self,
        train: &mut Batcher,
        eval_batches: &[(Tensor, Tensor)],
        opts: &FitOptions,
        series_name: &str,
    ) -> Result<FitReport> {
        self.ledger.reset_peaks();
        let mut curve = Curve::new(series_name);
        let mut train_loss = Mean::default();
        let mut diverged = false;
        let t0 = Instant::now();
        let mut steps_run = 0;
        let batches_per_epoch = train.batches_per_epoch().max(1);
        let accum = self.config.grad_accum.max(1);
        let accumulate = accum > 1 || self.config.grad_workers.max(1) > 1;

        for step in 0..opts.steps {
            let stats = if accumulate {
                let micro: Vec<(Tensor, Tensor)> = (0..accum)
                    .map(|_| {
                        let b = train.next_batch();
                        (b.images, b.labels)
                    })
                    .collect();
                self.step_accumulate(&micro)?
            } else {
                let batch = train.next_batch();
                self.step(&batch.images, &batch.labels)?
            };
            steps_run = step + 1;
            train_loss.add(stats.loss);
            if !stats.finite {
                diverged = true;
            }

            let at_eval = (step + 1) % opts.eval_every.max(1) == 0 || step + 1 == opts.steps;
            if at_eval || diverged {
                let (tl, ta) = if diverged {
                    (f32::NAN, curve.points.last().map(|p| p.test_acc).unwrap_or(0.0))
                } else {
                    let e = self.evaluate(eval_batches)?;
                    (e.loss, e.accuracy)
                };
                let point = CurvePoint {
                    step: step + 1,
                    // Epochs measure data consumed: each optimizer step
                    // draws `accum` micro-batches.
                    epoch: ((step + 1) * accum) as f32 / batches_per_epoch as f32,
                    train_loss: if diverged { f32::NAN } else { train_loss.value() },
                    test_loss: tl,
                    test_acc: ta,
                };
                if opts.verbose {
                    eprintln!(
                        "[{series_name}] step {:>5} epoch {:>5.2} train_loss {:>9.4} test_loss {:>9.4} test_acc {:>6.2}%{}",
                        point.step,
                        point.epoch,
                        point.train_loss,
                        point.test_loss,
                        point.test_acc * 100.0,
                        if diverged { "  << DIVERGED" } else { "" }
                    );
                }
                curve.push(point);
                train_loss.reset();
                if diverged && opts.stop_on_divergence {
                    break;
                }
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        Ok(FitReport {
            diverged: diverged || curve.diverged(),
            curve,
            steps_run,
            wall_seconds: wall,
            peak_activation_bytes: self.ledger.peak_of(Category::BlockInput)
                + self.ledger.peak_of(Category::StepState),
            peak_block_input_bytes: self.ledger.peak_of(Category::BlockInput),
            peak_step_state_bytes: self.ledger.peak_of(Category::StepState),
            sec_per_step: wall / steps_run.max(1) as f64,
        })
    }
}

/// The session's cached multi-device execution substrate: one persistent
/// pool per device whose workers are **pinned to that device's core at
/// spawn** (the `PersistentPool` per-worker state hook — every job a
/// worker ever runs executes through its own device's registry), plus the
/// load-aware [`ShardRouter`] that assigns contiguous chunks to the
/// least-loaded device.
struct ShardSet {
    pools: Vec<PersistentPool<Arc<ExecutionCore>>>,
    router: ShardRouter,
    workers_per_device: usize,
}

impl ShardSet {
    fn new(cores: &[Arc<ExecutionCore>], workers_per_device: usize) -> std::io::Result<Self> {
        let workers_per_device = workers_per_device.max(1);
        let mut pools = Vec::with_capacity(cores.len());
        for (d, core) in cores.iter().enumerate() {
            let pinned = core.clone();
            pools.push(PersistentPool::new(
                workers_per_device,
                &format!("anode-d{d}"),
                move || pinned.clone(),
            )?);
        }
        let caps = vec![workers_per_device; cores.len()];
        Ok(Self { pools, router: ShardRouter::new(&caps), workers_per_device })
    }
}

/// Ordered contiguous-chunk fan-out across the session's cached
/// per-device pools, lazily creating (or growing) them on first parallel
/// use. Each chunk executes against the core its worker was pinned to;
/// results return in input order tagged with the device that ran them.
///
/// A single device with `workers <= 1` runs inline on the caller's thread
/// against the primary core without touching any pool, and a failed pool
/// spawn degrades to the same serial path — both produce bit-identical
/// results to the sharded run by construction (per-item values never
/// depend on the chunking or the routing; reassembly is in input order).
/// Replacing a too-small set is safe mid-flight: concurrent calls hold
/// their own `Arc`, and the old pools join when their last user finishes.
fn sharded_exec<T, R, CS>(
    slot: &Mutex<Option<Arc<ShardSet>>>,
    cores: &[Arc<ExecutionCore>],
    workers: usize,
    items: &[T],
    init: impl Fn() -> CS + Sync,
    f: impl Fn(&ExecutionCore, &mut CS, usize, &T) -> R + Sync,
) -> (Vec<R>, Vec<(usize, CS)>)
where
    T: Sync,
    R: Send,
    CS: Send,
{
    let devices = cores.len();
    let w = workers.max(1);
    let serial = || {
        let primary: &ExecutionCore = &cores[0];
        let (results, states) = run_inline(items, &init, |cs, i, t| f(primary, cs, i, t));
        let tagged: Vec<(usize, CS)> = states.into_iter().map(|cs| (0usize, cs)).collect();
        (results, tagged)
    };
    if (devices <= 1 && w <= 1) || items.len() <= 1 {
        return serial();
    }
    match acquire_shard_set(slot, cores, w) {
        Some(set) => {
            let pools: Vec<&PersistentPool<Arc<ExecutionCore>>> = set.pools.iter().collect();
            // `w` caps the fan-out even when a larger pool set is cached
            // (pools never shrink): an explicit small worker count keeps
            // its requested concurrency bound, like map_with's limit.
            sharded_map_with(&pools, &set.router, w, items, &init, |core, cs, i, t| {
                // The worker's pinned state IS the device: every job this
                // worker ever runs executes through its device's core.
                let pinned: &ExecutionCore = core;
                f(pinned, cs, i, t)
            })
        }
        // Could not spawn (thread exhaustion): degrade to the serial path
        // rather than fail — the result is bit-identical by construction.
        None => serial(),
    }
}

/// The cached-`ShardSet` acquisition shared by [`sharded_exec`] and
/// [`sharded_exec_fold`]: reuse a cached set that is large enough,
/// otherwise build (and cache) a bigger one; `None` on spawn failure
/// (callers degrade to the serial path).
fn acquire_shard_set(
    slot: &Mutex<Option<Arc<ShardSet>>>,
    cores: &[Arc<ExecutionCore>],
    w: usize,
) -> Option<Arc<ShardSet>> {
    let mut slot = slot.lock().unwrap();
    let cached = match slot.as_ref() {
        Some(set) if set.workers_per_device >= w && set.pools.len() == cores.len() => {
            Some(set.clone())
        }
        _ => None,
    };
    match cached {
        Some(set) => Some(set),
        None => match ShardSet::new(cores, w) {
            Ok(set) => {
                let set = Arc::new(set);
                *slot = Some(set.clone());
                Some(set)
            }
            Err(_) => None,
        },
    }
}

/// Streaming variant of [`sharded_exec`]: instead of gathering every
/// result before returning, deliver each contiguous chunk's results to
/// `fold` **in input order as the chunk completes** — so the caller's
/// reduction (gradient accumulation) overlaps with chunks still
/// executing on the device pools. The fold order is fixed by
/// construction (the streaming scatter's in-order cursor), so any
/// order-sensitive reduction stays bit-identical to the gather-then-fold
/// path and to serial. The serial/degraded path computes items in order
/// on the calling thread and folds them identically.
fn sharded_exec_fold<T, R, CS>(
    slot: &Mutex<Option<Arc<ShardSet>>>,
    cores: &[Arc<ExecutionCore>],
    workers: usize,
    items: &[T],
    init: impl Fn() -> CS + Sync,
    f: impl Fn(&ExecutionCore, &mut CS, usize, &T) -> R + Sync,
    mut fold: impl FnMut(usize, Vec<R>),
) -> Vec<(usize, CS)>
where
    T: Sync,
    R: Send,
    CS: Send,
{
    let devices = cores.len();
    let w = workers.max(1);
    if (devices <= 1 && w <= 1) || items.len() <= 1 {
        let primary: &ExecutionCore = &cores[0];
        let (results, states) = run_inline(items, &init, |cs, i, t| f(primary, cs, i, t));
        fold(0, results);
        return states.into_iter().map(|cs| (0usize, cs)).collect();
    }
    match acquire_shard_set(slot, cores, w) {
        Some(set) => {
            let pools: Vec<&PersistentPool<Arc<ExecutionCore>>> = set.pools.iter().collect();
            sharded_fold_with(
                &pools,
                &set.router,
                w,
                items,
                &init,
                |core, cs, i, t| {
                    let pinned: &ExecutionCore = core;
                    f(pinned, cs, i, t)
                },
                fold,
            )
        }
        None => {
            let primary: &ExecutionCore = &cores[0];
            let (results, states) = run_inline(items, &init, |cs, i, t| f(primary, cs, i, t));
            fold(0, results);
            states.into_iter().map(|cs| (0usize, cs)).collect()
        }
    }
}

/// Group per-chunk ledgers by the device that ran them into one merged
/// ledger per device ([`MemoryLedger::merge`] — chunks of one device
/// share its memory, so their peaks sum); the cross-device fold is then
/// [`MemoryLedger::absorb_sharded`] (max over devices).
///
/// The summed device peak is an **upper bound** on that device's
/// concurrent working set: when a device receives more chunks than it
/// has workers (router imbalance, or a fast worker draining two chunks),
/// some of those chunks ran sequentially yet still sum. The bound is
/// never an undercount.
fn ledgers_by_device(devices: usize, states: &[(usize, MemoryLedger)]) -> Vec<MemoryLedger> {
    let mut per_device = vec![MemoryLedger::new(); devices.max(1)];
    for (d, ledger) in states {
        per_device[*d].merge(ledger);
    }
    per_device
}

/// One pre-batched tensor through the inference path with the rolling
/// activation metered transiently on `ledger` — the per-batch unit shared
/// by [`Session::predict_batches`] and the serve path's
/// [`crate::serve::SessionRunner`]. Keeping this in one place is what
/// makes the serve path's bit-identity guarantee structural rather than a
/// convention two copies would have to maintain.
pub(crate) fn infer_batch(
    core: &ExecutionCore,
    params: &[Tensor],
    images: &Tensor,
    ledger: &mut MemoryLedger,
) -> Result<Prediction> {
    let cfg = &core.cfg;
    let (hw, hb) = core.index.head;
    // Inference rolls one activation through the stages; its peak is the
    // largest stage activation.
    let rolling = cfg.rolling_act_bytes();
    let id = ledger.alloc(rolling, Category::Transient);
    let t = Instant::now();
    let out = core
        .forward_infer(images, params)
        .and_then(|z| head_logits(&z, &params[hw], &params[hb]));
    ledger.free(id);
    let logits = out?;
    let classes = argmax_rows(&logits);
    let seconds = t.elapsed().as_secs_f64();
    Ok(Prediction {
        classes,
        logits,
        stats: PredictStats {
            batch: cfg.batch,
            seconds,
            examples_per_sec: cfg.batch as f64 / seconds.max(1e-12),
            peak_activation_bytes: rolling,
        },
    })
}

/// Host-side classifier head: global-average-pool `z` (B,H,W,C), then the
/// dense layer `feat · w + b` (w: (C,K), b: (K)). Mirrors `_head_loss` in
/// python/compile/model.py, minus the loss — serving needs logits, and
/// this keeps the AOT surface unchanged.
pub fn head_logits(z: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
    if z.rank() != 4 {
        return Err(RuntimeError::Shape(format!(
            "head_logits wants rank-4 activations, got {:?}",
            z.shape()
        )));
    }
    let (bsz, h, wd, c) = (z.shape()[0], z.shape()[1], z.shape()[2], z.shape()[3]);
    if w.rank() != 2 || w.shape()[0] != c {
        return Err(RuntimeError::Shape(format!(
            "head weight {:?} does not match activation channels {c}",
            w.shape()
        )));
    }
    let k = w.shape()[1];
    if b.shape() != &[k][..] {
        return Err(RuntimeError::Shape(format!(
            "head bias {:?} does not match {k} classes",
            b.shape()
        )));
    }

    let zd = z.data();
    let wdat = w.data();
    let bdat = b.data();
    let hw = (h * wd) as f64;
    let mut out = vec![0.0f32; bsz * k];
    let mut feat = vec![0.0f64; c];
    for bi in 0..bsz {
        feat.iter_mut().for_each(|f| *f = 0.0);
        let base = bi * h * wd * c;
        for px in 0..h * wd {
            let off = base + px * c;
            for (ch, f) in feat.iter_mut().enumerate() {
                *f += zd[off + ch] as f64;
            }
        }
        for f in feat.iter_mut() {
            *f /= hw;
        }
        for j in 0..k {
            let mut acc = bdat[j] as f64;
            for (ch, f) in feat.iter().enumerate() {
                acc += *f * wdat[ch * k + j] as f64;
            }
            out[bi * k + j] = acc as f32;
        }
    }
    Tensor::from_vec(vec![bsz, k], out).map_err(|e| RuntimeError::Shape(e.to_string()))
}

/// Row-wise argmax over a (B, K) tensor.
pub fn argmax_rows(logits: &Tensor) -> Vec<usize> {
    let k = *logits.shape().last().unwrap_or(&1);
    logits
        .data()
        .chunks(k.max(1))
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_logits_matches_hand_computation() {
        // z: (1, 1, 2, 2) -> feat = mean over the 2 pixels per channel.
        let z = Tensor::from_vec(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        // feat = [(1+3)/2, (2+4)/2] = [2, 3]
        let w = Tensor::from_vec(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::from_vec(vec![2], vec![0.5, -0.5]).unwrap();
        let logits = head_logits(&z, &w, &b).unwrap();
        assert_eq!(logits.shape(), &[1, 2]);
        assert!((logits.data()[0] - 2.5).abs() < 1e-6);
        assert!((logits.data()[1] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn head_logits_rejects_bad_shapes() {
        let z = Tensor::zeros(&[2, 4, 4, 8]);
        let w_bad = Tensor::zeros(&[7, 10]);
        let b = Tensor::zeros(&[10]);
        assert!(head_logits(&z, &w_bad, &b).is_err());
        let w = Tensor::zeros(&[8, 10]);
        let b_bad = Tensor::zeros(&[9]);
        assert!(head_logits(&z, &w, &b_bad).is_err());
        assert!(head_logits(&Tensor::zeros(&[2, 4]), &w, &b).is_err());
    }

    #[test]
    fn argmax_rows_picks_max_per_row() {
        let t = Tensor::from_vec(vec![2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 0.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }
}
