//! Execute a checkpointing [`Schedule`] against concrete step functions.
//!
//! The executor is generic over the state type and the step/step-VJP
//! callbacks, so the same machinery runs against the AOT `step_fwd` /
//! `step_vjp` artifacts in the coordinator AND against cheap closures in
//! tests/property tests. It enforces the slot budget at runtime and
//! reports peak memory to the [`crate::memory::MemoryLedger`].

use std::collections::HashMap;

use super::{Action, Schedule};




/// Gradient step: given (z_i, adjoint at i+1) return (adjoint at i, and
/// accumulate parameter gradients internally).
/// Run the backward phase of `schedule`.
///
/// * `z0` — the block input (state 0), already stored by the coordinator.
/// * `adjoint` — dL/dz_nt, the incoming gradient.
/// * `step` — forward step closure.
/// * `step_grad` — VJP closure: (state_i, adjoint_{i+1}) -> adjoint_i.
///   Parameter-gradient accumulation is the closure's business.
/// * `on_live_states` — called with the current number of live states
///   (checkpoints + tape) after every action, for memory accounting.
///
/// Returns dL/dz_0.
pub fn run_backward<Z: Clone, F, G, M>(
    schedule: &Schedule,
    z0: &Z,
    adjoint: Z,
    mut step: F,
    mut step_grad: G,
    mut on_live_states: M,
) -> Result<Z, String>
where
    F: FnMut(&Z) -> Z,
    G: FnMut(&Z, &Z) -> Z,
    M: FnMut(usize),
{
    let mut slots: HashMap<usize, (usize, Z)> = HashMap::new();
    let mut tape: Vec<(usize, Z)> = Vec::new();
    let mut cur: Option<(usize, Z)> = Some((0, z0.clone()));
    let mut adj = adjoint;
    let max_slots = schedule.strategy.slots(schedule.nt);

    for (idx, a) in schedule.actions.iter().enumerate() {
        match a {
            Action::Checkpoint { slot, state } => {
                let (s, z) = cur.clone().ok_or_else(|| format!("action {idx}: no current state"))?;
                if s != *state {
                    return Err(format!("action {idx}: checkpoint state mismatch {s} != {state}"));
                }
                slots.insert(*slot, (s, z));
                if slots.len() > max_slots {
                    return Err(format!(
                        "action {idx}: slot budget exceeded ({} > {max_slots})",
                        slots.len()
                    ));
                }
            }
            Action::Restore { slot, state } => {
                let (s, z) = slots
                    .get(slot)
                    .cloned()
                    .ok_or_else(|| format!("action {idx}: restore of empty slot {slot}"))?;
                if s != *state {
                    return Err(format!("action {idx}: slot {slot} holds {s}, wanted {state}"));
                }
                cur = Some((s, z));
            }
            Action::Forward { state, store_tape } => {
                let (s, z) = cur.take().ok_or_else(|| format!("action {idx}: no current state"))?;
                if s != *state {
                    return Err(format!("action {idx}: forward from {s}, schedule says {state}"));
                }
                let z1 = step(&z);
                if *store_tape {
                    tape.push((s, z));
                }
                cur = Some((s + 1, z1));
            }
            Action::Backward { state } => {
                let (s, z) = tape.pop().ok_or_else(|| format!("action {idx}: empty tape"))?;
                if s != *state {
                    return Err(format!("action {idx}: tape holds {s}, wanted {state}"));
                }
                adj = step_grad(&z, &adj);
            }
        }
        on_live_states(slots.len() + tape.len());
    }
    Ok(adj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{plan, Strategy};

    /// Scalar test dynamics z' = a*z (step: z *= (1+h a)); adjoint of one
    /// step is multiplication by the same factor — easy to verify exactly.
    fn check_strategy(strategy: Strategy, nt: usize) {
        let factor = 1.07f64;
        let schedule = plan(strategy, nt);
        assert!(schedule.validate().is_empty(), "{strategy:?} nt={nt}");

        let mut peak = 0usize;
        let step_count = std::cell::Cell::new(0usize);
        let grad = run_backward(
            &schedule,
            &1.5f64,
            1.0f64,
            |z| {
                step_count.set(step_count.get() + 1);
                z * factor
            },
            |_z, a| a * factor,
            |live| peak = peak.max(live),
        )
        .unwrap();

        // d z_nt / d z_0 = factor^nt.
        let expect = factor.powi(nt as i32);
        assert!((grad - expect).abs() < 1e-9 * expect, "{strategy:?}: {grad} vs {expect}");
        assert_eq!(step_count.get(), schedule.forward_evals());
        assert!(peak <= schedule.peak_states().max(1), "{strategy:?}: peak {peak}");
    }

    #[test]
    fn all_strategies_produce_exact_gradient() {
        for nt in [1, 2, 5, 13, 32] {
            check_strategy(Strategy::StoreAll, nt);
            check_strategy(Strategy::MinMemory, nt);
            for m in [1, 2, 3, 5] {
                check_strategy(Strategy::Equispaced(m), nt);
                check_strategy(Strategy::Revolve(m), nt);
            }
        }
    }

    #[test]
    fn executor_rejects_budget_violation() {
        // Hand-build a schedule that uses more slots than the strategy allows.
        let mut s = plan(Strategy::Revolve(1), 2);
        s.actions.insert(1, Action::Checkpoint { slot: 9, state: 0 });
        let r = run_backward(&s, &1.0f64, 1.0, |z| *z, |_, a| *a, |_| {});
        assert!(r.is_err());
    }

    #[test]
    fn executor_checks_state_consistency() {
        let s = super::super::Schedule {
            nt: 1,
            strategy: Strategy::StoreAll,
            actions: vec![Action::Restore { slot: 3, state: 0 }],
        };
        assert!(run_backward(&s, &1.0f64, 1.0, |z| *z, |_, a| *a, |_| {}).is_err());
    }

    /// Nonlinear dynamics: compare revolve gradient against store-all
    /// (which is plain BPTT) — must agree to machine precision because
    /// revolve recomputes the *same* discrete states.
    #[test]
    fn revolve_equals_store_all_on_nonlinear_map() {
        let nt = 17;
        let step = |z: &f64| z + 0.1 * (z * z).tanh();
        let dstep = |z: &f64, a: &f64| {
            let t = (z * z).tanh();
            a * (1.0 + 0.1 * (1.0 - t * t) * 2.0 * z)
        };
        let run = |strategy| {
            run_backward(&plan(strategy, nt), &0.7f64, 1.0f64, step, dstep, |_| {}).unwrap()
        };
        let base = run(Strategy::StoreAll);
        for m in [1, 2, 4] {
            let g = run(Strategy::Revolve(m));
            assert!((g - base).abs() < 1e-14, "m={m}: {g} vs {base}");
        }
    }
}
