//! Barycentric trajectory interpolation for the interpolated adjoint
//! (Daulbaev et al., 2020 — see PAPERS.md).
//!
//! Instead of storing the whole forward trajectory (store-all) or
//! recomputing it from checkpoints (revolve/equispaced), the
//! interpolated adjoint stores a sparse set of **node states** captured
//! during the forward pass and reconstructs every intermediate step
//! input by barycentric Lagrange interpolation over those nodes.
//!
//! These helpers are the single source of node placement and
//! interpolation weights for BOTH execution paths — the interpreter
//! (`api::strategy`'s interp-adjoint strategy) and the compiled lowering
//! (`compile::plan::TrainProgram`, which const-folds the coefficient
//! bits into the plan) — which is what makes compiled ≡ sim bitwise for
//! the strategy: identical node indices, identical f32 coefficients,
//! identical zero-then-axpy accumulation order.

/// Node indices for a `p`-node interpolation grid over states `0..=nt`,
/// always including both endpoints (the block input and output, which
/// the coordinator holds anyway). `p` is clamped to `[2, nt + 1]`; with
/// `p == nt + 1` every state is a node and reconstruction is exact.
pub fn interp_nodes(nt: usize, p: usize) -> Vec<usize> {
    let p = p.clamp(2, nt + 1);
    // Equispaced with exact endpoints; floor(j*nt/(p-1)) is strictly
    // increasing because the real step nt/(p-1) is >= 1 when p <= nt+1.
    (0..p).map(|j| j * nt / (p - 1)).collect()
}

/// Barycentric Lagrange coefficients `c_j(t)` such that the
/// reconstructed state is `ẑ_t = Σ_j c_j(t) · z_{nodes[j]}`.
///
/// Weights are computed in f64 and rounded to f32 once per coefficient —
/// the exact bits the compiled plan folds in at build time. At a node
/// point the coefficients are exactly one-hot, so stored node states are
/// reproduced bitwise (the backward at a node never mixes arithmetic in).
pub fn interp_coeffs(nodes: &[usize], t: usize) -> Vec<f32> {
    if let Some(j) = nodes.iter().position(|&x| x == t) {
        let mut c = vec![0.0f32; nodes.len()];
        c[j] = 1.0;
        return c;
    }
    // w_j = 1 / Π_{k≠j} (x_j - x_k); c_j(t) = (w_j / (t - x_j)) / Σ_k (...).
    let xs: Vec<f64> = nodes.iter().map(|&x| x as f64).collect();
    let td = t as f64;
    let terms: Vec<f64> = xs
        .iter()
        .enumerate()
        .map(|(j, &xj)| {
            let prod: f64 = xs
                .iter()
                .enumerate()
                .filter(|&(k, _)| k != j)
                .map(|(_, &xk)| xj - xk)
                .product();
            1.0 / (prod * (td - xj))
        })
        .collect();
    let denom: f64 = terms.iter().sum();
    terms.iter().map(|&w| (w / denom) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nodes_are_strictly_increasing_with_exact_endpoints() {
        for nt in 1..=12usize {
            for p in 0..=16usize {
                let nodes = interp_nodes(nt, p);
                assert_eq!(nodes.len(), p.clamp(2, nt + 1), "nt={nt} p={p}");
                assert_eq!(nodes[0], 0, "nt={nt} p={p}");
                assert_eq!(*nodes.last().unwrap(), nt, "nt={nt} p={p}");
                assert!(nodes.windows(2).all(|w| w[0] < w[1]), "nt={nt} p={p}: {nodes:?}");
            }
        }
    }

    #[test]
    fn coeffs_are_exactly_one_hot_at_node_points() {
        let nodes = interp_nodes(8, 4);
        for (j, &n) in nodes.iter().enumerate() {
            let c = interp_coeffs(&nodes, n);
            for (k, &ck) in c.iter().enumerate() {
                let want = if k == j { 1.0f32 } else { 0.0 };
                assert_eq!(ck.to_bits(), want.to_bits(), "node {n} coeff {k}");
            }
        }
    }

    #[test]
    fn coeffs_sum_to_one_and_reproduce_polynomials() {
        // Barycentric interpolation on p nodes is exact for polynomials of
        // degree <= p-1; the trajectory z_t = 2 + 3t - t^2 + t^3/4 has
        // degree 3, so p = 4 nodes reconstruct every state.
        let nt = 8usize;
        let nodes = interp_nodes(nt, 4);
        let z = |t: f64| 2.0 + 3.0 * t - t * t + t * t * t / 4.0;
        for t in 0..=nt {
            let c = interp_coeffs(&nodes, t);
            let sum: f64 = c.iter().map(|&x| x as f64).sum();
            assert!((sum - 1.0).abs() < 1e-6, "t={t}: coeffs sum {sum}");
            let rec: f64 = nodes.iter().zip(&c).map(|(&j, &cj)| cj as f64 * z(j as f64)).sum();
            assert!(
                (rec - z(t as f64)).abs() < 1e-4 * z(t as f64).abs().max(1.0),
                "t={t}: reconstructed {rec} vs exact {}",
                z(t as f64)
            );
        }
    }

    /// One adjoint sweep over smooth scalar dynamics
    /// `z_{t+1} = z_t + h·(θ·z_t − z_t³)`, loss `L = ½·z_nt²`,
    /// reconstructing step inputs from `p` interpolation nodes
    /// (`p == nt+1` degenerates to the exact store-everything sweep —
    /// the symplectic strategy's shape). Returns dL/dθ.
    fn adjoint_grad(nt: usize, p: usize, theta: f64) -> f64 {
        let h = 0.1f64;
        let step = |z: f64| z + h * (theta * z - z * z * z);
        let mut traj = vec![0.8f64];
        for t in 0..nt {
            traj.push(step(traj[t]));
        }
        let nodes = interp_nodes(nt, p);
        let mut adj = traj[nt]; // dL/dz_nt
        let mut gtheta = 0.0f64;
        for t in (0..nt).rev() {
            let c = interp_coeffs(&nodes, t);
            let zt: f64 = nodes.iter().zip(&c).map(|(&j, &cj)| cj as f64 * traj[j]).sum();
            gtheta += adj * h * zt; // ∂f/∂θ = h·z
            adj *= 1.0 + h * (theta - 3.0 * zt * zt); // ∂f/∂z
        }
        gtheta
    }

    fn loss(nt: usize, theta: f64) -> f64 {
        let h = 0.1f64;
        let mut z = 0.8f64;
        for _ in 0..nt {
            z += h * (theta * z - z * z * z);
        }
        0.5 * z * z
    }

    /// Gradcheck against central finite differences: the exact sweep
    /// (p = nt+1, the symplectic/store-everything shape) matches FD to
    /// FD accuracy; sparse-node interpolated sweeps approximate it with
    /// error shrinking as nodes are added (Daulbaev's accuracy knob).
    #[test]
    fn adjoint_sweeps_match_finite_differences() {
        let (nt, theta, eps) = (8usize, 0.7f64, 1e-6f64);
        let fd = (loss(nt, theta + eps) - loss(nt, theta - eps)) / (2.0 * eps);
        assert!(fd.abs() > 1e-3, "degenerate test problem: fd={fd}");

        let exact = adjoint_grad(nt, nt + 1, theta);
        let rel = |g: f64| (g - fd).abs() / fd.abs().max(1e-12);
        assert!(rel(exact) < 1e-4, "exact sweep vs FD: {exact} vs {fd}");

        let e3 = rel(adjoint_grad(nt, 3, theta));
        let e5 = rel(adjoint_grad(nt, 5, theta));
        let e9 = rel(adjoint_grad(nt, 9, theta));
        assert!(e9 < 1e-4, "all-node interp must be exact: {e9}");
        assert!(e5 < 0.02, "5-node interp error too large: {e5}");
        assert!(e3 < 0.1, "3-node interp error too large: {e3}");
        assert!(e9 <= e3, "error must shrink with nodes: e3={e3} e9={e9}");
    }
}
