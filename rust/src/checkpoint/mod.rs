//! Checkpointing schedules for adjoint computation (paper §V).
//!
//! The ANODE backward pass needs the forward states z_0..z_{Nt-1} of each
//! ODE block in *reverse* order. Storing all of them costs O(Nt) memory;
//! the classical alternative (Griewank [17], Griewank & Walther's `revolve`
//! [18]) stores only `m` checkpoints and recomputes the rest, with provably
//! minimal recomputation.
//!
//! This module provides:
//! - [`Strategy`]: store-all / equispaced(m) / revolve(m) / O(1),
//! - [`plan`]: turn a strategy into an explicit [`Schedule`] of actions,
//! - [`run_backward`]: replay a schedule against any step function while
//!   enforcing the memory budget (used by the coordinator and the tests),
//! - [`binomial_eta`]: Griewank's η(m, r) optimality bound used to *prove*
//!   (in tests) the revolve plan achieves the theoretical minimum.

mod executor;
pub mod interp;
mod revolve;

pub use executor::run_backward;
pub use interp::{interp_coeffs, interp_nodes};
pub use revolve::{binomial_eta, min_recomputations, revolve_plan};

/// How to trade memory for recomputation inside one ODE block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Store every intermediate state (PyTorch-style autograd): O(Nt) memory,
    /// zero recomputation.
    StoreAll,
    /// Keep `m` equispaced checkpoints; recompute segments from the nearest
    /// one (the "naive approach" the paper contrasts with revolve).
    Equispaced(usize),
    /// Griewank–Walther binomial checkpointing with `m` checkpoint slots:
    /// provably minimal recomputation.
    Revolve(usize),
    /// Only the block input is kept: O(1) memory, O(Nt²) recomputation
    /// (the paper's extreme case).
    MinMemory,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::StoreAll => "store_all".into(),
            Strategy::Equispaced(m) => format!("equispaced({m})"),
            Strategy::Revolve(m) => format!("revolve({m})"),
            Strategy::MinMemory => "min_memory".into(),
        }
    }

    /// Checkpoint slots this strategy may hold at once (incl. block input).
    pub fn slots(&self, nt: usize) -> usize {
        match self {
            Strategy::StoreAll => nt + 1,
            Strategy::Equispaced(m) | Strategy::Revolve(m) => (*m).max(1),
            Strategy::MinMemory => 1,
        }
    }
}

/// One primitive action in a checkpointing schedule over steps 0..nt.
///
/// States are numbered 0..=nt (state i is *before* step i); the executor
/// holds states in named slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Copy the current state into checkpoint slot `slot` (records state id
    /// for validation).
    Checkpoint { slot: usize, state: usize },
    /// Restore the current state from slot `slot` (must hold `state`).
    Restore { slot: usize, state: usize },
    /// Advance the current state by one forward step: state -> state+1.
    /// `store_tape` marks steps whose input is pushed to the adjoint tape
    /// (i.e. this forward step will be immediately followed by its VJP).
    Forward { state: usize, store_tape: bool },
    /// Consume the tape entry for step `state` -> `state`+1 and apply its
    /// VJP, moving the adjoint from `state`+1 to `state`.
    Backward { state: usize },
}

/// A full schedule: actions plus bookkeeping for validation.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub nt: usize,
    pub strategy: Strategy,
    pub actions: Vec<Action>,
}

impl Schedule {
    /// Count of forward-step evaluations (the recomputation cost measure;
    /// an ideal store-all run uses exactly `nt`).
    pub fn forward_evals(&self) -> usize {
        self.actions.iter().filter(|a| matches!(a, Action::Forward { .. })).count()
    }

    /// Recomputations beyond the mandatory first forward sweep.
    pub fn extra_forwards(&self) -> usize {
        self.forward_evals().saturating_sub(self.nt)
    }

    /// Peak number of simultaneously-live checkpoint slots.
    pub fn peak_slots(&self) -> usize {
        let mut live: std::collections::HashSet<usize> = Default::default();
        let mut peak = 0;
        for a in &self.actions {
            if let Action::Checkpoint { slot, .. } = a {
                live.insert(*slot);
                peak = peak.max(live.len());
            }
        }
        peak
    }

    /// Peak tape depth (states held for pending VJPs). Store-all tapes the
    /// whole trajectory (= Nt); revolve/equispaced tape one step at a time.
    pub fn peak_tape(&self) -> usize {
        let mut depth = 0usize;
        let mut peak = 0usize;
        for a in &self.actions {
            match a {
                Action::Forward { store_tape: true, .. } => {
                    depth += 1;
                    peak = peak.max(depth);
                }
                Action::Backward { .. } => depth = depth.saturating_sub(1),
                _ => {}
            }
        }
        peak
    }

    /// Peak live states = checkpoint slots + tape depth, the true memory
    /// measure (in units of one activation) used by the memory ledger.
    pub fn peak_states(&self) -> usize {
        let mut live: std::collections::HashSet<usize> = Default::default();
        let mut depth = 0usize;
        let mut peak = 0usize;
        for a in &self.actions {
            match a {
                Action::Checkpoint { slot, .. } => {
                    live.insert(*slot);
                }
                Action::Forward { store_tape: true, .. } => depth += 1,
                Action::Backward { .. } => depth = depth.saturating_sub(1),
                _ => {}
            }
            peak = peak.max(live.len() + depth);
        }
        peak
    }

    /// Validate the schedule is executable and computes every VJP exactly
    /// once in reverse order. Returns the list of violated invariants.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let mut cur: Option<usize> = Some(0); // current forward state
        let mut slots: std::collections::HashMap<usize, usize> = Default::default();
        let mut tape: Vec<usize> = Vec::new(); // stack of step inputs
        let mut next_backward = self.nt; // expect Backward nt-1, nt-2, ...
        for (idx, a) in self.actions.iter().enumerate() {
            match *a {
                Action::Checkpoint { slot, state } => {
                    if cur != Some(state) {
                        errs.push(format!("action {idx}: checkpoint of state {state} but current is {cur:?}"));
                    }
                    slots.insert(slot, state);
                }
                Action::Restore { slot, state } => match slots.get(&slot) {
                    Some(&s) if s == state => cur = Some(state),
                    other => errs.push(format!(
                        "action {idx}: restore slot {slot} expected state {state}, holds {other:?}"
                    )),
                },
                Action::Forward { state, store_tape } => {
                    if cur != Some(state) {
                        errs.push(format!("action {idx}: forward from {state} but current is {cur:?}"));
                    }
                    if store_tape {
                        tape.push(state);
                    }
                    cur = Some(state + 1);
                }
                Action::Backward { state } => {
                    if state + 1 != next_backward {
                        errs.push(format!(
                            "action {idx}: backward over step {state} out of order (expected {})",
                            next_backward - 1
                        ));
                    }
                    match tape.pop() {
                        Some(s) if s == state => {}
                        other => errs.push(format!(
                            "action {idx}: tape top {other:?} but backward needs {state}"
                        )),
                    }
                    next_backward = state;
                }
            }
        }
        if next_backward != 0 {
            errs.push(format!("did not backward through all steps (stopped at {next_backward})"));
        }
        errs
    }
}

/// Build the action schedule for a strategy over `nt` steps.
///
/// Degenerate grids (`m >= nt`, which covers `nt == 1` and `m == nt`)
/// hold every state within budget, so budgeted strategies emit the
/// store-everything action list instead of a restore/replay schedule
/// with zero-length recompute segments.
pub fn plan(strategy: Strategy, nt: usize) -> Schedule {
    assert!(nt > 0);
    let actions = match strategy {
        Strategy::StoreAll => store_all_plan(nt),
        Strategy::MinMemory => min_memory_plan(nt),
        Strategy::Equispaced(m) | Strategy::Revolve(m) if m.max(1) >= nt => store_all_plan(nt),
        Strategy::Equispaced(m) => equispaced_plan(nt, m.max(1)),
        Strategy::Revolve(m) => revolve::revolve_plan(nt, m.max(1)),
    };
    Schedule { nt, strategy, actions }
}

/// Store-everything action list: tape every forward, then run the VJPs
/// in reverse — no checkpoint slots, no recomputation.
fn store_all_plan(nt: usize) -> Vec<Action> {
    let mut acts = Vec::with_capacity(2 * nt);
    for i in 0..nt {
        acts.push(Action::Forward { state: i, store_tape: true });
    }
    for i in (0..nt).rev() {
        acts.push(Action::Backward { state: i });
    }
    acts
}

/// Pick the cheapest strategy whose per-block activation memory fits
/// `budget_bytes`, given `nt` steps of `act_bytes` each.
///
/// Preference order (paper §V): the fused DTO backward (store-all within
/// the block, O(Nt)) when it fits; otherwise revolve(m) with the largest m
/// that fits (peak = m slots + 1 tape state); never fails — m=1 is the
/// O(1)-memory extreme with O(Nt²) recompute.
pub fn suggest_strategy(nt: usize, act_bytes: usize, budget_bytes: usize) -> Strategy {
    if act_bytes == 0 || (nt + 1) * act_bytes <= budget_bytes {
        return Strategy::StoreAll;
    }
    let slots = budget_bytes / act_bytes;
    let m = slots.saturating_sub(1).max(1).min(nt);
    Strategy::Revolve(m)
}

/// O(1)-memory plan: recompute from the block input for every step.
/// Cost: nt + (nt-1) + ... + 1 = O(nt²) forwards.
fn min_memory_plan(nt: usize) -> Vec<Action> {
    let mut acts = vec![Action::Checkpoint { slot: 0, state: 0 }];
    for target in (0..nt).rev() {
        acts.push(Action::Restore { slot: 0, state: 0 });
        for s in 0..target {
            acts.push(Action::Forward { state: s, store_tape: false });
        }
        acts.push(Action::Forward { state: target, store_tape: true });
        acts.push(Action::Backward { state: target });
    }
    acts
}

/// Equispaced-m plan (the paper's "naive approach": checkpoint the
/// trajectory at equispaced points; when a state is needed, forward-solve
/// from the nearest saved value). Tape depth is 1 — each step's VJP runs
/// right after that step is recomputed.
fn equispaced_plan(nt: usize, m: usize) -> Vec<Action> {
    // Checkpoint states: 0 plus up to m-1 further equispaced states.
    let mut cps: Vec<usize> = vec![0];
    if m > 1 {
        for k in 1..m {
            let s = k * nt / m;
            if s > 0 && s < nt && !cps.contains(&s) {
                cps.push(s);
            }
        }
    }
    cps.sort();
    let slot_of = |state: usize, cps: &[usize]| cps.iter().position(|&c| c == state).unwrap();

    let mut acts = Vec::new();
    // Positioning descent: advance once to the last checkpoint position,
    // dropping checkpoints on the way (backward-phase-only schedule; the
    // training forward pass itself uses the fused block_fwd artifact).
    let last_cp = *cps.last().unwrap();
    for s in 0..=last_cp {
        if cps.contains(&s) {
            acts.push(Action::Checkpoint { slot: slot_of(s, &cps), state: s });
        }
        if s < last_cp {
            acts.push(Action::Forward { state: s, store_tape: false });
        }
    }
    // Backward: for each step t (last first), replay from the nearest
    // checkpoint <= t, tape only step t, then run its VJP.
    for t in (0..nt).rev() {
        let cp = *cps.iter().filter(|&&c| c <= t).max().unwrap();
        acts.push(Action::Restore { slot: slot_of(cp, &cps), state: cp });
        for s in cp..t {
            acts.push(Action::Forward { state: s, store_tape: false });
        }
        acts.push(Action::Forward { state: t, store_tape: true });
        acts.push(Action::Backward { state: t });
    }
    acts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_all_is_valid_and_minimal() {
        for nt in [1, 2, 5, 16] {
            let s = plan(Strategy::StoreAll, nt);
            assert!(s.validate().is_empty(), "{:?}", s.validate());
            assert_eq!(s.forward_evals(), nt);
            assert_eq!(s.extra_forwards(), 0);
        }
    }

    #[test]
    fn min_memory_is_valid_and_quadratic() {
        for nt in [1, 2, 5, 12] {
            let s = plan(Strategy::MinMemory, nt);
            assert!(s.validate().is_empty(), "{:?}", s.validate());
            assert_eq!(s.forward_evals(), nt * (nt + 1) / 2);
            assert_eq!(s.peak_slots(), 1);
        }
    }

    #[test]
    fn equispaced_is_valid() {
        for nt in [1, 2, 5, 16, 33] {
            for m in [1, 2, 3, 5, 8] {
                let s = plan(Strategy::Equispaced(m), nt);
                let errs = s.validate();
                assert!(errs.is_empty(), "nt={nt} m={m}: {errs:?}");
                assert!(s.peak_slots() <= m.max(1));
            }
        }
    }

    #[test]
    fn equispaced_cost_between_storeall_and_minmem() {
        let nt = 32;
        let all = plan(Strategy::StoreAll, nt).forward_evals();
        let one = plan(Strategy::MinMemory, nt).forward_evals();
        for m in [2, 4, 8] {
            let e = plan(Strategy::Equispaced(m), nt).forward_evals();
            assert!(e >= all && e <= one, "m={m}: {e} not in [{all}, {one}]");
        }
    }

    /// Regression sweep over the degenerate (nt, m) edge: `nt < m`,
    /// `nt == 1`, and `m == nt` must all produce the valid
    /// store-everything schedule — exactly nt taped forwards, no
    /// checkpoint slots, no restore/replay with zero-length recompute
    /// segments (what the budgeted planners used to emit here).
    #[test]
    fn degenerate_grids_produce_store_everything_schedules() {
        let budgeted: [fn(usize) -> Strategy; 2] = [Strategy::Equispaced, Strategy::Revolve];
        for make in budgeted {
            for (nt, m) in [(1, 1), (1, 4), (2, 2), (3, 3), (3, 7), (5, 5), (5, 6), (8, 64)] {
                let s = plan(make(m), nt);
                let errs = s.validate();
                assert!(errs.is_empty(), "nt={nt} m={m}: {errs:?}");
                assert_eq!(s.forward_evals(), nt, "nt={nt} m={m}: must not recompute");
                assert_eq!(s.extra_forwards(), 0, "nt={nt} m={m}");
                assert_eq!(s.peak_slots(), 0, "nt={nt} m={m}: no checkpoint slots needed");
                assert_eq!(s.peak_tape(), nt, "nt={nt} m={m}: whole trajectory taped");
                assert_eq!(
                    s.actions,
                    plan(Strategy::StoreAll, nt).actions,
                    "nt={nt} m={m}: not the store-everything action list"
                );
            }
        }
        // The edge of the edge: m = nt - 1 must still be a real
        // checkpointing schedule (the degenerate arm must not over-fire).
        for nt in [2usize, 3, 5, 8] {
            let s = plan(Strategy::Revolve(nt - 1), nt);
            assert!(s.validate().is_empty());
            assert!(s.extra_forwards() > 0, "nt={nt}: m=nt-1 must recompute");
        }
    }

    #[test]
    fn validate_catches_bad_schedules() {
        let bad = Schedule {
            nt: 2,
            strategy: Strategy::StoreAll,
            actions: vec![
                Action::Forward { state: 0, store_tape: true },
                // missing forward of step 1
                Action::Backward { state: 1 },
                Action::Backward { state: 0 },
            ],
        };
        assert!(!bad.validate().is_empty());
    }

    #[test]
    fn slots_metadata() {
        assert_eq!(Strategy::StoreAll.slots(8), 9);
        assert_eq!(Strategy::Revolve(3).slots(8), 3);
        assert_eq!(Strategy::MinMemory.slots(8), 1);
    }

    #[test]
    fn suggest_strategy_respects_budget() {
        let act = 1000;
        // Plenty of memory: fused store-all within the block.
        assert_eq!(suggest_strategy(8, act, 10_000), Strategy::StoreAll);
        // Half the trajectory fits: revolve with the m that fits.
        assert_eq!(suggest_strategy(8, act, 5_000), Strategy::Revolve(4));
        // Two states fit: revolve(1) (the O(1) extreme).
        assert_eq!(suggest_strategy(8, act, 2_000), Strategy::Revolve(1));
        // Even a degenerate budget yields a runnable plan.
        assert_eq!(suggest_strategy(8, act, 0), Strategy::Revolve(1));
        // The suggestion's schedule really stays within the stated peak.
        for budget in [2_000usize, 3_000, 5_000, 9_000] {
            let s = suggest_strategy(8, act, budget);
            let sched = plan(s, 8);
            assert!(sched.validate().is_empty());
            if let Strategy::Revolve(m) = s {
                assert!((m + 1) * act <= budget.max(2 * act), "m={m} budget={budget}");
            }
        }
    }
}
