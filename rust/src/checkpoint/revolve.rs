//! Griewank–Walther binomial checkpointing ("revolve", [17, 18] in the
//! paper).
//!
//! Problem: the backward pass of one ODE block must apply the VJP of steps
//! nt-1, nt-2, ..., 0 in that order, but only the block *input* (state 0)
//! was kept. With `m` checkpoint slots, which states should be stored, and
//! when recomputed, to minimize total forward-step evaluations?
//!
//! Griewank proved the optimum is attained by a binomial recursion: store a
//! checkpoint at a split point δ, reverse the right segment with one fewer
//! free slot, release the slot, reverse the left segment. We compute the
//! optimal split with a memoized DP over (steps, free_slots) — which by
//! Griewank's theorem attains the binomial bound — and emit the explicit
//! action schedule. Tests assert the DP cost matches the closed-form
//! binomial values.

use std::collections::HashMap;

use super::Action;

/// β(s, r) = C(s+r, s): the maximal number of steps reversible with `s`
/// checkpoint slots and `r` repeated forward sweeps (Griewank's bound).
pub fn binomial_eta(s: usize, r: usize) -> u64 {
    // C(s+r, k) with k = min(s, r); the product form stays integral because
    // C(n, i+1) = C(n, i) * (n-i) / (i+1) is exact at every prefix.
    let n = s + r;
    let k = s.min(r);
    let mut res: u64 = 1;
    for i in 0..k {
        res = res.saturating_mul((n - i) as u64) / (i + 1) as u64;
    }
    res
}

/// DP over (l, s): minimal forward evaluations (including the taped forward
/// before each VJP) to reverse `l` steps given the segment's start state is
/// checkpointed and `s` additional slots are free.
fn opt_cost(l: usize, s: usize, memo: &mut HashMap<(usize, usize), (u64, usize)>) -> u64 {
    if l == 0 {
        return 0;
    }
    if l == 1 {
        return 1; // one taped forward + its VJP
    }
    if s == 0 {
        // Replay from the start for every target: sum_{t=0}^{l-1} (t+1).
        return (l as u64) * (l as u64 + 1) / 2;
    }
    if let Some(&(c, _)) = memo.get(&(l, s)) {
        return c;
    }
    let mut best = u64::MAX;
    let mut best_d = 1;
    for d in 1..l {
        // Advance d steps, drop a checkpoint, reverse right (s-1 free),
        // release, reverse left (s free).
        let c = d as u64
            + opt_cost(l - d, s - 1, memo)
            + opt_cost(d, s, memo);
        if c < best {
            best = c;
            best_d = d;
        }
    }
    // Also allow "don't use further checkpoints".
    let no_cp = (l as u64) * (l as u64 + 1) / 2;
    if no_cp < best {
        best = no_cp;
        best_d = 0; // sentinel: no checkpoint
    }
    memo.insert((l, s), (best, best_d));
    best
}

/// Minimal forward evaluations to reverse `nt` steps with `m` total slots
/// (one of which holds the block input). With `m >= nt` the budget holds
/// every state, so the schedule degenerates to store-everything and the
/// cost is the mandatory `nt` taped forwards — the recursion family's
/// checkpoint descent would pay untaped positioning advances it no
/// longer needs.
pub fn min_recomputations(nt: usize, m: usize) -> u64 {
    if m >= nt {
        return nt as u64;
    }
    let mut memo = HashMap::new();
    opt_cost(nt, m.saturating_sub(1), &mut memo)
}

struct Gen {
    actions: Vec<Action>,
    memo: HashMap<(usize, usize), (u64, usize)>,
    free_slots: Vec<usize>,
}

impl Gen {
    /// Reverse steps [lo, lo+l) given state `lo` in `slot`, with
    /// `self.free_slots` available for sub-checkpoints.
    fn rec(&mut self, lo: usize, l: usize, slot: usize) {
        if l == 0 {
            return;
        }
        if l == 1 {
            self.actions.push(Action::Restore { slot, state: lo });
            self.actions.push(Action::Forward { state: lo, store_tape: true });
            self.actions.push(Action::Backward { state: lo });
            return;
        }
        let s = self.free_slots.len();
        let d = if s == 0 {
            0
        } else {
            opt_cost(l, s, &mut self.memo);
            self.memo.get(&(l, s)).map(|&(_, d)| d).unwrap_or(0)
        };
        if d == 0 {
            // No further checkpoints: replay from lo for each target.
            for t in (0..l).rev() {
                self.actions.push(Action::Restore { slot, state: lo });
                for k in 0..t {
                    self.actions.push(Action::Forward { state: lo + k, store_tape: false });
                }
                self.actions.push(Action::Forward { state: lo + t, store_tape: true });
                self.actions.push(Action::Backward { state: lo + t });
            }
            return;
        }
        // Advance to the split point and drop a checkpoint there.
        self.actions.push(Action::Restore { slot, state: lo });
        for k in 0..d {
            self.actions.push(Action::Forward { state: lo + k, store_tape: false });
        }
        let sub = self.free_slots.pop().expect("free slot");
        self.actions.push(Action::Checkpoint { slot: sub, state: lo + d });
        self.rec(lo + d, l - d, sub);
        self.free_slots.push(sub); // slot released after right segment
        self.rec(lo, d, slot);
    }
}

/// Build the revolve action schedule for `nt` steps with `m` slots.
///
/// The schedule is backward-phase-only: the training forward pass runs the
/// fused `block_fwd` artifact, the coordinator keeps the block input, and
/// this schedule reconstructs/reverses using `step_fwd`/`step_vjp` modules.
pub fn revolve_plan(nt: usize, m: usize) -> Vec<Action> {
    let mut g = Gen {
        actions: vec![Action::Checkpoint { slot: 0, state: 0 }],
        memo: HashMap::new(),
        free_slots: (1..m).collect(),
    };
    g.rec(0, nt, 0);
    g.actions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::{plan, Schedule, Strategy};

    #[test]
    fn beta_values() {
        assert_eq!(binomial_eta(1, 1), 2);
        assert_eq!(binomial_eta(2, 1), 3);
        assert_eq!(binomial_eta(2, 2), 6);
        assert_eq!(binomial_eta(3, 3), 20);
        assert_eq!(binomial_eta(0, 5), 1);
        assert_eq!(binomial_eta(5, 0), 1);
    }

    #[test]
    fn dp_matches_hand_checked_small_cases() {
        // l=1: single taped forward.
        assert_eq!(min_recomputations(1, 1), 1);
        // m=1 (no free slots): quadratic replay.
        assert_eq!(min_recomputations(4, 1), 10);
        assert_eq!(min_recomputations(8, 1), 36);
        // m == nt: the budget holds every state — store-everything, nt
        // taped forwards, no positioning advances.
        assert_eq!(min_recomputations(2, 2), 2);
        // l=3, one free slot: 1 + OPT(2,0)=3 + OPT(1,1)=1 -> 5.
        assert_eq!(min_recomputations(3, 2), 5);
        // Plenty of slots (m > nt): still the store-everything degenerate
        // case — exactly the mandatory nt taped forwards.
        assert_eq!(min_recomputations(4, 16), 4);
    }

    #[test]
    fn revolve_schedule_is_valid_for_many_sizes() {
        for nt in [1, 2, 3, 5, 8, 16, 33] {
            for m in [1, 2, 3, 5, 9] {
                let s = plan(Strategy::Revolve(m), nt);
                let errs = s.validate();
                assert!(errs.is_empty(), "nt={nt} m={m}: {errs:?}");
            }
        }
    }

    #[test]
    fn revolve_cost_matches_dp() {
        for nt in [1, 2, 5, 8, 16, 33] {
            for m in [1, 2, 3, 5] {
                let s = plan(Strategy::Revolve(m), nt);
                assert_eq!(
                    s.forward_evals() as u64,
                    min_recomputations(nt, m),
                    "nt={nt} m={m}"
                );
            }
        }
    }

    #[test]
    fn revolve_never_exceeds_slot_budget() {
        for nt in [5, 16, 33] {
            for m in [1, 2, 3, 5] {
                let s = plan(Strategy::Revolve(m), nt);
                assert!(s.peak_slots() <= m, "nt={nt} m={m}: {}", s.peak_slots());
                if m < nt {
                    // Tape depth stays 1 (single pending VJP at a time).
                    assert!(s.peak_tape() <= 1);
                    assert!(s.peak_states() <= m + 1);
                } else {
                    // Degenerate budget: store-everything tapes the whole
                    // trajectory, still within the m+1 modeled states.
                    assert_eq!(s.peak_tape(), nt, "nt={nt} m={m}");
                    assert!(s.peak_states() <= m + 1, "nt={nt} m={m}");
                }
            }
        }
    }

    #[test]
    fn revolve_beats_or_ties_equispaced() {
        // Both plans are backward-phase-only; revolve is the optimal member
        // of the family, so it can never lose.
        for nt in [8, 16, 32] {
            for m in [2, 3, 4, 6] {
                let r = plan(Strategy::Revolve(m), nt).forward_evals();
                let e = plan(Strategy::Equispaced(m), nt).forward_evals();
                assert!(r <= e, "nt={nt} m={m}: revolve {r} vs equispaced {e}");
            }
        }
    }

    #[test]
    fn revolve_cost_decreases_with_memory() {
        let nt = 32;
        let mut prev = u64::MAX;
        for m in 1..=12 {
            let c = min_recomputations(nt, m);
            assert!(c <= prev, "m={m}: {c} > {prev}");
            prev = c;
        }
    }

    #[test]
    fn binomial_reachability_bound_holds() {
        // Griewank: with s free slots and cost <= (r+1)*l forwards one can
        // reverse up to beta(s, r) steps. Check the DP respects the bound:
        // for l = beta(s, r), cost <= (r+1) * l.
        for s in 1..=4usize {
            for r in 1..=4usize {
                let l = binomial_eta(s, r) as usize;
                let c = min_recomputations(l, s + 1);
                assert!(
                    c <= ((r + 1) as u64) * (l as u64),
                    "s={s} r={r} l={l}: cost {c}"
                );
            }
        }
    }

    /// Brute-force optimality cross-check on small instances: enumerate all
    /// schedules of the recursion family via the DP, and compare against an
    /// independent exhaustive search over split positions.
    #[test]
    fn dp_agrees_with_exhaustive_search() {
        fn exhaustive(l: usize, s: usize) -> u64 {
            if l == 0 {
                return 0;
            }
            if l == 1 {
                return 1;
            }
            if s == 0 {
                return (l as u64) * (l as u64 + 1) / 2;
            }
            let mut best = (l as u64) * (l as u64 + 1) / 2;
            for d in 1..l {
                let c = d as u64 + exhaustive(l - d, s - 1) + exhaustive(d, s);
                best = best.min(c);
            }
            best
        }
        for l in 1..=12 {
            for s in 0..=3 {
                // m = s+1 >= l is the degenerate store-everything case: the
                // m unused slots buy a whole-trajectory tape within the
                // modeled m+1 states, beating the recursion family (whose
                // tape depth stays 1). Sub-segments cannot play that trick —
                // their tape would stack on top of live checkpoints — so
                // the recursion family stays the right model below the top.
                let expect = if s + 1 >= l { l as u64 } else { exhaustive(l, s) };
                assert_eq!(min_recomputations(l, s + 1), expect, "l={l} s={s}");
            }
        }
    }

    /// Grid sweep (nt, m) ∈ {8,16,32,64} × {2,3,4,8}: the emitted revolve
    /// plan must (a) be a valid schedule within its slot budget, (b) attain
    /// the DP optimum exactly, and (c) respect Griewank's binomial
    /// reachability bound expressed through `binomial_eta` — with the
    /// minimal sweep count r such that β(m−1, r) ≥ nt, reversal costs at
    /// most (r+1)·nt forward evaluations and at least the mandatory nt.
    #[test]
    fn revolve_grid_matches_optimum_and_binomial_bound() {
        // Spot values independently cross-checked against the recurrence
        // (taped forward counted per VJP, replay-from-start base case).
        let expected: &[(usize, usize, u64)] =
            &[(8, 2, 22), (16, 3, 49), (32, 4, 107), (64, 8, 201)];
        for &(nt, m, cost) in expected {
            assert_eq!(min_recomputations(nt, m), cost, "nt={nt} m={m}");
        }

        for nt in [8usize, 16, 32, 64] {
            for m in [2usize, 3, 4, 8] {
                let sched = plan(Strategy::Revolve(m), nt);
                let errs = sched.validate();
                assert!(errs.is_empty(), "nt={nt} m={m}: {errs:?}");
                assert!(sched.peak_slots() <= m, "nt={nt} m={m}");

                let cost = sched.forward_evals() as u64;
                assert_eq!(cost, min_recomputations(nt, m), "nt={nt} m={m}: plan not optimal");

                let mut r = 0usize;
                while binomial_eta(m - 1, r) < nt as u64 {
                    r += 1;
                }
                assert!(
                    cost <= ((r + 1) as u64) * nt as u64,
                    "nt={nt} m={m}: cost {cost} above binomial bound with r={r}"
                );
                assert!(cost >= nt as u64, "nt={nt} m={m}: fewer forwards than steps");
            }
        }
    }

    #[test]
    fn schedule_peak_states_is_m_plus_tape() {
        let s: Schedule = plan(Strategy::Revolve(3), 16);
        assert!(s.peak_states() <= 4);
        let sa = plan(Strategy::StoreAll, 16);
        assert_eq!(sa.peak_tape(), 16);
    }
}
