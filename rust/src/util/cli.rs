//! Tiny CLI argument parser (`--flag`, `--key value`, positionals).
//! Replaces clap, which is unavailable in the offline image.
//!
//! Malformed option values are **hard errors**: `--steps abc` terminates
//! the process with a clear message instead of silently falling back to a
//! default. Options that were parsed but never consumed by the command can
//! be reported via [`Args::warn_unknown`].

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` options.
    opts: HashMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
    /// Keys the command actually consumed (for unknown-option warnings).
    consumed: RefCell<HashSet<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().insert(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed numeric option: `Ok(None)` if absent, `Err` with a clear
    /// message if present but unparseable.
    pub fn try_get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                format!(
                    "invalid value `{v}` for --{key} (expected {})",
                    std::any::type_name::<T>()
                )
            }),
        }
    }

    /// Parsed numeric option with default. A present-but-malformed value
    /// is a **hard error** (exit 2) — silently training for 200 steps
    /// because `--steps abc` failed to parse is worse than stopping.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.try_get_parse(key) {
            Ok(Some(v)) => v,
            Ok(None) => default,
            Err(msg) => {
                eprintln!("error: {msg}");
                std::process::exit(2);
            }
        }
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
            || self.opts.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Options and flags that were supplied but never consumed by the
    /// command — almost always typos (`--step` for `--steps`).
    pub fn unknown_options(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        let mut unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(*k))
            .cloned()
            .collect();
        unknown.sort();
        unknown.dedup();
        unknown
    }

    /// Warn (stderr) about supplied-but-unconsumed options. Call after the
    /// command has read everything it understands.
    pub fn warn_unknown(&self) {
        for k in self.unknown_options() {
            eprintln!("warning: unknown option --{k} (ignored — see `--help` for valid options)");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_opts() {
        let a = parse("train --arch resnet --steps 100 --verbose");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("arch"), Some("resnet"));
        assert_eq!(a.get_parse_or("steps", 0usize), 100);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--lr=0.1 --solver=rk2");
        assert_eq!(a.get_parse_or("lr", 0.0f64), 0.1);
        assert_eq!(a.get("solver"), Some("rk2"));
    }

    #[test]
    fn flag_before_positional_not_consumed_as_value() {
        // `--verbose train`: "train" does not start with --, so it is taken
        // as the value; callers should use --verbose=true before positionals.
        let a = parse("--steps 5 train");
        assert_eq!(a.get("steps"), Some("5"));
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("arch", "resnet"), "resnet");
        assert_eq!(a.get_parse_or("nt", 5usize), 5);
    }

    #[test]
    fn malformed_value_is_error_not_default() {
        let a = parse("--steps abc");
        let err = a.try_get_parse::<usize>("steps").unwrap_err();
        assert!(err.contains("abc"), "{err}");
        assert!(err.contains("--steps"), "{err}");
        // Absent key parses to None; well-formed parses to Some.
        assert_eq!(a.try_get_parse::<usize>("missing").unwrap(), None);
        let b = parse("--steps 7");
        assert_eq!(b.try_get_parse::<usize>("steps").unwrap(), Some(7));
    }

    #[test]
    fn unknown_options_are_reported() {
        let a = parse("train --arch resnet --stepz 100 --fastt");
        let _ = a.get("arch");
        let unknown = a.unknown_options();
        assert_eq!(unknown, vec!["fastt".to_string(), "stepz".to_string()]);
        // Consuming clears the report.
        let _ = a.get("stepz");
        assert!(a.has_flag("fastt") || !a.unknown_options().contains(&"fastt".to_string()));
        assert!(a.unknown_options().is_empty());
    }
}
