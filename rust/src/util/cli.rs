//! Tiny CLI argument parser (`--flag`, `--key value`, positionals).
//! Replaces clap, which is unavailable in the offline image.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` options.
    opts: HashMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed numeric option with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.opts.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_opts() {
        let a = parse("train --arch resnet --steps 100 --verbose");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("arch"), Some("resnet"));
        assert_eq!(a.get_parse_or("steps", 0usize), 100);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--lr=0.1 --solver=rk2");
        assert_eq!(a.get_parse_or("lr", 0.0f64), 0.1);
        assert_eq!(a.get("solver"), Some("rk2"));
    }

    #[test]
    fn flag_before_positional_not_consumed_as_value() {
        // `--verbose train`: "train" does not start with --, so it is taken
        // as the value; callers should use --verbose=true before positionals.
        let a = parse("--steps 5 train");
        assert_eq!(a.get("steps"), Some("5"));
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("arch", "resnet"), "resnet");
        assert_eq!(a.get_parse_or("nt", 5usize), 5);
    }
}
