//! Micro-benchmark timing harness (criterion replacement for the offline
//! image). Benches are built with `harness = false` and use [`bench`]
//! to run warmups + timed iterations and report mean/median/p95 as
//! [`BenchStats`].

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub p95: Duration,
}

impl BenchStats {
    /// One-line report in criterion-like format.
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={:>12?} median={:>12?} min={:>12?} p95={:>12?}",
            self.name, self.iters, self.mean, self.median, self.min, self.p95
        )
    }
}

/// Run `f` with `warmup` unrecorded calls then `iters` timed calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        median: samples[n / 2],
        min: samples[0],
        p95: samples[(n * 95 / 100).min(n - 1)],
    }
}

/// Time a single closure run.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Prevent the optimizer from discarding a value (std::hint-based).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Percentile over an already-sorted sample (`p` in 0..=100) using the
/// nearest-*index* method — `sorted[round(p/100 · (n−1))]`, numpy's
/// `interpolation="nearest"` — which differs from classic nearest-rank by
/// at most one sample. Serving benches use this for p50/p95/p99 latency.
pub fn percentile(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// p50/p95/p99 of a latency sample — the one summary used by the serve
/// CLI driver, the `serve_throughput`/`train_throughput` benches and any
/// future latency reporter, so the percentile math lives in one place.
#[derive(Debug, Clone, Copy)]
pub struct LatencyPercentiles {
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
}

impl LatencyPercentiles {
    /// Sort the sample in place and pick the percentiles (the sort is the
    /// caller-visible side effect callers relied on before this helper).
    pub fn from_unsorted(latencies: &mut [Duration]) -> Self {
        latencies.sort();
        Self {
            p50: percentile(latencies, 50.0),
            p95: percentile(latencies, 95.0),
            p99: percentile(latencies, 99.0),
        }
    }

    /// One-line `p50=.. p95=.. p99=..` report.
    pub fn report(&self) -> String {
        format!("p50={:?} p95={:?} p99={:?}", self.p50, self.p95, self.p99)
    }
}

/// Quick-mode switch for CI bench smoke runs: `ANODE_BENCH_QUICK=1` (or
/// `true`) shrinks iteration/request counts so the benches finish in
/// seconds while still emitting their `BENCH_*.json` artifacts.
pub fn quick_mode() -> bool {
    match std::env::var("ANODE_BENCH_QUICK") {
        Ok(v) => v == "1" || v.eq_ignore_ascii_case("true"),
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("noop", 2, 16, || {
            black_box(1 + 1);
        });
        assert_eq!(s.iters, 16);
        assert!(s.min <= s.median);
        assert!(s.median <= s.p95);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn latency_percentiles_sort_and_pick() {
        let mut sample: Vec<Duration> = (1..=100).rev().map(Duration::from_millis).collect();
        let p = LatencyPercentiles::from_unsorted(&mut sample);
        assert_eq!(sample[0], Duration::from_millis(1), "sample must be sorted in place");
        assert_eq!(p.p50, Duration::from_millis(51));
        assert_eq!(p.p95, Duration::from_millis(95));
        assert_eq!(p.p99, Duration::from_millis(99));
        assert!(p.report().contains("p95="), "{}", p.report());
    }

    #[test]
    fn percentile_nearest_rank() {
        let sorted: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&sorted, 0.0), Duration::from_millis(1));
        assert_eq!(percentile(&sorted, 50.0), Duration::from_millis(51));
        assert_eq!(percentile(&sorted, 99.0), Duration::from_millis(99));
        assert_eq!(percentile(&sorted, 100.0), Duration::from_millis(100));
        assert_eq!(percentile(&[], 50.0), Duration::ZERO);
        let one = [Duration::from_secs(2)];
        assert_eq!(percentile(&one, 99.0), Duration::from_secs(2));
    }
}
