//! A tiny scoped worker pool for data-parallel fan-out.
//!
//! The serving paths (`Session::predict_batches`, `Session::evaluate`)
//! split pre-batched work across a handful of std threads. Work is divided
//! into **contiguous chunks**, one per worker, and results come back in
//! input order — so reductions over the output see exactly the serial
//! ordering and parallel runs stay bit-identical to `workers = 1`.
//!
//! No queues, no channels, no unsafe: `std::thread::scope` lets workers
//! borrow the shared read-only state (`&ExecutionCore`, `&[Tensor]`)
//! directly, and each worker owns its mutable state (e.g. a
//! [`crate::memory::MemoryLedger`]) for the duration of its chunk.

/// Map `f(index, item)` over `items` on up to `workers` threads,
/// preserving input order in the output.
///
/// `workers <= 1` (or a single item) runs inline on the caller's thread —
/// the serial path is the parallel path with the pool turned off, not a
/// separate code path.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let (results, _) = parallel_map_with(items, workers, || (), move |_state, i, t| f(i, t));
    results
}

/// Like [`parallel_map`], but each worker thread carries private mutable
/// state created by `init` (one per worker, on the worker's own thread).
/// Returns the in-order results plus the per-worker states for the caller
/// to aggregate (e.g. merging worker memory ledgers).
pub fn parallel_map_with<S, T, R, FI, F>(
    items: &[T],
    workers: usize,
    init: FI,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    S: Send,
    T: Sync,
    R: Send,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let w = workers.max(1).min(n.max(1));
    if w <= 1 {
        let mut state = init();
        let results = items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
        return (results, vec![state]);
    }

    let chunk = n.div_ceil(w);
    let mut results = Vec::with_capacity(n);
    let mut states = Vec::with_capacity(w);
    std::thread::scope(|scope| {
        let init = &init;
        let f = &f;
        let mut handles = Vec::with_capacity(w);
        for (ci, chunk_items) in items.chunks(chunk).enumerate() {
            let base = ci * chunk;
            handles.push(scope.spawn(move || {
                let mut state = init();
                let out: Vec<R> = chunk_items
                    .iter()
                    .enumerate()
                    .map(|(j, t)| f(&mut state, base + j, t))
                    .collect();
                (out, state)
            }));
        }
        // Chunks are contiguous and joined in spawn order, so extending
        // reconstitutes the input order exactly. A panicking worker is
        // re-raised on the caller's thread, but only after every other
        // worker has been joined — callers see the original panic payload
        // and never a deadlock or a process abort.
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            match h.join() {
                Ok((out, state)) => {
                    results.extend(out);
                    states.push(state);
                }
                Err(payload) => {
                    if panic.is_none() {
                        panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    });
    (results, states)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order_for_any_worker_count() {
        let items: Vec<usize> = (0..97).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * 3).collect();
        for workers in [1, 2, 3, 4, 8, 97, 200] {
            let par = parallel_map(&items, workers, |i, &x| {
                assert_eq!(i, x, "index must match the item's input position");
                x * 3
            });
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn per_worker_state_counts_partition_the_items() {
        let items: Vec<u32> = (0..40).collect();
        let count_and_copy = |count: &mut usize, _i: usize, x: &u32| {
            *count += 1;
            *x
        };
        for workers in [1, 3, 4, 7] {
            let (results, states) = parallel_map_with(&items, workers, || 0usize, count_and_copy);
            assert_eq!(results, items, "workers={workers}");
            assert!(states.len() <= workers.max(1));
            assert_eq!(states.iter().sum::<usize>(), items.len(), "workers={workers}");
        }
    }

    #[test]
    fn worker_panic_propagates_without_deadlock_or_abort() {
        let items: Vec<usize> = (0..32).collect();
        // catch_unwind (not #[should_panic]): proves the panic surfaces as
        // an ordinary unwind on the caller's thread — a worker panic that
        // aborted the process or deadlocked the join loop would fail here.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(&items, 4, |_, &x| {
                if x == 13 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = outcome.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at 13"), "original payload lost: {msg:?}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u8> = Vec::new();
        let (results, states) = parallel_map_with(&empty, 4, || 0u8, |_, _, &x| x);
        assert!(results.is_empty());
        assert_eq!(states.len(), 1);
        assert_eq!(parallel_map(&[5u8], 4, |_, &x| x + 1), vec![6]);
    }
}
