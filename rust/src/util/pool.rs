//! Worker pools for data-parallel fan-out — the single execution substrate
//! behind every parallel path in the crate.
//!
//! [`PersistentPool`] generalizes the serving path's pinned-worker design
//! (PR 3) into a reusable primitive: **long-lived** named threads, each
//! owning private per-worker state for its whole lifetime, fed from a
//! bounded shared job queue with a drain-on-close shutdown protocol and a
//! panic-safe join. On top of the raw [`PersistentPool::submit`] interface
//! (used by `anode::serve`), [`PersistentPool::map_with`] provides the
//! ordered scatter-gather the session paths need: work splits into
//! **contiguous chunks**, one per worker, and results come back in input
//! order — so reductions over the output see exactly the serial ordering
//! and parallel runs stay bit-identical to `workers = 1` for every worker
//! count.
//!
//! The free functions [`parallel_map`]/[`parallel_map_with`] keep the
//! original per-call API: they run inline for `workers <= 1` and otherwise
//! stand up a transient pool for the duration of the call (paying the
//! spawn tax the cached pools on `Session`/`ServeHandle` avoid — the
//! `train_throughput` bench measures the difference).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of work executed on a pool worker against its per-worker state.
pub type Job<S> = Box<dyn FnOnce(&mut S) + Send + 'static>;

/// First panic payload observed by any worker (re-raised at join).
type PanicPayload = Box<dyn std::any::Any + Send>;

struct JobQueue<S> {
    queue: VecDeque<Job<S>>,
    closed: bool,
    /// Workers still running. When the last one leaves (e.g. every init
    /// panicked), anything still queued is dropped so waiting mappers see
    /// their channels disconnect instead of hanging on a queue nothing
    /// will ever drain.
    live_workers: usize,
}

struct PoolShared<S> {
    jobs: Mutex<JobQueue<S>>,
    job_ready: Condvar,
    job_space: Condvar,
    /// Bound on *waiting* jobs (executing jobs are not counted): one spare
    /// job per worker keeps workers fed without unbounded buffering.
    cap: usize,
    /// First payload from a job that panicked on a worker thread. Workers
    /// contain the unwind and keep serving (a dead worker with queued jobs
    /// would stall every path sharing the pool); the payload is re-raised
    /// by [`PersistentPool::join`] after all workers have been joined.
    panic: Mutex<Option<PanicPayload>>,
}

/// Long-lived worker threads with per-worker state `S`, a bounded shared
/// job queue, ordered contiguous-chunk scatter-gather ([`Self::map_with`])
/// and a drain-on-close, panic-safe shutdown protocol.
///
/// One pool instance is one execution domain: `anode::serve` runs its
/// batches on a pool of ledger-carrying workers, a `Session` caches a pool
/// for its `evaluate`/`predict_batches`/`step_accumulate` fan-outs, and a
/// future pool-per-device instantiation is the multi-device sharding seam
/// (see rust/DESIGN.md §6c).
pub struct PersistentPool<S = ()> {
    shared: Arc<PoolShared<S>>,
    handles: Mutex<Vec<JoinHandle<S>>>,
    workers: usize,
}

impl<S: Send + 'static> PersistentPool<S> {
    /// Spawn `workers` (min 1) persistent threads named `{name}-{i}`, each
    /// owning a private state built by `init` on the worker's own thread.
    pub fn new<F>(workers: usize, name: &str, init: F) -> std::io::Result<Self>
    where
        F: Fn() -> S + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            jobs: Mutex::new(JobQueue {
                queue: VecDeque::new(),
                closed: false,
                live_workers: workers,
            }),
            job_ready: Condvar::new(),
            job_space: Condvar::new(),
            cap: workers,
            panic: Mutex::new(None),
        });
        let init = Arc::new(init);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_shared = shared.clone();
            let worker_init = init.clone();
            let builder = std::thread::Builder::new().name(format!("{name}-{i}"));
            let spawned = builder.spawn(move || {
                // A panicking `init` must not leave an open queue nothing
                // drains (a later map would hang): close the pool so
                // submits fail loudly, then die with the original panic so
                // join() re-raises it.
                let mut state = match catch_unwind(AssertUnwindSafe(worker_init.as_ref())) {
                    Ok(state) => state,
                    Err(payload) => {
                        close_shared(&worker_shared);
                        worker_exit(&worker_shared);
                        resume_unwind(payload);
                    }
                };
                worker_loop(&worker_shared, &mut state);
                worker_exit(&worker_shared);
                state
            });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partially spawned pool before propagating:
                    // without a close, the earlier workers would block on
                    // job_ready forever — a thread leak per failed spawn.
                    close_shared(&shared);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Self { shared, handles: Mutex::new(handles), workers })
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Hand a job to the pool, blocking while `workers` jobs already wait
    /// (backpressure toward the submitter). Once the pool is closed the
    /// job is handed back — dropping it releases whatever it captured
    /// (e.g. reply channels), which is the clean-failure path.
    pub fn submit(&self, job: Job<S>) -> Result<(), Job<S>> {
        let mut st = self.shared.jobs.lock().unwrap();
        loop {
            if st.closed {
                return Err(job);
            }
            if st.queue.len() < self.shared.cap {
                st.queue.push_back(job);
                self.shared.job_ready.notify_one();
                return Ok(());
            }
            st = self.shared.job_space.wait(st).unwrap();
        }
    }

    /// Map `f(chunk_state, index, item)` over `items` on up to `limit` of
    /// this pool's workers, preserving input order in the output.
    ///
    /// See [`Self::map_with`]; this is the stateless-chunk variant.
    pub fn map<T, R, F>(&self, limit: usize, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let (results, _) = self.map_with(limit, items, || (), move |_cs, i, t| f(i, t));
        results
    }

    /// Ordered scatter-gather: split `items` into **contiguous chunks**,
    /// one per used worker (at most `limit`), run each chunk as one pool
    /// job with a fresh chunk state from `init`, and return the in-order
    /// results plus the per-chunk states (e.g. worker memory ledgers) for
    /// the caller to aggregate.
    ///
    /// `limit <= 1` (or a single item) runs inline on the caller's thread
    /// — the serial path is the parallel path with the pool turned off,
    /// not a separate code path. Chunking and reassembly are identical to
    /// the scoped [`parallel_map_with`], so results are bit-identical for
    /// every worker count.
    ///
    /// A panic raised by `f` is contained on the worker (the pool stays
    /// usable) and re-raised here with its original payload once every
    /// chunk has settled.
    pub fn map_with<T, R, CS, FI, F>(
        &self,
        limit: usize,
        items: &[T],
        init: FI,
        f: F,
    ) -> (Vec<R>, Vec<CS>)
    where
        T: Sync,
        R: Send,
        CS: Send,
        FI: Fn() -> CS + Sync,
        F: Fn(&mut CS, usize, &T) -> R + Sync,
    {
        let n = items.len();
        let w = limit.max(1).min(self.workers).min(n.max(1));
        if w <= 1 {
            return run_inline(items, &init, &f);
        }

        let chunk = n.div_ceil(w);
        let chunks = n.div_ceil(chunk);
        let latch = Arc::new(Latch::default());
        // Declared before any job exists so it drops — and therefore waits
        // for every outstanding job closure to be gone — *last*, on both
        // the return and the unwind path out of this frame.
        let guard = CompletionGuard(latch.clone());
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<(Vec<R>, CS)>)>();

        let init = &init;
        let f = &f;
        for (ci, chunk_items) in items.chunks(chunk).enumerate() {
            let base = ci * chunk;
            let tx = tx.clone();
            // The borrowing closure: run the chunk against a fresh chunk
            // state, catching panics so a worker thread never dies on user
            // code (the payload is re-raised on the caller below).
            let work: Box<dyn FnOnce(&mut S) + Send + '_> = Box::new(move |_worker| {
                let out = catch_unwind(AssertUnwindSafe(|| {
                    let mut cs = init();
                    let rs: Vec<R> = chunk_items
                        .iter()
                        .enumerate()
                        .map(|(j, t)| f(&mut cs, base + j, t))
                        .collect();
                    (rs, cs)
                }));
                let _ = tx.send((ci, out));
            });
            // SAFETY: `guard` blocks this frame (return *or* unwind) until
            // the ticket paired with this job is dropped, and the ticket is
            // dropped only after `work` has been consumed (run to
            // completion) or dropped unrun — either way the erased borrows
            // of `items`/`init`/`f` are dead before the frame can exit.
            let work: Job<S> = unsafe { erase_job_lifetime(work) };
            latch.add();
            let ticket = Ticket(latch.clone());
            let job: Job<S> = Box::new(move |worker| {
                work(worker);
                drop(ticket);
            });
            // A closed pool hands the job back; dropping it releases its
            // ticket + sender, and the missing chunk is detected below.
            let _ = self.submit(job);
        }
        drop(tx);

        let mut slots: Vec<Option<(Vec<R>, CS)>> = (0..chunks).map(|_| None).collect();
        let mut panic: Option<PanicPayload> = None;
        while let Ok((ci, outcome)) = rx.recv() {
            match outcome {
                Ok(pair) => slots[ci] = Some(pair),
                Err(payload) => {
                    if panic.is_none() {
                        panic = Some(payload);
                    }
                }
            }
        }
        // Every sender is gone; wait for the job closures themselves to be
        // dropped before touching the borrows again.
        drop(guard);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }

        let mut results = Vec::with_capacity(n);
        let mut states = Vec::with_capacity(chunks);
        for slot in slots {
            match slot {
                Some((rs, cs)) => {
                    results.extend(rs);
                    states.push(cs);
                }
                None => panic!("PersistentPool::map_with: pool closed before every chunk ran"),
            }
        }
        (results, states)
    }
}

// Shutdown/teardown needs no bounds on `S`: these methods only flip the
// queue flag and join handles, so `Drop` can share the one protocol.
impl<S> PersistentPool<S> {
    /// Close the job queue: workers finish what is queued (drain, never
    /// drop), then exit. Idempotent and poison-tolerant (teardown paths
    /// must never panic on a poisoned lock).
    pub fn close(&self) {
        close_shared(&self.shared);
    }

    /// Close, join every worker and return their states in worker-index
    /// order. The first panic payload captured from any job is re-raised
    /// *after* all workers have been joined, so a panicking job cannot
    /// leak threads.
    pub fn join(&self) -> Vec<S> {
        let (states, panic) = self.join_collect();
        if let Some(p) = panic {
            resume_unwind(p);
        }
        states
    }

    /// Non-propagating join for teardown paths that must not panic (Drop):
    /// returns the worker states plus the first panic payload, if any.
    pub fn join_collect(&self) -> (Vec<S>, Option<PanicPayload>) {
        self.close();
        let handles: Vec<JoinHandle<S>> = {
            let mut guard = match self.handles.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.drain(..).collect()
        };
        let mut states = Vec::with_capacity(handles.len());
        let mut panic: Option<PanicPayload> = None;
        for h in handles {
            match h.join() {
                Ok(state) => states.push(state),
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        if panic.is_none() {
            panic = match self.shared.panic.lock() {
                Ok(mut slot) => slot.take(),
                Err(poisoned) => poisoned.into_inner().take(),
            };
        }
        (states, panic)
    }
}

impl<S> Drop for PersistentPool<S> {
    fn drop(&mut self) {
        // Quiet teardown through the one shutdown protocol: close, drain,
        // join. A pending panic payload was either already re-raised by a
        // map call or is dropped here (Drop must not unwind).
        let _ = self.join_collect();
    }
}

/// The one close implementation (pool `close`, worker init-panic path,
/// partial-spawn cleanup): poison-tolerant, wakes every waiter.
fn close_shared<S>(shared: &PoolShared<S>) {
    {
        let mut st = match shared.jobs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.closed = true;
    }
    shared.job_ready.notify_all();
    shared.job_space.notify_all();
}

/// Mark one worker gone. When the last worker leaves, whatever is still
/// queued is dropped (outside the lock) — dropping a job disconnects its
/// reply channels and releases its map ticket, so callers fail loudly
/// instead of waiting forever. On the healthy path the queue is already
/// empty here: a worker only exits once the pool is closed and drained.
fn worker_exit<S>(shared: &PoolShared<S>) {
    let leftovers: Vec<Job<S>> = {
        let mut st = match shared.jobs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.live_workers = st.live_workers.saturating_sub(1);
        if st.live_workers == 0 {
            st.queue.drain(..).collect()
        } else {
            Vec::new()
        }
    };
    drop(leftovers);
}

fn worker_loop<S>(shared: &PoolShared<S>, state: &mut S) {
    loop {
        let job = {
            let mut st = shared.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    shared.job_space.notify_one();
                    break job;
                }
                if st.closed {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        // Contain job panics: the worker (and its state) stays alive for
        // later jobs — a dead worker would stall whoever shares the queue.
        // The job may have left `state` logically torn; stateful callers
        // (e.g. the serve runner's ledger) repair it in their own catch.
        let outcome = catch_unwind(AssertUnwindSafe(|| job(&mut *state)));
        if let Err(payload) = outcome {
            let mut slot = match shared.panic.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Erase the borrow lifetime of a pool job.
///
/// # Safety
/// The caller must guarantee the job is consumed or dropped before `'a`
/// ends. [`PersistentPool::map_with`] enforces this with a completion
/// latch whose guard blocks the borrowing frame until every job is gone.
unsafe fn erase_job_lifetime<'a, S>(
    job: Box<dyn FnOnce(&mut S) + Send + 'a>,
) -> Box<dyn FnOnce(&mut S) + Send + 'static> {
    std::mem::transmute(job)
}

/// The shared serial path: one state, items in order on the caller's
/// thread — what every parallel entry point degrades to for `workers <= 1`
/// (or when thread spawn fails), keeping serial-vs-parallel bit-identity
/// structural.
pub(crate) fn run_inline<S, T, R>(
    items: &[T],
    init: impl Fn() -> S,
    f: impl Fn(&mut S, usize, &T) -> R,
) -> (Vec<R>, Vec<S>) {
    let mut state = init();
    let results = items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
    (results, vec![state])
}

/// Counts outstanding map jobs; zero means every job closure is dropped.
#[derive(Default)]
struct Latch {
    outstanding: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn add(&self) {
        *self.outstanding.lock().unwrap() += 1;
    }

    fn done_one(&self) {
        let mut n = self.outstanding.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut n = self.outstanding.lock().unwrap();
        while *n > 0 {
            n = self.done.wait(n).unwrap();
        }
    }
}

/// Dropped when a map job's closure (run or unrun) is destroyed.
struct Ticket(Arc<Latch>);

impl Drop for Ticket {
    fn drop(&mut self) {
        self.0.done_one();
    }
}

/// Blocks in Drop until every ticket issued from the latch is gone — the
/// frame that erased job lifetimes cannot exit (return or unwind) while a
/// job still borrows its arguments.
struct CompletionGuard(Arc<Latch>);

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Map `f(index, item)` over `items` on up to `workers` threads,
/// preserving input order in the output.
///
/// `workers <= 1` (or a single item) runs inline on the caller's thread;
/// otherwise a **transient** [`PersistentPool`] lives for the duration of
/// the call. Long-lived callers (`Session`, `ServeHandle`) cache a pool
/// instead and skip the per-call spawn tax.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let (results, _) = parallel_map_with(items, workers, || (), move |_state, i, t| f(i, t));
    results
}

/// Like [`parallel_map`], but each chunk carries private mutable state
/// created by `init` (one per chunk, on the executing worker's thread).
/// Returns the in-order results plus the per-chunk states for the caller
/// to aggregate (e.g. merging worker memory ledgers).
pub fn parallel_map_with<S, T, R, FI, F>(
    items: &[T],
    workers: usize,
    init: FI,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    S: Send,
    T: Sync,
    R: Send,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let w = workers.max(1).min(n.max(1));
    if w <= 1 {
        return run_inline(items, &init, &f);
    }
    match PersistentPool::new(w, "anode-map", || ()) {
        Ok(pool) => pool.map_with(w, items, init, f),
        // Could not spawn (thread exhaustion): degrade to the serial path
        // rather than fail — the result is bit-identical by construction.
        Err(_) => run_inline(items, &init, &f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order_for_any_worker_count() {
        let items: Vec<usize> = (0..97).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * 3).collect();
        for workers in [1, 2, 3, 4, 8, 97, 200] {
            let par = parallel_map(&items, workers, |i, &x| {
                assert_eq!(i, x, "index must match the item's input position");
                x * 3
            });
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn per_worker_state_counts_partition_the_items() {
        let items: Vec<u32> = (0..40).collect();
        let count_and_copy = |count: &mut usize, _i: usize, x: &u32| {
            *count += 1;
            *x
        };
        for workers in [1, 3, 4, 7] {
            let (results, states) = parallel_map_with(&items, workers, || 0usize, count_and_copy);
            assert_eq!(results, items, "workers={workers}");
            assert!(states.len() <= workers.max(1));
            assert_eq!(states.iter().sum::<usize>(), items.len(), "workers={workers}");
        }
    }

    #[test]
    fn worker_panic_propagates_without_deadlock_or_abort() {
        let items: Vec<usize> = (0..32).collect();
        // catch_unwind (not #[should_panic]): proves the panic surfaces as
        // an ordinary unwind on the caller's thread — a worker panic that
        // aborted the process or deadlocked the join loop would fail here.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(&items, 4, |_, &x| {
                if x == 13 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = outcome.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at 13"), "original payload lost: {msg:?}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u8> = Vec::new();
        let (results, states) = parallel_map_with(&empty, 4, || 0u8, |_, _, &x| x);
        assert!(results.is_empty());
        assert_eq!(states.len(), 1);
        assert_eq!(parallel_map(&[5u8], 4, |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn persistent_pool_reuse_preserves_order_across_calls() {
        let pool: PersistentPool = PersistentPool::new(4, "t-reuse", || ()).unwrap();
        let items: Vec<usize> = (0..50).collect();
        for round in 1..=3 {
            let out = pool.map(4, &items, |i, &x| {
                assert_eq!(i, x);
                x * round
            });
            let want: Vec<usize> = items.iter().map(|&x| x * round).collect();
            assert_eq!(out, want, "round={round}");
        }
    }

    #[test]
    fn persistent_pool_limit_bounds_chunk_count() {
        let pool: PersistentPool = PersistentPool::new(8, "t-limit", || ()).unwrap();
        let items: Vec<u32> = (0..24).collect();
        let count_and_copy = |c: &mut usize, _i: usize, x: &u32| {
            *c += 1;
            *x
        };
        let (results, states) = pool.map_with(2, &items, || 0usize, count_and_copy);
        assert_eq!(results, items);
        assert_eq!(states.len(), 2, "limit must bound the chunk fan-out");
        assert_eq!(states.iter().sum::<usize>(), items.len());
    }

    #[test]
    fn persistent_pool_survives_map_panic_and_stays_usable() {
        let pool: PersistentPool = PersistentPool::new(4, "t-panic", || ()).unwrap();
        let items: Vec<usize> = (0..32).collect();
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(4, &items, |_, &x| {
                if x == 7 {
                    panic!("kapow {x}");
                }
                x
            })
        }));
        assert!(outcome.is_err(), "map panic must propagate to the caller");
        // The panic was contained on the worker: the pool keeps serving.
        let out = pool.map(4, &items, |_, &x| x + 1);
        assert_eq!(out[31], 32);
        // No worker died, and no payload is pending at join.
        let states = pool.join();
        assert_eq!(states.len(), 4);
    }

    #[test]
    fn persistent_pool_submit_jobs_mutate_worker_state_and_join_returns_it() {
        let pool: PersistentPool<usize> = PersistentPool::new(3, "t-state", || 0usize).unwrap();
        for _ in 0..30 {
            assert!(pool.submit(Box::new(|n| *n += 1)).is_ok());
        }
        let states = pool.join();
        assert_eq!(states.len(), 3);
        assert_eq!(states.iter().sum::<usize>(), 30, "drain-on-close must run every queued job");
        // Submit after close hands the job back instead of dropping it.
        assert!(pool.submit(Box::new(|_| {})).is_err());
    }

    #[test]
    fn panicking_worker_init_fails_maps_loudly_instead_of_hanging() {
        let pool: PersistentPool<usize> =
            PersistentPool::new(2, "t-init-panic", || panic!("init boom")).unwrap();
        let items: Vec<usize> = (0..8).collect();
        // Whether the dead workers closed the pool before or after these
        // jobs were submitted, the map must surface a panic — never park
        // forever on a queue nothing drains.
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| pool.map(2, &items, |_, &x| x)));
        assert!(outcome.is_err(), "map on a dead pool must fail, not hang");
        // The init payload itself surfaces at join.
        let (states, panic) = pool.join_collect();
        assert!(states.is_empty(), "no worker survived init");
        let msg = panic
            .as_ref()
            .and_then(|p| p.downcast_ref::<&str>())
            .copied()
            .unwrap_or_default();
        assert!(msg.contains("init boom"), "init payload lost: {msg:?}");
    }

    #[test]
    fn submitted_job_panic_is_reraised_at_join_after_all_workers_joined() {
        let pool: PersistentPool<usize> = PersistentPool::new(2, "t-joinpanic", || 0usize).unwrap();
        assert!(pool.submit(Box::new(|_| panic!("late boom"))).is_ok());
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| pool.join()));
        let payload = outcome.expect_err("job panic must re-raise at join");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("late boom"), "original payload lost: {msg:?}");
        // The payload was consumed; a second join is clean and empty.
        let (states, panic) = pool.join_collect();
        assert!(states.is_empty() && panic.is_none());
    }
}
