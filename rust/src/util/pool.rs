//! Worker pools for data-parallel fan-out — the single execution substrate
//! behind every parallel path in the crate.
//!
//! [`PersistentPool`] generalizes the serving path's pinned-worker design
//! (PR 3) into a reusable primitive: **long-lived** named threads, each
//! owning private per-worker state for its whole lifetime, fed from a
//! bounded shared job queue with a drain-on-close shutdown protocol and a
//! panic-safe join. On top of the raw [`PersistentPool::submit`] interface
//! (used by `anode::serve`), [`PersistentPool::map_with`] provides the
//! ordered scatter-gather the session paths need: work splits into
//! **contiguous chunks**, one per worker, and results come back in input
//! order — so reductions over the output see exactly the serial ordering
//! and parallel runs stay bit-identical to `workers = 1` for every worker
//! count.
//!
//! The free functions [`parallel_map`]/[`parallel_map_with`] keep the
//! original per-call API: they run inline for `workers <= 1` and otherwise
//! stand up a transient pool for the duration of the call (paying the
//! spawn tax the cached pools on `Session`/`ServeHandle` avoid — the
//! `train_throughput` bench measures the difference).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of work executed on a pool worker against its per-worker state.
pub type Job<S> = Box<dyn FnOnce(&mut S) + Send + 'static>;

/// First panic payload observed by any worker (re-raised at join).
type PanicPayload = Box<dyn std::any::Any + Send>;

struct JobQueue<S> {
    queue: VecDeque<Job<S>>,
    closed: bool,
    /// Workers still running. When the last one leaves (e.g. every init
    /// panicked), anything still queued is dropped so waiting mappers see
    /// their channels disconnect instead of hanging on a queue nothing
    /// will ever drain.
    live_workers: usize,
}

struct PoolShared<S> {
    jobs: Mutex<JobQueue<S>>,
    job_ready: Condvar,
    job_space: Condvar,
    /// Bound on *waiting* jobs (executing jobs are not counted): one spare
    /// job per worker keeps workers fed without unbounded buffering.
    cap: usize,
    /// First payload from a job that panicked on a worker thread. Workers
    /// contain the unwind and keep serving (a dead worker with queued jobs
    /// would stall every path sharing the pool); the payload is re-raised
    /// by [`PersistentPool::join`] after all workers have been joined.
    panic: Mutex<Option<PanicPayload>>,
}

/// Long-lived worker threads with per-worker state `S`, a bounded shared
/// job queue, ordered contiguous-chunk scatter-gather ([`Self::map_with`])
/// and a drain-on-close, panic-safe shutdown protocol.
///
/// One pool instance is one execution domain: `anode::serve` runs its
/// batches on a pool of ledger-carrying workers, a `Session` caches a pool
/// for its `evaluate`/`predict_batches`/`step_accumulate` fan-outs, and a
/// future pool-per-device instantiation is the multi-device sharding seam
/// (see rust/DESIGN.md §6c).
pub struct PersistentPool<S = ()> {
    shared: Arc<PoolShared<S>>,
    handles: Mutex<Vec<JoinHandle<S>>>,
    workers: usize,
}

impl<S: Send + 'static> PersistentPool<S> {
    /// Spawn `workers` (min 1) persistent threads named `{name}-{i}`, each
    /// owning a private state built by `init` on the worker's own thread.
    pub fn new<F>(workers: usize, name: &str, init: F) -> std::io::Result<Self>
    where
        F: Fn() -> S + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            jobs: Mutex::new(JobQueue {
                queue: VecDeque::new(),
                closed: false,
                live_workers: workers,
            }),
            job_ready: Condvar::new(),
            job_space: Condvar::new(),
            cap: workers,
            panic: Mutex::new(None),
        });
        let init = Arc::new(init);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_shared = shared.clone();
            let worker_init = init.clone();
            let builder = std::thread::Builder::new().name(format!("{name}-{i}"));
            let spawned = builder.spawn(move || {
                // A panicking `init` must not leave an open queue nothing
                // drains (a later map would hang): close the pool so
                // submits fail loudly, then die with the original panic so
                // join() re-raises it.
                let mut state = match catch_unwind(AssertUnwindSafe(worker_init.as_ref())) {
                    Ok(state) => state,
                    Err(payload) => {
                        close_shared(&worker_shared);
                        worker_exit(&worker_shared);
                        resume_unwind(payload);
                    }
                };
                worker_loop(&worker_shared, &mut state);
                worker_exit(&worker_shared);
                state
            });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partially spawned pool before propagating:
                    // without a close, the earlier workers would block on
                    // job_ready forever — a thread leak per failed spawn.
                    close_shared(&shared);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Self { shared, handles: Mutex::new(handles), workers })
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Hand a job to the pool, blocking while `workers` jobs already wait
    /// (backpressure toward the submitter). Once the pool is closed the
    /// job is handed back — dropping it releases whatever it captured
    /// (e.g. reply channels), which is the clean-failure path.
    pub fn submit(&self, job: Job<S>) -> Result<(), Job<S>> {
        let mut st = self.shared.jobs.lock().unwrap();
        loop {
            if st.closed {
                return Err(job);
            }
            if st.queue.len() < self.shared.cap {
                st.queue.push_back(job);
                self.shared.job_ready.notify_one();
                return Ok(());
            }
            st = self.shared.job_space.wait(st).unwrap();
        }
    }

    /// Map `f(chunk_state, index, item)` over `items` on up to `limit` of
    /// this pool's workers, preserving input order in the output.
    ///
    /// See [`Self::map_with`]; this is the stateless-chunk variant.
    pub fn map<T, R, F>(&self, limit: usize, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let (results, _) = self.map_with(limit, items, || (), move |_cs, i, t| f(i, t));
        results
    }

    /// Ordered scatter-gather: split `items` into **contiguous chunks**,
    /// one per used worker (at most `limit`), run each chunk as one pool
    /// job with a fresh chunk state from `init`, and return the in-order
    /// results plus the per-chunk states (e.g. worker memory ledgers) for
    /// the caller to aggregate.
    ///
    /// `limit <= 1` (or a single item) runs inline on the caller's thread
    /// — the serial path is the parallel path with the pool turned off,
    /// not a separate code path. Chunking and reassembly are identical to
    /// the scoped [`parallel_map_with`], so results are bit-identical for
    /// every worker count.
    ///
    /// A panic raised by `f` is contained on the worker (the pool stays
    /// usable) and re-raised here with its original payload once every
    /// chunk has settled.
    pub fn map_with<T, R, CS, FI, F>(
        &self,
        limit: usize,
        items: &[T],
        init: FI,
        f: F,
    ) -> (Vec<R>, Vec<CS>)
    where
        T: Sync,
        R: Send,
        CS: Send,
        FI: Fn() -> CS + Sync,
        F: Fn(&mut CS, usize, &T) -> R + Sync,
    {
        let n = items.len();
        let w = limit.max(1).min(self.workers).min(n.max(1));
        if w <= 1 {
            return run_inline(items, &init, &f);
        }
        let chunk = n.div_ceil(w);
        let assignments: Vec<ChunkAssignment> = (0..n)
            .step_by(chunk)
            .map(|start| ChunkAssignment { device: 0, start, len: chunk.min(n - start) })
            .collect();
        // Single-pool map ignores the worker's pinned state; the sharded
        // entry point `sharded_map_with` exposes it (the device pin).
        let wrapped = |_worker: &mut S, cs: &mut CS, i: usize, t: &T| f(cs, i, t);
        let (results, states) =
            scatter_gather(&[self], &assignments, None, items, &init, &wrapped);
        (results, states.into_iter().map(|(_, cs)| cs).collect())
    }
}

/// A contiguous chunk of a sharded map's input, assigned to one device
/// pool by the [`ShardRouter`]. Assignments are produced (and results
/// reassembled) in `start` order, so the output order never depends on the
/// routing decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    /// Index of the device pool this chunk executes on.
    pub device: usize,
    /// First item index of the chunk.
    pub start: usize,
    /// Items in the chunk (>= 1).
    pub len: usize,
}

/// Load-aware chunk router for pool-per-device sharding.
///
/// Tracks outstanding work items per device and always routes the next
/// chunk to the **least-loaded** device, where load is normalized by the
/// device's capacity (its worker count): device `d` wins when
/// `load[d] / cap[d]` is strictly smallest, ties going to the lowest
/// device id. With equal capacities and an idle start this degenerates to
/// capacity-proportional round-robin; under imbalance (one device busy
/// with serve traffic, or slow) new chunks drain to the others.
///
/// Routing never affects *what* is computed — chunks are contiguous and
/// results reassemble in input order — so any routing decision yields
/// bit-identical output (asserted under forced worst-case imbalance in
/// rust/tests/sharding.rs, and property-tested in rust/tests/proptests.rs).
pub struct ShardRouter {
    caps: Vec<usize>,
    /// Outstanding items per device, shared with release-only
    /// [`LoadTicket`]s (an `Arc` so tickets are `'static` and can ride
    /// inside pool jobs).
    loads: Arc<Mutex<Vec<u64>>>,
}

impl ShardRouter {
    /// Router over devices with the given capacities (worker counts).
    /// Zero capacities are clamped to 1; an empty slice means one device.
    pub fn new(capacities: &[usize]) -> Self {
        let caps: Vec<usize> = if capacities.is_empty() {
            vec![1]
        } else {
            capacities.iter().map(|&c| c.max(1)).collect()
        };
        let n = caps.len();
        Self { caps, loads: Arc::new(Mutex::new(vec![0; n])) }
    }

    /// Devices the router routes over.
    pub fn devices(&self) -> usize {
        self.caps.len()
    }

    /// Capacity (worker count) of device `d`.
    pub fn capacity(&self, d: usize) -> usize {
        self.caps[d]
    }

    /// Snapshot of the outstanding load per device.
    pub fn loads(&self) -> Vec<u64> {
        match self.loads.lock() {
            Ok(guard) => guard.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }

    /// Least-normalized-load pick under the lock (ties → lowest id).
    fn pick_locked(&self, loads: &[u64]) -> usize {
        let mut best = 0usize;
        for d in 1..loads.len() {
            if loads[d] * self.caps[best] as u64 < loads[best] * self.caps[d] as u64 {
                best = d;
            }
        }
        best
    }

    /// Route one unit of `cost` items to the least-loaded device and add
    /// it to that device's load. Pair with [`ShardRouter::complete`] or a
    /// [`ShardRouter::ticket`] so the load drains when the work finishes.
    pub fn acquire(&self, cost: u64) -> usize {
        let mut loads = self.loads.lock().unwrap();
        let d = self.pick_locked(&loads);
        loads[d] += cost;
        d
    }

    /// Mark `cost` items complete on device `d` (the manual counterpart of
    /// a dropped [`LoadTicket`]). Saturating and poison-tolerant: load
    /// release runs on teardown paths that must not panic.
    pub fn complete(&self, device: usize, cost: u64) {
        let mut loads = match self.loads.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        loads[device] = loads[device].saturating_sub(cost);
    }

    /// Release-only guard for a load already added by
    /// [`ShardRouter::acquire`] / [`ShardRouter::assign_chunks`]: dropping
    /// it completes `cost` items on `device`. Owns an `Arc` of the load
    /// table, so it can ride inside a `'static` pool job and still release
    /// when the job is dropped unrun (a closed pool).
    pub fn ticket(&self, device: usize, cost: u64) -> LoadTicket {
        LoadTicket { loads: self.loads.clone(), device, cost }
    }

    /// Split `[0, n)` into contiguous chunks of `chunk_len` (the last one
    /// short) and route each, in order, to the least-loaded device at that
    /// point, adding each chunk's length to its device's load. The caller
    /// releases each chunk via [`ShardRouter::ticket`] /
    /// [`ShardRouter::complete`] as it finishes.
    pub fn assign_chunks(&self, n: usize, chunk_len: usize) -> Vec<ChunkAssignment> {
        let chunk_len = chunk_len.max(1);
        let mut out = Vec::with_capacity(n.div_ceil(chunk_len));
        let mut loads = self.loads.lock().unwrap();
        let mut start = 0usize;
        while start < n {
            let len = chunk_len.min(n - start);
            let d = self.pick_locked(&loads);
            loads[d] += len as u64;
            out.push(ChunkAssignment { device: d, start, len });
            start += len;
        }
        out
    }
}

/// Release-only load guard — see [`ShardRouter::ticket`].
pub struct LoadTicket {
    loads: Arc<Mutex<Vec<u64>>>,
    device: usize,
    cost: u64,
}

impl Drop for LoadTicket {
    fn drop(&mut self) {
        let mut loads = match self.loads.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        loads[self.device] = loads[self.device].saturating_sub(self.cost);
    }
}

/// Ordered scatter-gather across **several** pools (one per device): run
/// `items` as contiguous chunks on the pools named by `router`'s
/// assignment, handing each chunk's closure the executing worker's pinned
/// per-worker state (the device pin) plus a fresh chunk state from `init`,
/// and reassemble results in input order.
///
/// Returns the in-order results plus each chunk's state tagged with the
/// device that ran it (so per-device ledger folds stay possible). Chunk
/// granularity is `ceil(n / total used workers)`, where each device
/// contributes at most `limit` workers — so a caller asking for fewer
/// workers than the (never-shrinking) pools hold gets a fan-out bounded
/// by its request, exactly like `map_with`'s `limit`. A panic inside `f`
/// is contained on its worker (all pools stay usable) and re-raised here
/// once every chunk settles; every assigned chunk's load is released on
/// the router whether the chunk ran, panicked, or was dropped by a closed
/// pool.
///
/// The caller owns the serial path: `sharded_map_with` always dispatches
/// through the pools (worker state cannot be synthesized inline), so
/// degenerate cases (`devices == 1 && workers <= 1`) should run
/// `run_inline`-style on the caller's thread instead — which is exactly
/// what `Session` does, keeping serial-vs-parallel bit-identity structural.
pub fn sharded_map_with<S, T, R, CS, FI, F>(
    pools: &[&PersistentPool<S>],
    router: &ShardRouter,
    limit: usize,
    items: &[T],
    init: FI,
    f: F,
) -> (Vec<R>, Vec<(usize, CS)>)
where
    S: Send + 'static,
    T: Sync,
    R: Send,
    CS: Send,
    FI: Fn() -> CS + Sync,
    F: Fn(&mut S, &mut CS, usize, &T) -> R + Sync,
{
    assert!(!pools.is_empty(), "sharded_map_with needs at least one device pool");
    assert_eq!(
        pools.len(),
        router.devices(),
        "router device count must match the pool list"
    );
    let n = items.len();
    if n == 0 {
        return (Vec::new(), Vec::new());
    }
    let limit = limit.max(1);
    let total: usize = pools.iter().map(|p| p.workers().min(limit)).sum();
    let chunk = n.div_ceil(total.max(1));
    let assignments = router.assign_chunks(n, chunk);
    scatter_gather(pools, &assignments, Some(router), items, &init, &f)
}

/// Streaming scatter-gather over `items` split per `router`, delivering
/// each chunk's results to `fold` **on the calling thread, in input
/// order, as chunks complete** — so a reduction over chunk i overlaps
/// with chunk i+1 still executing on the pools (the pipelined
/// reduce/apply behind `Session::step_accumulate`), while the fixed fold
/// order keeps the result bit-identical to the barrier version (and to
/// serial). `fold(base, results)` receives the chunk's first item index
/// and its in-order results; chunks are contiguous and folded in `start`
/// order, so concatenating the `base`s reproduces `0..n`.
///
/// Returns each chunk's state tagged with its device (chunk order).
/// Panic/teardown semantics match [`sharded_map_with`]; a panic may
/// surface after `fold` has already consumed earlier chunks.
pub fn sharded_fold_with<S, T, R, CS, FI, F, K>(
    pools: &[&PersistentPool<S>],
    router: &ShardRouter,
    limit: usize,
    items: &[T],
    init: FI,
    f: F,
    fold: K,
) -> Vec<(usize, CS)>
where
    S: Send + 'static,
    T: Sync,
    R: Send,
    CS: Send,
    FI: Fn() -> CS + Sync,
    F: Fn(&mut S, &mut CS, usize, &T) -> R + Sync,
    K: FnMut(usize, Vec<R>),
{
    assert!(!pools.is_empty(), "sharded_fold_with needs at least one device pool");
    assert_eq!(
        pools.len(),
        router.devices(),
        "router device count must match the pool list"
    );
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let limit = limit.max(1);
    let total: usize = pools.iter().map(|p| p.workers().min(limit)).sum();
    let chunk = n.div_ceil(total.max(1));
    let assignments = router.assign_chunks(n, chunk);
    let mut states = Vec::with_capacity(assignments.len());
    let mut fold = fold;
    scatter_stream(pools, &assignments, Some(router), items, &init, &f, |ci, rs, device, cs| {
        fold(assignments[ci].start, rs);
        states.push((device, cs));
    });
    states
}

/// The shared scatter-gather core behind [`PersistentPool::map_with`]
/// (one pool, worker state ignored) and [`sharded_map_with`] (pool per
/// device, worker state = the device pin): submit one job per assignment
/// to its device's pool, gather `(chunk index, outcome)` over a channel,
/// reassemble in input order. A thin collecting sink over
/// [`scatter_stream`].
fn scatter_gather<S, T, R, CS, FI, F>(
    pools: &[&PersistentPool<S>],
    assignments: &[ChunkAssignment],
    router: Option<&ShardRouter>,
    items: &[T],
    init: &FI,
    f: &F,
) -> (Vec<R>, Vec<(usize, CS)>)
where
    S: Send + 'static,
    T: Sync,
    R: Send,
    CS: Send,
    FI: Fn() -> CS + Sync,
    F: Fn(&mut S, &mut CS, usize, &T) -> R + Sync,
{
    let mut results = Vec::with_capacity(items.len());
    let mut states = Vec::with_capacity(assignments.len());
    scatter_stream(pools, assignments, router, items, init, f, |_ci, rs, device, cs| {
        results.extend(rs);
        states.push((device, cs));
    });
    (results, states)
}

/// The streaming core: submit one job per assignment, then deliver each
/// chunk's `(results, device, state)` to `sink` **in chunk-index order**
/// on the calling thread, buffering out-of-order completions. Because
/// assignments are produced in `start` order, chunk order *is* input
/// order — the invariant every fixed-order reduction above relies on.
/// The first panic from any chunk is re-raised after all chunks settle;
/// a chunk dropped by a closed pool panics with a diagnostic.
#[allow(clippy::too_many_arguments)]
fn scatter_stream<S, T, R, CS, FI, F, K>(
    pools: &[&PersistentPool<S>],
    assignments: &[ChunkAssignment],
    router: Option<&ShardRouter>,
    items: &[T],
    init: &FI,
    f: &F,
    mut sink: K,
) where
    S: Send + 'static,
    T: Sync,
    R: Send,
    CS: Send,
    FI: Fn() -> CS + Sync,
    F: Fn(&mut S, &mut CS, usize, &T) -> R + Sync,
    K: FnMut(usize, Vec<R>, usize, CS),
{
    let chunks = assignments.len();
    let latch = Arc::new(Latch::default());
    // Declared before any job exists so it drops — and therefore waits
    // for every outstanding job closure to be gone — *last*, on both
    // the return and the unwind path out of this frame.
    let guard = CompletionGuard(latch.clone());
    let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<(Vec<R>, usize, CS)>)>();

    for (ci, a) in assignments.iter().enumerate() {
        let chunk_items = &items[a.start..a.start + a.len];
        let base = a.start;
        let device = a.device;
        let tx = tx.clone();
        // The borrowing closure: run the chunk against the worker's pinned
        // state and a fresh chunk state, catching panics so a worker
        // thread never dies on user code (the payload is re-raised on the
        // caller below).
        let work: Box<dyn FnOnce(&mut S) + Send + '_> = Box::new(move |worker| {
            let out = catch_unwind(AssertUnwindSafe(|| {
                let mut cs = init();
                let rs: Vec<R> = chunk_items
                    .iter()
                    .enumerate()
                    .map(|(j, t)| f(worker, &mut cs, base + j, t))
                    .collect();
                (rs, device, cs)
            }));
            let _ = tx.send((ci, out));
        });
        // SAFETY: `guard` blocks this frame (return *or* unwind) until
        // the ticket paired with this job is dropped, and the ticket is
        // dropped only after `work` has been consumed (run to
        // completion) or dropped unrun — either way the erased borrows
        // of `items`/`init`/`f` are dead before the frame can exit.
        let work: Job<S> = unsafe { erase_job_lifetime(work) };
        latch.add();
        let ticket = Ticket(latch.clone());
        // Owned (`Arc`-backed) load guard: the chunk's routed load drains
        // when the job finishes — or when a closed pool drops it unrun.
        let load = router.map(|r| r.ticket(device, a.len as u64));
        let job: Job<S> = Box::new(move |worker| {
            work(worker);
            // Load before latch ticket: once the mapping frame unblocks,
            // every completed chunk's load is already drained.
            drop(load);
            drop(ticket);
        });
        // A closed pool hands the job back; dropping it releases its
        // ticket + sender + load, and the missing chunk is detected below.
        let _ = pools[device].submit(job);
    }
    drop(tx);

    // Deliver chunks to the sink the moment the in-order cursor reaches
    // them: chunk i folds on this thread while chunk i+1 (and beyond) is
    // still executing on the pools. Out-of-order completions park in
    // `slots` until the cursor catches up.
    let mut slots: Vec<Option<(Vec<R>, usize, CS)>> = (0..chunks).map(|_| None).collect();
    let mut cursor = 0usize;
    let mut panic: Option<PanicPayload> = None;
    while let Ok((ci, outcome)) = rx.recv() {
        match outcome {
            Ok(triple) => {
                slots[ci] = Some(triple);
                while cursor < chunks {
                    match slots[cursor].take() {
                        Some((rs, device, cs)) => {
                            sink(cursor, rs, device, cs);
                            cursor += 1;
                        }
                        None => break,
                    }
                }
            }
            Err(payload) => {
                if panic.is_none() {
                    panic = Some(payload);
                }
            }
        }
    }
    // Every sender is gone; wait for the job closures themselves to be
    // dropped before touching the borrows again.
    drop(guard);
    if let Some(payload) = panic {
        resume_unwind(payload);
    }
    if cursor < chunks {
        panic!("sharded map: a device pool closed before every chunk ran");
    }
}

// Shutdown/teardown needs no bounds on `S`: these methods only flip the
// queue flag and join handles, so `Drop` can share the one protocol.
impl<S> PersistentPool<S> {
    /// Close the job queue: workers finish what is queued (drain, never
    /// drop), then exit. Idempotent and poison-tolerant (teardown paths
    /// must never panic on a poisoned lock).
    pub fn close(&self) {
        close_shared(&self.shared);
    }

    /// Close, join every worker and return their states in worker-index
    /// order. The first panic payload captured from any job is re-raised
    /// *after* all workers have been joined, so a panicking job cannot
    /// leak threads.
    pub fn join(&self) -> Vec<S> {
        let (states, panic) = self.join_collect();
        if let Some(p) = panic {
            resume_unwind(p);
        }
        states
    }

    /// Non-propagating join for teardown paths that must not panic (Drop):
    /// returns the worker states plus the first panic payload, if any.
    pub fn join_collect(&self) -> (Vec<S>, Option<PanicPayload>) {
        self.close();
        let handles: Vec<JoinHandle<S>> = {
            let mut guard = match self.handles.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.drain(..).collect()
        };
        let mut states = Vec::with_capacity(handles.len());
        let mut panic: Option<PanicPayload> = None;
        for h in handles {
            match h.join() {
                Ok(state) => states.push(state),
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        if panic.is_none() {
            panic = match self.shared.panic.lock() {
                Ok(mut slot) => slot.take(),
                Err(poisoned) => poisoned.into_inner().take(),
            };
        }
        (states, panic)
    }
}

impl<S> Drop for PersistentPool<S> {
    fn drop(&mut self) {
        // Quiet teardown through the one shutdown protocol: close, drain,
        // join. A pending panic payload was either already re-raised by a
        // map call or is dropped here (Drop must not unwind).
        let _ = self.join_collect();
    }
}

/// The one close implementation (pool `close`, worker init-panic path,
/// partial-spawn cleanup): poison-tolerant, wakes every waiter.
fn close_shared<S>(shared: &PoolShared<S>) {
    {
        let mut st = match shared.jobs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.closed = true;
    }
    shared.job_ready.notify_all();
    shared.job_space.notify_all();
}

/// Mark one worker gone. When the last worker leaves, whatever is still
/// queued is dropped (outside the lock) — dropping a job disconnects its
/// reply channels and releases its map ticket, so callers fail loudly
/// instead of waiting forever. On the healthy path the queue is already
/// empty here: a worker only exits once the pool is closed and drained.
fn worker_exit<S>(shared: &PoolShared<S>) {
    let leftovers: Vec<Job<S>> = {
        let mut st = match shared.jobs.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        st.live_workers = st.live_workers.saturating_sub(1);
        if st.live_workers == 0 {
            st.queue.drain(..).collect()
        } else {
            Vec::new()
        }
    };
    drop(leftovers);
}

fn worker_loop<S>(shared: &PoolShared<S>, state: &mut S) {
    loop {
        let job = {
            let mut st = shared.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    shared.job_space.notify_one();
                    break job;
                }
                if st.closed {
                    return;
                }
                st = shared.job_ready.wait(st).unwrap();
            }
        };
        // Contain job panics: the worker (and its state) stays alive for
        // later jobs — a dead worker would stall whoever shares the queue.
        // The job may have left `state` logically torn; stateful callers
        // (e.g. the serve runner's ledger) repair it in their own catch.
        let outcome = catch_unwind(AssertUnwindSafe(|| job(&mut *state)));
        if let Err(payload) = outcome {
            let mut slot = match shared.panic.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Erase the borrow lifetime of a pool job.
///
/// # Safety
/// The caller must guarantee the job is consumed or dropped before `'a`
/// ends. [`PersistentPool::map_with`] enforces this with a completion
/// latch whose guard blocks the borrowing frame until every job is gone.
unsafe fn erase_job_lifetime<'a, S>(
    job: Box<dyn FnOnce(&mut S) + Send + 'a>,
) -> Box<dyn FnOnce(&mut S) + Send + 'static> {
    std::mem::transmute(job)
}

/// The shared serial path: one state, items in order on the caller's
/// thread — what every parallel entry point degrades to for `workers <= 1`
/// (or when thread spawn fails), keeping serial-vs-parallel bit-identity
/// structural.
pub(crate) fn run_inline<S, T, R>(
    items: &[T],
    init: impl Fn() -> S,
    f: impl Fn(&mut S, usize, &T) -> R,
) -> (Vec<R>, Vec<S>) {
    let mut state = init();
    let results = items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
    (results, vec![state])
}

/// Counts outstanding map jobs; zero means every job closure is dropped.
#[derive(Default)]
struct Latch {
    outstanding: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn add(&self) {
        *self.outstanding.lock().unwrap() += 1;
    }

    fn done_one(&self) {
        let mut n = self.outstanding.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut n = self.outstanding.lock().unwrap();
        while *n > 0 {
            n = self.done.wait(n).unwrap();
        }
    }
}

/// Dropped when a map job's closure (run or unrun) is destroyed.
struct Ticket(Arc<Latch>);

impl Drop for Ticket {
    fn drop(&mut self) {
        self.0.done_one();
    }
}

/// Blocks in Drop until every ticket issued from the latch is gone — the
/// frame that erased job lifetimes cannot exit (return or unwind) while a
/// job still borrows its arguments.
struct CompletionGuard(Arc<Latch>);

impl Drop for CompletionGuard {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// Map `f(index, item)` over `items` on up to `workers` threads,
/// preserving input order in the output.
///
/// `workers <= 1` (or a single item) runs inline on the caller's thread;
/// otherwise a **transient** [`PersistentPool`] lives for the duration of
/// the call. Long-lived callers (`Session`, `ServeHandle`) cache a pool
/// instead and skip the per-call spawn tax.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let (results, _) = parallel_map_with(items, workers, || (), move |_state, i, t| f(i, t));
    results
}

/// Like [`parallel_map`], but each chunk carries private mutable state
/// created by `init` (one per chunk, on the executing worker's thread).
/// Returns the in-order results plus the per-chunk states for the caller
/// to aggregate (e.g. merging worker memory ledgers).
pub fn parallel_map_with<S, T, R, FI, F>(
    items: &[T],
    workers: usize,
    init: FI,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    S: Send,
    T: Sync,
    R: Send,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let w = workers.max(1).min(n.max(1));
    if w <= 1 {
        return run_inline(items, &init, &f);
    }
    match PersistentPool::new(w, "anode-map", || ()) {
        Ok(pool) => pool.map_with(w, items, init, f),
        // Could not spawn (thread exhaustion): degrade to the serial path
        // rather than fail — the result is bit-identical by construction.
        Err(_) => run_inline(items, &init, &f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order_for_any_worker_count() {
        let items: Vec<usize> = (0..97).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * 3).collect();
        for workers in [1, 2, 3, 4, 8, 97, 200] {
            let par = parallel_map(&items, workers, |i, &x| {
                assert_eq!(i, x, "index must match the item's input position");
                x * 3
            });
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn per_worker_state_counts_partition_the_items() {
        let items: Vec<u32> = (0..40).collect();
        let count_and_copy = |count: &mut usize, _i: usize, x: &u32| {
            *count += 1;
            *x
        };
        for workers in [1, 3, 4, 7] {
            let (results, states) = parallel_map_with(&items, workers, || 0usize, count_and_copy);
            assert_eq!(results, items, "workers={workers}");
            assert!(states.len() <= workers.max(1));
            assert_eq!(states.iter().sum::<usize>(), items.len(), "workers={workers}");
        }
    }

    #[test]
    fn worker_panic_propagates_without_deadlock_or_abort() {
        let items: Vec<usize> = (0..32).collect();
        // catch_unwind (not #[should_panic]): proves the panic surfaces as
        // an ordinary unwind on the caller's thread — a worker panic that
        // aborted the process or deadlocked the join loop would fail here.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(&items, 4, |_, &x| {
                if x == 13 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        let payload = outcome.expect_err("worker panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at 13"), "original payload lost: {msg:?}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u8> = Vec::new();
        let (results, states) = parallel_map_with(&empty, 4, || 0u8, |_, _, &x| x);
        assert!(results.is_empty());
        assert_eq!(states.len(), 1);
        assert_eq!(parallel_map(&[5u8], 4, |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn persistent_pool_reuse_preserves_order_across_calls() {
        let pool: PersistentPool = PersistentPool::new(4, "t-reuse", || ()).unwrap();
        let items: Vec<usize> = (0..50).collect();
        for round in 1..=3 {
            let out = pool.map(4, &items, |i, &x| {
                assert_eq!(i, x);
                x * round
            });
            let want: Vec<usize> = items.iter().map(|&x| x * round).collect();
            assert_eq!(out, want, "round={round}");
        }
    }

    #[test]
    fn persistent_pool_limit_bounds_chunk_count() {
        let pool: PersistentPool = PersistentPool::new(8, "t-limit", || ()).unwrap();
        let items: Vec<u32> = (0..24).collect();
        let count_and_copy = |c: &mut usize, _i: usize, x: &u32| {
            *c += 1;
            *x
        };
        let (results, states) = pool.map_with(2, &items, || 0usize, count_and_copy);
        assert_eq!(results, items);
        assert_eq!(states.len(), 2, "limit must bound the chunk fan-out");
        assert_eq!(states.iter().sum::<usize>(), items.len());
    }

    #[test]
    fn persistent_pool_survives_map_panic_and_stays_usable() {
        let pool: PersistentPool = PersistentPool::new(4, "t-panic", || ()).unwrap();
        let items: Vec<usize> = (0..32).collect();
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map(4, &items, |_, &x| {
                if x == 7 {
                    panic!("kapow {x}");
                }
                x
            })
        }));
        assert!(outcome.is_err(), "map panic must propagate to the caller");
        // The panic was contained on the worker: the pool keeps serving.
        let out = pool.map(4, &items, |_, &x| x + 1);
        assert_eq!(out[31], 32);
        // No worker died, and no payload is pending at join.
        let states = pool.join();
        assert_eq!(states.len(), 4);
    }

    #[test]
    fn sharded_fold_streams_chunks_in_input_order() {
        let p0: PersistentPool = PersistentPool::new(2, "t-fold0", || ()).unwrap();
        let p1: PersistentPool = PersistentPool::new(2, "t-fold1", || ()).unwrap();
        let router = ShardRouter::new(&[2, 2]);
        let items: Vec<usize> = (0..37).collect();
        let mut folded: Vec<usize> = Vec::new();
        let states = sharded_fold_with(
            &[&p0, &p1],
            &router,
            2,
            &items,
            || 0usize,
            |_worker, count, _i, &x| {
                *count += 1;
                x * 2
            },
            |base, rs| {
                // The fold must see chunks in input order even though
                // completions race across two pools.
                assert_eq!(folded.len(), base, "chunk arrived out of order");
                folded.extend(rs);
            },
        );
        let want: Vec<usize> = items.iter().map(|&x| x * 2).collect();
        assert_eq!(folded, want);
        assert_eq!(states.iter().map(|(_, c)| *c).sum::<usize>(), items.len());
        assert!(states.iter().all(|(d, _)| *d < 2));
    }

    #[test]
    fn persistent_pool_submit_jobs_mutate_worker_state_and_join_returns_it() {
        let pool: PersistentPool<usize> = PersistentPool::new(3, "t-state", || 0usize).unwrap();
        for _ in 0..30 {
            assert!(pool.submit(Box::new(|n| *n += 1)).is_ok());
        }
        let states = pool.join();
        assert_eq!(states.len(), 3);
        assert_eq!(states.iter().sum::<usize>(), 30, "drain-on-close must run every queued job");
        // Submit after close hands the job back instead of dropping it.
        assert!(pool.submit(Box::new(|_| {})).is_err());
    }

    #[test]
    fn panicking_worker_init_fails_maps_loudly_instead_of_hanging() {
        let pool: PersistentPool<usize> =
            PersistentPool::new(2, "t-init-panic", || panic!("init boom")).unwrap();
        let items: Vec<usize> = (0..8).collect();
        // Whether the dead workers closed the pool before or after these
        // jobs were submitted, the map must surface a panic — never park
        // forever on a queue nothing drains.
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| pool.map(2, &items, |_, &x| x)));
        assert!(outcome.is_err(), "map on a dead pool must fail, not hang");
        // The init payload itself surfaces at join.
        let (states, panic) = pool.join_collect();
        assert!(states.is_empty(), "no worker survived init");
        let msg = panic
            .as_ref()
            .and_then(|p| p.downcast_ref::<&str>())
            .copied()
            .unwrap_or_default();
        assert!(msg.contains("init boom"), "init payload lost: {msg:?}");
    }

    #[test]
    fn submitted_job_panic_is_reraised_at_join_after_all_workers_joined() {
        let pool: PersistentPool<usize> = PersistentPool::new(2, "t-joinpanic", || 0usize).unwrap();
        assert!(pool.submit(Box::new(|_| panic!("late boom"))).is_ok());
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| pool.join()));
        let payload = outcome.expect_err("job panic must re-raise at join");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("late boom"), "original payload lost: {msg:?}");
        // The payload was consumed; a second join is clean and empty.
        let (states, panic) = pool.join_collect();
        assert!(states.is_empty() && panic.is_none());
    }
}
