//! In-tree utilities that replace external crates unavailable in the
//! offline build image: a JSON parser/writer ([`json`]), a tiny CLI argument
//! parser ([`cli`]), and a micro-benchmark timer ([`bench`]).

pub mod bench;
pub mod cli;
pub mod json;
