//! In-tree utilities that replace external crates unavailable in the
//! offline build image: a JSON parser/writer ([`json`]), a tiny CLI argument
//! parser ([`cli`]), a micro-benchmark timer ([`bench`]), and a scoped
//! worker pool for the parallel serving paths ([`pool`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
