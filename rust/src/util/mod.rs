//! In-tree utilities that replace external crates unavailable in the
//! offline build image: a JSON parser/writer ([`json`]), a tiny CLI argument
//! parser ([`cli`]), a micro-benchmark timer ([`bench`]), and the
//! persistent worker pool that is the execution substrate for every
//! parallel path — serving, inference fan-out and data-parallel training
//! ([`pool`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
