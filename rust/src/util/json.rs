//! Minimal JSON parser and writer (RFC 8259 subset sufficient for the
//! artifact manifest and metrics files): objects, arrays, strings with
//! escapes, numbers, booleans, null. No external dependencies.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64 if a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As usize if a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As str if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// As bool if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of usize (e.g. a shape).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape and quote a string per JSON rules.
fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for manifests).
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn shape_helper() {
        let v = Json::parse("[32, 32, 3]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![32, 32, 3]));
        assert_eq!(Json::parse("[1, -2]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"batch":32,"nt":5},"modules":[{"file":"a.hlo.txt","name":"m"}]}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\n".into());
        assert_eq!(v.to_string_compact(), r#""a\"b\n""#);
    }
}
