//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. Everything above it
//! (coordinator, models, examples) works in terms of [`crate::tensor::Tensor`]
//! and module names from the artifact manifest.
//!
//! Multi-device execution is modeled as one [`ArtifactRegistry`] (client +
//! executable cache) per device, collected in a [`DeviceSet`]; the [`sim`]
//! module provides the deterministic offline backend that lets the whole
//! multi-device stack run on the vendored xla stub (rust/DESIGN.md §6d).

mod backend;
mod client;
mod device;
mod registry;
pub mod sim;

pub use backend::{backend_env, Backend};
pub use client::{Executable, Result, RuntimeError, XlaRuntime};
pub use device::{sim_devices_env, DeviceSet};
pub use registry::{ArtifactRegistry, ModuleSpec, ParamSpec, TensorSpec};
