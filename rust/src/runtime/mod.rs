//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. Everything above it
//! (coordinator, models, examples) works in terms of [`crate::tensor::Tensor`]
//! and module names from the artifact manifest.

mod client;
mod registry;

pub use client::{Executable, Result, RuntimeError, XlaRuntime};
pub use registry::{ArtifactRegistry, ModuleSpec, ParamSpec, TensorSpec};
