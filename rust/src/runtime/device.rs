//! Device topology for multi-device sharding: one [`ArtifactRegistry`] —
//! and therefore one PJRT client and one executable cache — **per
//! device**.
//!
//! A [`DeviceSet`] is the engine-level resource behind pool-per-device
//! execution (rust/DESIGN.md §6d): device `d`'s worker pool executes only
//! through `set.registry(d)`, so devices never contend on a shared client
//! or compiled-module cache, and a per-device failure is contained to that
//! device's registry. Offline, [`DeviceSet::open_simulated`] backs every
//! device with the deterministic [`super::sim`] backend (the vendored xla
//! stub simulates `ANODE_SIM_DEVICES` devices), so the whole multi-device
//! stack is exercisable without artifacts or a real PJRT backend.

use std::path::Path;
use std::sync::Arc;

use super::{ArtifactRegistry, Backend, Result};

/// Device count the environment asks to simulate: `ANODE_SIM_DEVICES=N`
/// (N >= 1). This is the same contract the vendored xla stub exposes as
/// `PjRtClient::device_count` — the CI sim job sets it to run the whole
/// suite against a 4-device topology.
pub fn sim_devices_env() -> Option<usize> {
    std::env::var("ANODE_SIM_DEVICES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

/// One registry (client + executable cache) per device, device ids dense
/// from 0. Device 0 is the *primary*: single-device code paths (and
/// back-compat accessors like `Engine::registry`) see exactly the registry
/// they always did.
pub struct DeviceSet {
    devices: Vec<Arc<ArtifactRegistry>>,
}

impl DeviceSet {
    /// Open `count` (min 1) PJRT-backed registries over one artifact dir,
    /// pinned to device ids `0..count`.
    pub fn open(dir: &Path, count: usize) -> Result<Self> {
        Self::build(dir, count, Backend::Xla, None)
    }

    /// Open `count` (min 1) **simulated** registries — the offline
    /// multi-device harness (deterministic execution, no backend).
    pub fn open_simulated(dir: &Path, count: usize) -> Result<Self> {
        Self::build(dir, count, Backend::Sim, None)
    }

    /// Open `count` (min 1) registries all running `backend` — the
    /// general constructor behind the `ANODE_BACKEND` / `--backend`
    /// selection seam.
    pub fn open_with_backend(dir: &Path, count: usize, backend: Backend) -> Result<Self> {
        Self::build(dir, count, backend, None)
    }

    /// A single-device set around an already-open registry (the
    /// `EngineBuilder::registry` sharing path).
    pub fn single(reg: Arc<ArtifactRegistry>) -> Self {
        Self { devices: vec![reg] }
    }

    /// A set whose device 0 is an already-open registry; devices
    /// `1..count` open from the primary's artifact directory with the
    /// primary's execution mode (simulated primaries get simulated
    /// siblings).
    pub fn with_primary(reg: Arc<ArtifactRegistry>, count: usize) -> Result<Self> {
        let backend = reg.backend();
        let dir = reg.dir().to_path_buf();
        Self::build(&dir, count, backend, Some(reg))
    }

    fn build(
        dir: &Path,
        count: usize,
        backend: Backend,
        primary: Option<Arc<ArtifactRegistry>>,
    ) -> Result<Self> {
        let count = count.max(1);
        let mut devices = Vec::with_capacity(count);
        if let Some(reg) = primary {
            devices.push(reg);
        }
        for d in devices.len()..count {
            devices.push(Arc::new(ArtifactRegistry::open_with_backend(dir, d, backend)?));
        }
        Ok(Self { devices })
    }

    /// Devices in the set (>= 1).
    pub fn count(&self) -> usize {
        self.devices.len()
    }

    /// The registry pinned to device `d`.
    pub fn registry(&self, d: usize) -> &Arc<ArtifactRegistry> {
        &self.devices[d]
    }

    /// All per-device registries, device-id order.
    pub fn registries(&self) -> &[Arc<ArtifactRegistry>] {
        &self.devices
    }

    /// The primary (device 0) registry — what single-device accessors see.
    pub fn primary(&self) -> &Arc<ArtifactRegistry> {
        &self.devices[0]
    }
}

#[cfg(test)]
mod tests {
    use super::super::sim::{write_artifacts, SimSpec};
    use super::*;

    #[test]
    fn device_set_opens_one_registry_per_device() {
        let dir = std::env::temp_dir().join(format!("anode_devset_{}", std::process::id()));
        write_artifacts(&dir, &SimSpec::default()).unwrap();
        let set = DeviceSet::open_simulated(&dir, 3).unwrap();
        assert_eq!(set.count(), 3);
        for d in 0..3 {
            assert_eq!(set.registry(d).device_id(), d);
            assert!(set.registry(d).is_simulated());
        }
        // Distinct registries — separate executable caches and clients.
        assert!(!Arc::ptr_eq(set.registry(0), set.registry(1)));
        assert!(!Arc::ptr_eq(set.registry(1), set.registry(2)));
        assert!(Arc::ptr_eq(set.primary(), set.registry(0)));

        // A zero request still yields one device (a platform always has one).
        let one = DeviceSet::open_simulated(&dir, 0).unwrap();
        assert_eq!(one.count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
