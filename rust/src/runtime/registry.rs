//! Manifest-driven artifact registry.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! lowered module (input/output tensor specs) plus the canonical parameter
//! layout matching `artifacts/params.bin`. The registry parses the manifest,
//! compiles modules lazily on first use, and caches executables.
//!
//! The registry is `Send + Sync`: the manifest tables are immutable after
//! `open`, the executable cache sits behind an `RwLock` (reads on the hot
//! path take the shared lock only), and PJRT client creation is a lazy
//! `OnceLock`. One registry can back many engines/sessions across threads,
//! all sharing one compiled-module cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock, RwLock};

use super::backend::Backend;
use super::client::{Executable, Result, RuntimeError, XlaRuntime};
use super::sim::{sim_outputs, SimBackend};
use crate::compile::{CompileStatsSnapshot, CompiledSet};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Shape+dtype+name of one module input or output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered HLO module.
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One named parameter in the canonical layout of `params.bin`.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in f32 elements into params.bin.
    pub offset: usize,
}

fn bad(msg: impl Into<String>) -> RuntimeError {
    RuntimeError::Io(format!("bad manifest.json: {}", msg.into()))
}

fn parse_tensor_spec(v: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: v.get("name").and_then(Json::as_str).ok_or_else(|| bad("spec missing name"))?.to_string(),
        shape: v
            .get("shape")
            .and_then(Json::as_usize_vec)
            .ok_or_else(|| bad("spec missing shape"))?,
        dtype: v.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
    })
}

/// Lazily-compiling registry of AOT artifacts.
///
/// One registry is **one execution domain**: it owns one lazy PJRT client
/// and one executable cache. Multi-device execution opens one registry per
/// device ([`super::DeviceSet`]), each pinned to a `device_id`, so devices
/// never share clients or compiled modules. A registry opened with
/// [`ArtifactRegistry::open_simulated`] executes calls through the
/// deterministic [`super::sim`] backend instead of PJRT — the offline
/// multi-device harness.
pub struct ArtifactRegistry {
    /// Created on first executable compile, so manifest parsing and
    /// validation (the `api::EngineBuilder` path) work without a live
    /// PJRT backend.
    runtime: OnceLock<XlaRuntime>,
    /// Simulated execution: when set, `call` synthesizes outputs from the
    /// manifest specs instead of touching PJRT.
    sim: Option<SimBackend>,
    /// Compiled execution ([`Backend::Compiled`]): every manifest module
    /// lowered to a fused kernel plan at open time; `call` dispatches the
    /// cached plan with no per-call spec interpretation. Takes precedence
    /// over `sim` (a registry runs exactly one backend).
    compiled: Option<CompiledSet>,
    /// Which device of a [`super::DeviceSet`] this registry is pinned to
    /// (0 for single-device registries).
    device_id: usize,
    dir: PathBuf,
    modules: HashMap<String, ModuleSpec>,
    params: HashMap<String, Vec<ParamSpec>>,
    config: Json,
    cache: RwLock<HashMap<String, Arc<Executable>>>,
}

impl ArtifactRegistry {
    /// Open `dir/manifest.json` and prepare a CPU PJRT runtime (device 0).
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, 0, None)
    }

    /// Open a PJRT-backed registry pinned to `device_id` of a multi-device
    /// set — its own client and executable cache, shared with no other
    /// device (see [`super::DeviceSet`]).
    ///
    /// The id isolates clients and compiled-module caches per device;
    /// **physical device placement is not wired yet** — the current
    /// client layer always creates a default CPU client, so on a real
    /// backend every registry computes on the same device (see the
    /// "real multi-device PJRT" follow-up in ROADMAP.md; only
    /// `runtime::client` needs to learn device selection). Simulated
    /// registries are unaffected — their values are device-independent
    /// by construction.
    pub fn open_on_device(dir: &Path, device_id: usize) -> Result<Self> {
        Self::open_with(dir, device_id, None)
    }

    /// Open a **simulated** registry pinned to `device_id`: `call`
    /// synthesizes deterministic outputs from the manifest output specs
    /// ([`super::sim`]), so the full execution stack runs offline. Values
    /// depend only on (module, inputs) — never the device — which is what
    /// keeps sharded runs bit-identical to serial.
    pub fn open_simulated(dir: &Path, device_id: usize) -> Result<Self> {
        Self::open_with(dir, device_id, Some(SimBackend::default()))
    }

    /// [`ArtifactRegistry::open_simulated`] with fault injection: every
    /// `call` to `fail_module` returns a typed error — the offline
    /// stand-in for a device whose execution path is broken (used by the
    /// fault tests in rust/tests/sharding.rs).
    pub fn open_simulated_with_fault(
        dir: &Path,
        device_id: usize,
        fail_module: impl Into<String>,
    ) -> Result<Self> {
        Self::open_with(dir, device_id, Some(SimBackend { fail_module: Some(fail_module.into()) }))
    }

    /// Open a registry pinned to `device_id` running the given execution
    /// [`Backend`]. [`Backend::Compiled`] lowers **every** manifest module
    /// through the `crate::compile` pipeline eagerly here, so a corrupt
    /// manifest fails the open with a typed compile error rather than the
    /// thousandth call — and the hot path never re-validates a shape.
    pub fn open_with_backend(dir: &Path, device_id: usize, backend: Backend) -> Result<Self> {
        match backend {
            Backend::Xla => Self::open_with(dir, device_id, None),
            Backend::Sim => Self::open_with(dir, device_id, Some(SimBackend::default())),
            Backend::Compiled => {
                let mut reg = Self::open_with(dir, device_id, None)?;
                reg.compiled = Some(CompiledSet::compile(reg.modules.values())?);
                Ok(reg)
            }
        }
    }

    fn open_with(dir: &Path, device_id: usize, sim: Option<SimBackend>) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError::Io(format!(
                "cannot read {} ({e}) — run `make artifacts`",
                manifest_path.display()
            ))
        })?;
        let root = Json::parse(&text).map_err(|e| bad(e.to_string()))?;

        let mut modules = HashMap::new();
        for m in root.get("modules").and_then(Json::as_arr).ok_or_else(|| bad("no modules"))? {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("module missing name"))?
                .to_string();
            let file = m
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("module missing file"))?
                .to_string();
            let inputs = m
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("module missing inputs"))?
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = m
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("module missing outputs"))?
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            modules.insert(name.clone(), ModuleSpec { name, file, inputs, outputs });
        }

        let mut params = HashMap::new();
        if let Some(Json::Obj(pm)) = root.get("params") {
            for (model, list) in pm {
                let specs = list
                    .as_arr()
                    .ok_or_else(|| bad("params entry not an array"))?
                    .iter()
                    .map(|p| {
                        Ok(ParamSpec {
                            name: p
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| bad("param missing name"))?
                                .to_string(),
                            shape: p
                                .get("shape")
                                .and_then(Json::as_usize_vec)
                                .ok_or_else(|| bad("param missing shape"))?,
                            offset: p
                                .get("offset")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| bad("param missing offset"))?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                params.insert(model.clone(), specs);
            }
        }

        let config = root.get("config").cloned().unwrap_or(Json::Obj(Default::default()));
        Ok(Self {
            runtime: OnceLock::new(),
            sim,
            compiled: None,
            device_id,
            dir: dir.to_path_buf(),
            modules,
            params,
            config,
            cache: RwLock::new(HashMap::new()),
        })
    }

    /// Which device this registry is pinned to (0 unless opened through a
    /// [`super::DeviceSet`]).
    pub fn device_id(&self) -> usize {
        self.device_id
    }

    /// The artifact directory this registry was opened from (used by
    /// [`super::DeviceSet::with_primary`] to open sibling per-device
    /// registries over the same artifacts).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Does this registry execute through the deterministic simulation
    /// backend instead of PJRT? (Strictly [`Backend::Sim`] — the compiled
    /// backend is offline too but reports itself via [`Self::backend`].)
    pub fn is_simulated(&self) -> bool {
        self.sim.is_some()
    }

    /// Which execution backend this registry dispatches calls to.
    pub fn backend(&self) -> Backend {
        if self.compiled.is_some() {
            Backend::Compiled
        } else if self.sim.is_some() {
            Backend::Sim
        } else {
            Backend::Xla
        }
    }

    /// Snapshot of the compiled backend's live counters (plans cached,
    /// fused ops, arena activity), if this registry runs it.
    pub fn compile_stats(&self) -> Option<CompileStatsSnapshot> {
        self.compiled.as_ref().map(|c| c.stats().snapshot())
    }

    /// The compiled plan set, for building fused model-level programs
    /// over this registry ([`crate::compile::InferProgram`]).
    pub(crate) fn compiled_set(&self) -> Option<&CompiledSet> {
        self.compiled.as_ref()
    }

    /// The PJRT runtime, created on first use. Two threads racing here both
    /// build a client; the first `set` wins and the loser is dropped —
    /// client creation is idempotent, so this needs no extra locking.
    fn runtime(&self) -> Result<&XlaRuntime> {
        if self.runtime.get().is_none() {
            let rt = XlaRuntime::cpu()?;
            let _ = self.runtime.set(rt);
        }
        Ok(self.runtime.get().expect("runtime just initialized"))
    }

    /// Manifest `config` section (solver, Nt, batch size, ...).
    pub fn config(&self) -> &Json {
        &self.config
    }

    /// A u64 field from the manifest config, if present.
    pub fn config_u64(&self, key: &str) -> Option<u64> {
        self.config.get(key).and_then(Json::as_u64)
    }

    /// Names of all modules in the manifest (sorted).
    pub fn module_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.modules.keys().map(|s| s.as_str()).collect();
        names.sort();
        names
    }

    /// Does the manifest contain this module?
    pub fn has_module(&self, name: &str) -> bool {
        self.modules.contains_key(name)
    }

    /// Spec for one module.
    pub fn module_spec(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .ok_or_else(|| RuntimeError::Io(format!("module {name} not in manifest")))
    }

    /// Canonical parameter layout for a model (e.g. "resnet", "sqnxt").
    pub fn param_layout(&self, model: &str) -> Result<&[ParamSpec]> {
        self.params
            .get(model)
            .map(|v| v.as_slice())
            .ok_or_else(|| RuntimeError::Io(format!("no param layout for model {model}")))
    }

    /// Load the initial parameters for `model` from params.bin (f32 LE),
    /// in canonical order.
    pub fn load_params(&self, model: &str) -> Result<Vec<Tensor>> {
        let layout = self.param_layout(model)?.to_vec();
        let path = self.dir.join("params.bin");
        let bytes = std::fs::read(&path)
            .map_err(|e| RuntimeError::Io(format!("cannot read {}: {e}", path.display())))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        layout
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                let end = p.offset + n;
                if end > floats.len() {
                    return Err(RuntimeError::Io(format!(
                        "params.bin too short for {} (needs {} floats, file has {})",
                        p.name,
                        end,
                        floats.len()
                    )));
                }
                Tensor::from_vec(p.shape.clone(), floats[p.offset..end].to_vec())
                    .map_err(|e| RuntimeError::Shape(e.to_string()))
            })
            .collect()
    }

    /// Get (compiling lazily) the executable for `name`.
    ///
    /// Hot path takes the read lock only. On a miss the compile happens
    /// outside any lock; if two threads race, the first insert wins and the
    /// duplicate executable is dropped (compilation is idempotent).
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.read().expect("executable cache poisoned").get(name) {
            return Ok(exe.clone());
        }
        let spec = self.module_spec(name)?;
        let path = self.dir.join(&spec.file);
        let exe = Arc::new(self.runtime()?.compile_hlo_text(name, &path)?);
        let mut cache = self.cache.write().expect("executable cache poisoned");
        Ok(cache.entry(name.to_string()).or_insert(exe).clone())
    }

    /// Execute a module, validating input shapes against the manifest.
    ///
    /// The spec is **borrowed**, not cloned — the manifest tables are
    /// immutable after `open`, so the hot path carries no per-call
    /// allocation for the spec. Compiled registries dispatch the cached
    /// fused-kernel plan; simulated registries synthesize deterministic
    /// outputs from the manifest output specs; PJRT-backed registries
    /// compile lazily and run the artifact.
    pub fn call(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.module_spec(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(RuntimeError::Shape(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (t, s) in inputs.iter().zip(spec.inputs.iter()) {
            if t.shape() != s.shape.as_slice() {
                return Err(RuntimeError::Shape(format!(
                    "{name}: input {} shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                )));
            }
        }
        self.dispatch(spec, inputs)
    }

    /// [`Self::call`] minus the per-input shape loop — only the input
    /// *count* is checked. For callers whose inputs are shape-validated
    /// at the API boundary and then flow through a fixed module sequence
    /// (the execution core's training/inference loops), re-validating
    /// every tensor on every call is pure overhead; this is the trusted
    /// hot path. Unknown modules and wrong arity still fail typed.
    pub fn call_trusted(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.module_spec(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(RuntimeError::Shape(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        self.dispatch(spec, inputs)
    }

    /// Backend dispatch shared by [`Self::call`] and [`Self::call_trusted`]:
    /// compiled plan → simulation (with fault injection) → PJRT.
    fn dispatch(&self, spec: &ModuleSpec, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if let Some(set) = &self.compiled {
            let plan = set.plan(&spec.name).ok_or_else(|| {
                RuntimeError::Io(format!("module {} missing from compiled set", spec.name))
            })?;
            return plan.execute(inputs);
        }
        if let Some(sim) = &self.sim {
            if sim.fail_module.as_deref() == Some(spec.name.as_str()) {
                return Err(RuntimeError::Xla(format!(
                    "sim device {}: injected fault executing {}",
                    self.device_id, spec.name
                )));
            }
            return sim_outputs(&spec.name, inputs, &spec.outputs);
        }
        let exe = self.get(&spec.name)?;
        let outs = exe.call(inputs)?;
        if outs.len() != spec.outputs.len() {
            return Err(RuntimeError::Shape(format!(
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                outs.len()
            )));
        }
        Ok(outs)
    }

    /// Number of compiled (cached) executables — used by tests/perf logs.
    pub fn compiled_count(&self) -> usize {
        self.cache.read().expect("executable cache poisoned").len()
    }
}

// The whole execution stack shares one registry across worker threads, so
// a non-Send backend type must fail the build here rather than at a distant
// use site. (The vendored xla stub is trivially thread-safe; a real
// PJRT-backed `xla` crate must keep its client/executable handles
// `Send + Sync` — PJRT itself is thread-safe.)
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ArtifactRegistry>();
};
