//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO *text* (see DESIGN.md §5 and
//! /opt/xla-example/README.md): jax ≥ 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;

use crate::tensor::Tensor;

/// Errors surfaced by the runtime layer. `Clone` because the serving
/// path fans one batch-level failure out to every request in the batch.
#[derive(Debug, Clone)]
pub enum RuntimeError {
    /// Underlying xla crate error (PJRT, compilation, execution).
    Xla(String),
    /// Artifact file missing or unreadable.
    Io(String),
    /// Output arity or shape did not match expectations.
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::Io(e) => write!(f, "artifact io error: {e}"),
            RuntimeError::Shape(e) => write!(f, "shape error: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// A PJRT client owning compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

/// One compiled HLO module, callable with host tensors.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable name (manifest module name) for error messages.
    pub name: String,
}

impl XlaRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    /// Platform name, e.g. "cpu".
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Devices the platform exposes. The vendored stub simulates
    /// `ANODE_SIM_DEVICES` devices (default 1); a real PJRT client reports
    /// its hardware topology. See [`super::DeviceSet`].
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text(&self, name: &str, path: &Path) -> Result<Executable> {
        if !path.exists() {
            return Err(RuntimeError::Io(format!(
                "artifact {} not found at {} — run `make artifacts`",
                name,
                path.display()
            )));
        }
        let path_str = path
            .to_str()
            .ok_or_else(|| RuntimeError::Io(format!("non-utf8 path {}", path.display())))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: name.to_string() })
    }
}

impl Executable {
    /// Execute with host f32 tensors; returns output tensors.
    ///
    /// Modules are lowered with `return_tuple=True`, so the single PJRT
    /// output buffer is a tuple we unpack into `Tensor`s.
    pub fn call(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<usize> = t.shape().to_vec();
                let lit = xla::Literal::vec1(t.data());
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64).map_err(RuntimeError::from)
            })
            .collect::<Result<Vec<_>>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        let mut result = out[0][0].to_literal_sync()?;
        let tuple = result.decompose_tuple()?;
        let mut tensors = Vec::with_capacity(tuple.len());
        for lit in tuple {
            tensors.push(literal_to_tensor(&lit, &self.name)?);
        }
        Ok(tensors)
    }
}

/// Convert an xla literal (f32) to a host tensor.
fn literal_to_tensor(lit: &xla::Literal, ctx: &str) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| RuntimeError::Shape(format!("{ctx}: output not f32: {e}")))?;
    Tensor::from_vec(dims, data).map_err(|e| RuntimeError::Shape(format!("{ctx}: {e}")))
}
