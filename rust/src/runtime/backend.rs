//! Execution-backend selection: which engine executes module calls.
//!
//! A registry runs in exactly one of three modes, and every layer above it
//! — [`super::DeviceSet`], `api::EngineBuilder`, the CLI — selects the
//! mode through this one enum instead of ad-hoc booleans:
//!
//! * [`Backend::Xla`] — compile the HLO-text artifacts through PJRT
//!   (the production path; errors on the vendored stub).
//! * [`Backend::Sim`] — synthesize outputs through the deterministic
//!   [`super::sim`] value model (the offline interpreter).
//! * [`Backend::Compiled`] — lower the manifest through the typed IR of
//!   [`crate::compile`] into fused native kernels ahead of time; calls
//!   dispatch precompiled plans with zero per-call shape checks. Values
//!   are bit-identical to [`Backend::Sim`] by construction (the plans
//!   implement the same value model), so every bit-identity property of
//!   the sharded execution stack holds across backends.
//!
//! Selection precedence at the engine layer: an explicit
//! `EngineBuilder::backend` wins, then the `ANODE_BACKEND` environment
//! variable ([`backend_env`]), then the legacy `simulate(true)` flag
//! (an alias for [`Backend::Sim`]), then [`Backend::Xla`]. The env
//! overriding `simulate` is deliberate: `ANODE_BACKEND=compiled` makes
//! the whole sim-based test suite exercise the compiled path (the CI
//! `backend-compiled` gate leg).

/// Which execution engine a registry dispatches module calls to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// PJRT over the AOT HLO-text artifacts (default).
    #[default]
    Xla,
    /// Deterministic simulated execution (interpreted value model).
    Sim,
    /// Ahead-of-time compiled fused kernels ([`crate::compile`]).
    Compiled,
}

impl Backend {
    /// Stable lowercase name (CLI flags, `ANODE_BACKEND`, logs).
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Xla => "xla",
            Backend::Sim => "sim",
            Backend::Compiled => "compiled",
        }
    }

    /// Parse the stable name back (`"xla"` / `"sim"` / `"compiled"`).
    pub fn parse(s: &str) -> Option<Backend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "xla" => Some(Backend::Xla),
            "sim" => Some(Backend::Sim),
            "compiled" => Some(Backend::Compiled),
            _ => None,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Backend requested by the environment: `ANODE_BACKEND=xla|sim|compiled`.
/// Unset or unrecognized values yield `None` (callers fall back to their
/// own default; the CLI rejects bad values loudly at flag-parse time).
pub fn backend_env() -> Option<Backend> {
    std::env::var("ANODE_BACKEND").ok().as_deref().and_then(Backend::parse)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_stable_names() {
        for b in [Backend::Xla, Backend::Sim, Backend::Compiled] {
            assert_eq!(Backend::parse(b.as_str()), Some(b));
            assert_eq!(b.to_string(), b.as_str());
        }
        assert_eq!(Backend::parse(" Compiled "), Some(Backend::Compiled));
        assert_eq!(Backend::parse("jit"), None);
        assert_eq!(Backend::default(), Backend::Xla);
    }
}
