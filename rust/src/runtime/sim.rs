//! Simulated execution backend — the offline multi-device test harness.
//!
//! The vendored xla stub cannot execute HLO, which used to confine every
//! end-to-end test (training, prediction, serving) to machines with real
//! artifacts. Simulation closes that gap: a registry opened with
//! [`super::ArtifactRegistry::open_simulated`] answers `call` by
//! synthesizing outputs **deterministically from the module name, the
//! input bytes and the manifest output specs** — no backend, no compiled
//! executables. The numbers are meaningless as a model but bit-stable, so
//! every structural property of the execution stack is testable offline:
//! the forward/backward dataflow of all five gradient strategies, the
//! fixed-order gradient reduction, SGD updates, ledger accounting, and —
//! the point of the harness — **bit-identity of sharded execution across
//! any (devices × workers) grid**, because the synthesized value of a call
//! depends only on its inputs, never on which device or worker ran it.
//!
//! [`write_artifacts`] emits a matching synthetic artifact set (manifest
//! with full input/output tensor specs plus `params.bin`) for a small
//! [`SimSpec`] model, so `rust/tests/sharding.rs` and the
//! `shard_throughput` bench can stand up a complete multi-device engine on
//! the stub. See rust/DESIGN.md §6d.

use std::path::Path;

use crate::tensor::Tensor;

use super::{Result, RuntimeError, TensorSpec};

/// Deterministic-execution state of a simulated registry (one per device;
/// the device id itself never feeds the value kernel — that is what makes
/// sharded runs bit-identical to serial).
#[derive(Debug, Clone, Default)]
pub(crate) struct SimBackend {
    /// Fault injection: `call`s to this module fail with a typed error —
    /// the offline stand-in for a device whose execution path is broken.
    pub fail_module: Option<String>,
}

pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1_0000_0001_b3;

/// The one FNV-style mixing step of the deterministic value model.
///
/// `pub(crate)` because the compiled backend ([`crate::compile`]) lowers
/// the *same* value model to fused kernels: sharing the primitive is what
/// makes "compiled ≡ sim, bitwise" a structural property instead of two
/// hand-synchronized copies of the constants.
pub(crate) fn mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Map a hash to a small centered float in [-0.5, 0.5) — always finite,
/// so simulated losses/gradients never trip the divergence guards.
/// Shared with [`crate::compile`] for the same reason as [`mix`].
pub(crate) fn centered(h: u64) -> f32 {
    ((h % 1_000_003) as f32 / 1_000_003.0) - 0.5
}

/// FNV digest of a module name — the compile-time-constant prefix of the
/// value model ([`crate::compile`] folds it into each plan's seed).
pub(crate) fn name_digest(name: &str) -> u64 {
    let mut digest = FNV_OFFSET;
    for b in name.bytes() {
        digest = mix(digest, u64::from(b));
    }
    digest
}

/// Synthesize a module call's outputs from (name, inputs, output specs).
///
/// Pure and order-sensitive in its inputs: two calls agree bitwise iff the
/// module name and every input tensor's bytes agree, which is exactly the
/// determinism contract sharded execution needs.
pub fn sim_outputs(name: &str, inputs: &[&Tensor], outputs: &[TensorSpec]) -> Result<Vec<Tensor>> {
    if outputs.is_empty() {
        return Err(RuntimeError::Shape(format!(
            "sim: module {name} declares no outputs in the manifest — simulated manifests \
             must carry full output specs (see runtime::sim::write_artifacts)"
        )));
    }
    let mut digest = name_digest(name);
    for t in inputs {
        digest = mix(digest, t.data().len() as u64);
        for &v in t.data() {
            digest = mix(digest, u64::from(v.to_bits()));
        }
    }
    outputs
        .iter()
        .enumerate()
        .map(|(oi, spec)| {
            let base = mix(digest, oi as u64 + 1);
            let n: usize = spec.shape.iter().product::<usize>().max(1);
            let data: Vec<f32> = (0..n).map(|j| centered(mix(base, j as u64))).collect();
            Tensor::from_vec(spec.shape.clone(), data)
                .map_err(|e| RuntimeError::Shape(format!("sim {name}: {e}")))
        })
        .collect()
}

/// Shape of the small synthetic model [`write_artifacts`] emits.
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub batch: usize,
    pub image: usize,
    /// Channels per stage; the stage count is `channels.len()`.
    pub channels: Vec<usize>,
    pub blocks_per_stage: usize,
    pub nt: usize,
    pub num_classes: usize,
}

impl Default for SimSpec {
    fn default() -> Self {
        // Small enough that a full (devices × workers × strategies) grid
        // of simulated training runs stays fast.
        Self {
            batch: 4,
            image: 8,
            channels: vec![4, 8],
            blocks_per_stage: 1,
            nt: 4,
            num_classes: 10,
        }
    }
}

impl SimSpec {
    fn stages(&self) -> usize {
        self.channels.len()
    }

    fn act_shape(&self, s: usize) -> Vec<usize> {
        let hw = self.image >> s;
        vec![self.batch, hw, hw, self.channels[s]]
    }

    /// Deterministic input image batch `k` shaped for this model — the
    /// one generator shared by `rust/tests/sharding.rs` and the
    /// `shard_throughput` bench, so the two harnesses cannot silently
    /// diverge from the spec's input shape.
    pub fn image_batch(&self, k: usize) -> Tensor {
        let len = self.batch * self.image * self.image * 3;
        let data = (0..len).map(|j| (((k * 131 + j) % 977) as f32) * 0.001 - 0.3).collect();
        Tensor::from_vec(vec![self.batch, self.image, self.image, 3], data)
            .expect("sim image shape")
    }

    /// Deterministic in-range class labels for input batch `k`.
    pub fn label_batch(&self, k: usize) -> Tensor {
        let data = (0..self.batch).map(|r| ((k + r) % self.num_classes) as f32).collect();
        Tensor::from_vec(vec![self.batch], data).expect("sim label shape")
    }
}

fn shape_json(shape: &[usize]) -> String {
    let inner: Vec<String> = shape.iter().map(|d| d.to_string()).collect();
    format!("[{}]", inner.join(","))
}

fn spec_json(name: &str, shape: &[usize]) -> String {
    format!(r#"{{"name":"{name}","shape":{},"dtype":"f32"}}"#, shape_json(shape))
}

/// Write a complete synthetic artifact set (manifest.json with full
/// input/output tensor specs, plus a matching params.bin) for `spec` into
/// `dir` — a `resnet`/`euler` model every gradient strategy can drive.
///
/// Open the result with [`super::ArtifactRegistry::open_simulated`] (or
/// `EngineBuilder::simulate(true)`) and the whole execution stack —
/// train, predict, serve — runs offline with deterministic values.
pub fn write_artifacts(dir: &Path, spec: &SimSpec) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;

    // --- params: canonical layout with real shapes and offsets ---------
    let mut params: Vec<(String, Vec<usize>)> = Vec::new();
    params.push(("stem.w".into(), vec![3, spec.channels[0]]));
    params.push(("stem.b".into(), vec![spec.channels[0]]));
    for s in 0..spec.stages() {
        let c = spec.channels[s];
        for b in 0..spec.blocks_per_stage {
            params.push((format!("s{s}.b{b}.w"), vec![c, c]));
            params.push((format!("s{s}.b{b}.b"), vec![c]));
        }
        if s + 1 < spec.stages() {
            params.push((format!("trans{s}.w"), vec![c, spec.channels[s + 1]]));
            params.push((format!("trans{s}.b"), vec![spec.channels[s + 1]]));
        }
    }
    let c_last = *spec.channels.last().expect("at least one stage");
    params.push(("head.w".into(), vec![c_last, spec.num_classes]));
    params.push(("head.b".into(), vec![spec.num_classes]));

    let mut param_entries = Vec::with_capacity(params.len());
    let mut offset = 0usize;
    let mut blob: Vec<f32> = Vec::new();
    for (name, shape) in &params {
        let n: usize = shape.iter().product();
        param_entries.push(format!(
            r#"{{"name":"{name}","shape":{},"offset":{offset}}}"#,
            shape_json(shape)
        ));
        for j in 0..n {
            // Deterministic small init, independent of everything else.
            blob.push(centered(mix(FNV_OFFSET, (offset + j) as u64)) * 0.2);
        }
        offset += n;
    }

    // --- modules: full input/output specs ------------------------------
    fn find_shape<'a>(params: &'a [(String, Vec<usize>)], name: &str) -> &'a [usize] {
        &params.iter().find(|(n, _)| n == name).expect("param exists").1
    }
    let x_shape = vec![spec.batch, spec.image, spec.image, 3];
    let labels_shape = vec![spec.batch];
    let scalar = vec![1usize];

    let mut modules: Vec<String> = Vec::new();
    let mut add = |name: &str, inputs: Vec<(&str, &[usize])>, outputs: Vec<(&str, &[usize])>| {
        let ins: Vec<String> = inputs.iter().map(|(n, s)| spec_json(n, s)).collect();
        let outs: Vec<String> = outputs.iter().map(|(n, s)| spec_json(n, s)).collect();
        modules.push(format!(
            r#"{{"name":"{name}","file":"{name}.hlo.txt","inputs":[{}],"outputs":[{}]}}"#,
            ins.join(","),
            outs.join(",")
        ));
    };

    let act0 = spec.act_shape(0);
    add(
        "stem_fwd",
        vec![
            ("x", &x_shape),
            ("w", find_shape(&params, "stem.w")),
            ("b", find_shape(&params, "stem.b")),
        ],
        vec![("z", &act0)],
    );
    add(
        "stem_vjp",
        vec![
            ("x", &x_shape),
            ("w", find_shape(&params, "stem.w")),
            ("b", find_shape(&params, "stem.b")),
            ("gz", &act0),
        ],
        vec![("gw", find_shape(&params, "stem.w")), ("gb", find_shape(&params, "stem.b"))],
    );
    for s in 0..spec.stages() {
        let act = spec.act_shape(s);
        let w = find_shape(&params, &format!("s{s}.b0.w")).to_vec();
        let b = find_shape(&params, &format!("s{s}.b0.b")).to_vec();
        let fwd_ins = vec![("z", &act[..]), ("w", &w[..]), ("b", &b[..])];
        let vjp_ins =
            vec![("z", &act[..]), ("w", &w[..]), ("b", &b[..]), ("gz", &act[..])];
        let vjp_outs = vec![("gz", &act[..]), ("gw", &w[..]), ("gb", &b[..])];
        for kind in ["fwd", "step_fwd"] {
            add(
                &format!("block_resnet_s{s}_euler_{kind}"),
                fwd_ins.clone(),
                vec![("z", &act[..])],
            );
        }
        for kind in ["vjp", "step_vjp", "otd"] {
            add(&format!("block_resnet_s{s}_euler_{kind}"), vjp_ins.clone(), vjp_outs.clone());
        }
        let mut node_outs = vjp_outs.clone();
        node_outs.push(("z0_rec", &act[..]));
        add(&format!("block_resnet_s{s}_euler_node"), vjp_ins.clone(), node_outs);
        if s + 1 < spec.stages() {
            let next = spec.act_shape(s + 1);
            let tw = find_shape(&params, &format!("trans{s}.w")).to_vec();
            let tb = find_shape(&params, &format!("trans{s}.b")).to_vec();
            add(
                &format!("trans{s}_fwd"),
                vec![("z", &act[..]), ("w", &tw[..]), ("b", &tb[..])],
                vec![("z", &next[..])],
            );
            add(
                &format!("trans{s}_vjp"),
                vec![("z", &act[..]), ("w", &tw[..]), ("b", &tb[..]), ("gz", &next[..])],
                vec![("gz", &act[..]), ("gw", &tw[..]), ("gb", &tb[..])],
            );
        }
    }
    let z_final = spec.act_shape(spec.stages() - 1);
    let k = spec.num_classes;
    add(
        &format!("head{k}_loss_grad"),
        vec![
            ("z", &z_final),
            ("w", find_shape(&params, "head.w")),
            ("b", find_shape(&params, "head.b")),
            ("labels", &labels_shape),
        ],
        vec![
            ("loss", &scalar),
            ("correct", &scalar),
            ("gz", &z_final),
            ("gw", find_shape(&params, "head.w")),
            ("gb", find_shape(&params, "head.b")),
        ],
    );
    add(
        &format!("head{k}_eval"),
        vec![
            ("z", &z_final),
            ("w", find_shape(&params, "head.w")),
            ("b", find_shape(&params, "head.b")),
            ("labels", &labels_shape),
        ],
        vec![("loss", &scalar), ("correct", &scalar)],
    );

    let manifest = format!(
        r#"{{
  "modules": [{}],
  "params": {{"resnet{k}": [{}]}},
  "config": {{"batch": {}, "image": {}, "blocks_per_stage": {}, "nt": {}, "channels": {}}}
}}"#,
        modules.join(","),
        param_entries.join(","),
        spec.batch,
        spec.image,
        spec.blocks_per_stage,
        spec.nt,
        shape_json(&spec.channels),
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;

    let bytes: Vec<u8> = blob.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(dir.join("params.bin"), bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: "f32".into() }
    }

    #[test]
    fn sim_outputs_are_deterministic_and_input_sensitive() {
        let z = Tensor::full(&[2, 3], 0.25);
        let outs = vec![spec("a", &[2, 3]), spec("loss", &[1])];
        let run1 = sim_outputs("mod", &[&z], &outs).unwrap();
        let run2 = sim_outputs("mod", &[&z], &outs).unwrap();
        assert_eq!(run1.len(), 2);
        assert_eq!(run1[0].data(), run2[0].data(), "same inputs must agree bitwise");
        assert_eq!(run1[1].shape(), &[1]);
        assert!(run1.iter().all(|t| t.all_finite()));

        let z2 = Tensor::full(&[2, 3], 0.26);
        let run3 = sim_outputs("mod", &[&z2], &outs).unwrap();
        assert_ne!(run1[0].data(), run3[0].data(), "different inputs must differ");
        let run4 = sim_outputs("other", &[&z], &outs).unwrap();
        assert_ne!(run1[0].data(), run4[0].data(), "different modules must differ");
    }

    #[test]
    fn sim_outputs_reject_missing_output_specs() {
        let z = Tensor::zeros(&[2]);
        let err = sim_outputs("empty", &[&z], &[]).unwrap_err();
        assert!(err.to_string().contains("no outputs"), "{err}");
    }

    #[test]
    fn write_artifacts_emits_parseable_manifest_and_params() {
        let dir = std::env::temp_dir()
            .join(format!("anode_sim_unit_{}", std::process::id()));
        write_artifacts(&dir, &SimSpec::default()).unwrap();
        let reg = crate::runtime::ArtifactRegistry::open(&dir).unwrap();
        assert!(reg.has_module("stem_fwd"));
        assert!(reg.has_module("block_resnet_s0_euler_step_vjp"));
        assert!(reg.has_module("head10_loss_grad"));
        let params = reg.load_params("resnet10").unwrap();
        assert_eq!(params.first().unwrap().shape(), &[3, 4]);
        assert!(params.iter().all(|p| p.all_finite()));
        std::fs::remove_dir_all(&dir).ok();
    }
}
