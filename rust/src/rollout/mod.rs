//! # `anode::rollout` — the train→canary→promote/rollback loop
//!
//! Closes the continuous-training loop over the existing seams: a
//! [`RolloutOrchestrator`] drives a training [`Session`] on the caller's
//! thread **while serve traffic keeps flowing** through the session's
//! live [`ServeHandle`] pipeline (the admission queue, batcher, and
//! device pools never drain), periodically snapshots the trained
//! parameters into one `Arc<Vec<Tensor>>` candidate (one allocation
//! shared across every device runner — the PR 6 `swap_params` contract),
//! shadow-evaluates each candidate on a held-out stream, and:
//!
//! * **promotes** the candidate to the live pipeline
//!   ([`ServeHandle::promote_params`], an atomic between-batches
//!   hot-swap) when the [`QualityGate`] passes — a configurable relative
//!   loss threshold that must hold for `hysteresis` *consecutive*
//!   candidates, so a flapping trainer never reaches serving;
//! * **rolls back** to the last-good snapshot
//!   ([`ServeHandle::rollback_params`]) on a *regression event* — a
//!   training step or shadow evaluation that errors (e.g. a broken
//!   device), or a candidate whose loss goes non-finite (a diverged
//!   trainer makes the most recent promotion suspect too).
//!
//! ```text
//!        ┌────────── train canary_every steps ──────────┐
//!        │                                              ▼
//!   Session ──▶ candidate = Arc<Vec<Tensor>> ──▶ shadow-eval (held-out)
//!        ▲                                              │
//!        │                 QualityGate: pass × hysteresis│
//!   serve traffic keeps flowing                         ▼
//!   ServeHandle ◀── promote_params ──── pass ──┬── fail: hold (streak=0)
//!        ▲                                     └── error/non-finite:
//!        └────────── rollback_params ◀──────────── rollback to last-good
//! ```
//!
//! The shadow evaluation runs through [`Session::evaluate_with_workers`]
//! — the ledger-free inference path over the **session's cached
//! per-device pools** (`util::pool`), so the trainer and the evaluator
//! share one thread substrate instead of each spawning their own.
//!
//! Gate semantics, rollback ordering against in-flight batches, and the
//! CI baseline-gate workflow are documented in rust/DESIGN.md §6g. The
//! offline e2e (sim devices, fault injection, net clients during
//! promotion) lives in rust/tests/rollout.rs; `BENCH_rollout.json` comes
//! from rust/benches/rollout_throughput.rs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::Session;
use crate::runtime::{Result, RuntimeError};
use crate::serve::ServeHandle;
use crate::tensor::Tensor;

/// Configuration for one [`RolloutOrchestrator::run`] campaign.
#[derive(Debug, Clone)]
pub struct RolloutConfig {
    /// Training steps between candidate snapshots (min 1; default 4).
    pub canary_every: usize,
    /// Candidate rounds to run (default 3). Each round trains
    /// `canary_every` steps, snapshots, and shadow-evaluates once.
    pub rounds: usize,
    /// Relative loss tolerance of the quality gate: a candidate passes
    /// when `loss <= baseline * (1 + gate_threshold)` (default 0.25).
    /// Negative thresholds demand strict improvement.
    pub gate_threshold: f32,
    /// Consecutive passing candidates required before a promotion
    /// (min 1; default 1). A candidate that alternates pass/fail resets
    /// the streak each failure and never promotes.
    pub hysteresis: usize,
    /// Worker threads per device for the shadow evaluation (default 1).
    /// Evaluation runs over the session's cached pools either way.
    pub eval_workers: usize,
    /// Stop the campaign after the first rollback (default true). When
    /// false the orchestrator keeps training toward a better candidate.
    pub stop_on_rollback: bool,
    /// External pause flag (e.g. [`crate::net::NetServer::drain_flag`]):
    /// when it reads `true` the orchestrator stops promoting and returns
    /// early with [`RolloutReport::paused`] set — a draining server must
    /// not take new snapshots mid-drain.
    pub pause_on: Option<Arc<AtomicBool>>,
}

impl Default for RolloutConfig {
    fn default() -> Self {
        Self {
            canary_every: 4,
            rounds: 3,
            gate_threshold: 0.25,
            hysteresis: 1,
            eval_workers: 1,
            stop_on_rollback: true,
            pause_on: None,
        }
    }
}

impl RolloutConfig {
    /// Set the training steps per candidate snapshot.
    pub fn canary_every(mut self, steps: usize) -> Self {
        self.canary_every = steps.max(1);
        self
    }

    /// Set the candidate rounds to run.
    pub fn rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    /// Set the gate's relative loss tolerance.
    pub fn gate_threshold(mut self, threshold: f32) -> Self {
        self.gate_threshold = threshold;
        self
    }

    /// Set the consecutive-pass requirement.
    pub fn hysteresis(mut self, passes: usize) -> Self {
        self.hysteresis = passes.max(1);
        self
    }

    /// Set the shadow-evaluation worker count per device.
    pub fn eval_workers(mut self, workers: usize) -> Self {
        self.eval_workers = workers.max(1);
        self
    }

    /// Keep running after a rollback instead of stopping.
    pub fn continue_after_rollback(mut self) -> Self {
        self.stop_on_rollback = false;
        self
    }

    /// Pause promotion (and the campaign) when `flag` reads true.
    pub fn pause_on(mut self, flag: Arc<AtomicBool>) -> Self {
        self.pause_on = Some(flag);
        self
    }
}

/// What the quality gate said about one candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateDecision {
    /// The candidate passed `hysteresis` consecutive evaluations: promote.
    Promote,
    /// The candidate passed, but the streak is still building: hold.
    Hold,
    /// The candidate failed the threshold (or its loss was non-finite):
    /// hold serving on the current snapshot and reset the streak.
    Reject,
}

/// The promotion gate: a relative loss threshold with a consecutive-pass
/// hysteresis window. Pure state machine — no I/O — so the flapping
/// semantics are unit-testable without a pipeline.
///
/// A candidate *passes* when its held-out loss is finite and within
/// `threshold` (relative) of the baseline — the loss of the currently
/// promoted snapshot. `hysteresis` consecutive passes promote; any
/// failure resets the streak, so a candidate stream that alternates
/// pass/fail ("flapping") never promotes.
#[derive(Debug, Clone)]
pub struct QualityGate {
    threshold: f32,
    hysteresis: usize,
    streak: usize,
}

impl QualityGate {
    /// Gate with the given relative threshold and consecutive-pass
    /// requirement (clamped to >= 1).
    pub fn new(threshold: f32, hysteresis: usize) -> Self {
        Self { threshold, hysteresis: hysteresis.max(1), streak: 0 }
    }

    /// Current consecutive-pass streak.
    pub fn streak(&self) -> usize {
        self.streak
    }

    /// Feed one candidate evaluation. `baseline_loss` is the held-out
    /// loss of the currently promoted snapshot; a non-finite baseline
    /// (nothing promoted yet under a diverged start) lets any finite
    /// candidate pass.
    pub fn observe(&mut self, candidate_loss: f32, baseline_loss: f32) -> GateDecision {
        let pass = candidate_loss.is_finite()
            && (!baseline_loss.is_finite()
                || candidate_loss <= baseline_loss * (1.0 + self.threshold));
        if !pass {
            self.streak = 0;
            return GateDecision::Reject;
        }
        self.streak += 1;
        if self.streak >= self.hysteresis {
            self.streak = 0;
            GateDecision::Promote
        } else {
            GateDecision::Hold
        }
    }
}

/// Outcome of one [`RolloutOrchestrator::run`] campaign.
#[derive(Debug, Clone)]
pub struct RolloutReport {
    /// Rounds actually run (< `rounds` on an early stop).
    pub rounds_run: usize,
    /// Candidates snapshot-and-evaluated.
    pub candidates: usize,
    /// Candidates promoted to the live pipeline.
    pub promotions: usize,
    /// Regression events rolled back to the last-good snapshot.
    pub rollbacks: usize,
    /// Did the campaign stop because the pause flag was raised?
    pub paused: bool,
    /// Held-out loss of the snapshot serving when the campaign ended
    /// (NaN before the first baseline evaluation completes).
    pub baseline_loss: f32,
    /// Snapshot→promoted wall-clock per promotion, in order.
    pub promote_latency: Vec<Duration>,
    /// Detection→rolled-back wall-clock per rollback, in order.
    pub rollback_latency: Vec<Duration>,
    /// Total campaign wall-clock.
    pub wall: Duration,
}

/// The train→canary→promote/rollback driver over one [`ServeHandle`].
///
/// The orchestrator owns the promotion bookkeeping — the `live` snapshot
/// (what the pipeline serves now) and the `last_good` snapshot (the live
/// before the most recent promotion, the rollback target) — and survives
/// across [`RolloutOrchestrator::run`] calls, so a later campaign (even
/// with a different session over the same model) rolls back to what an
/// earlier campaign promoted. Construct it over the snapshot the handle
/// is currently serving; [`Session::rollout`] wires that up for the
/// common case.
pub struct RolloutOrchestrator {
    handle: ServeHandle,
    config: RolloutConfig,
    gate: QualityGate,
    live: Arc<Vec<Tensor>>,
    last_good: Arc<Vec<Tensor>>,
    baseline_loss: f32,
}

impl RolloutOrchestrator {
    /// Orchestrator over a running pipeline. `initial` must be the
    /// snapshot `handle` currently serves (it seeds both `live` and
    /// `last_good`); the baseline loss is established by the first
    /// shadow evaluation.
    pub fn new(handle: ServeHandle, initial: Arc<Vec<Tensor>>, config: RolloutConfig) -> Self {
        let gate = QualityGate::new(config.gate_threshold, config.hysteresis);
        Self {
            handle,
            config,
            gate,
            live: initial.clone(),
            last_good: initial,
            baseline_loss: f32::NAN,
        }
    }

    /// The snapshot the pipeline serves now (per this orchestrator's
    /// bookkeeping).
    pub fn live(&self) -> Arc<Vec<Tensor>> {
        self.live.clone()
    }

    /// The rollback target: the live snapshot before the most recent
    /// promotion (= `live` until something promotes).
    pub fn last_good(&self) -> Arc<Vec<Tensor>> {
        self.last_good.clone()
    }

    fn paused(&self) -> bool {
        self.config.pause_on.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Swap the last-good snapshot back into the pipeline and record the
    /// regression event. `live` becomes `last_good` again; the gate
    /// streak resets (whatever was accumulating is no longer trusted).
    fn roll_back(&mut self, detected: Instant, report: &mut RolloutReport) -> Result<()> {
        self.handle.rollback_params(self.last_good.clone())?;
        self.live = self.last_good.clone();
        self.gate = QualityGate::new(self.config.gate_threshold, self.config.hysteresis);
        report.rollbacks += 1;
        report.rollback_latency.push(detected.elapsed());
        Ok(())
    }

    /// Run one campaign: `rounds` × (train `canary_every` steps →
    /// snapshot → shadow-eval → gate). Training batches cycle through
    /// `train` in order; `eval` is the held-out stream. Returns the
    /// campaign report; the serve pipeline keeps running either way.
    ///
    /// Errors out of this function are *orchestration* failures (empty
    /// streams, a rollback swap that itself failed). Training/evaluation
    /// errors and non-finite candidate losses are regression events —
    /// handled by rolling back, not surfaced as `Err`.
    pub fn run(
        &mut self,
        session: &mut Session<'_>,
        train: &[(Tensor, Tensor)],
        eval: &[(Tensor, Tensor)],
    ) -> Result<RolloutReport> {
        if train.is_empty() || eval.is_empty() {
            return Err(RuntimeError::Shape(
                "rollout: need at least one training batch and one held-out batch".into(),
            ));
        }
        let t0 = Instant::now();
        let mut report = RolloutReport {
            rounds_run: 0,
            candidates: 0,
            promotions: 0,
            rollbacks: 0,
            paused: false,
            baseline_loss: self.baseline_loss,
            promote_latency: Vec::new(),
            rollback_latency: Vec::new(),
            wall: Duration::ZERO,
        };
        let mut cursor = 0usize;
        'campaign: for _ in 0..self.config.rounds {
            if self.paused() {
                report.paused = true;
                break;
            }
            report.rounds_run += 1;
            // Train toward the next candidate. A failing step is a
            // regression event: the trainer (or its device) is broken,
            // so serving returns to the last-good snapshot.
            for _ in 0..self.config.canary_every.max(1) {
                let (images, labels) = &train[cursor % train.len()];
                cursor += 1;
                if session.step(images, labels).is_err() {
                    self.roll_back(Instant::now(), &mut report)?;
                    if self.config.stop_on_rollback {
                        break 'campaign;
                    }
                    continue 'campaign;
                }
            }
            // One allocation shared across every device runner: the
            // candidate Arc is what promote_params fans out.
            let snapshot_at = Instant::now();
            let candidate = Arc::new(session.params().to_vec());
            self.handle.note_candidate();
            report.candidates += 1;
            // Shadow-evaluate on the held-out stream via the session's
            // cached per-device pools (ledger-free inference path).
            let loss = match session.evaluate_with_workers(eval, self.config.eval_workers) {
                Ok(stats) => stats.loss,
                Err(_) => {
                    self.roll_back(Instant::now(), &mut report)?;
                    if self.config.stop_on_rollback {
                        break 'campaign;
                    }
                    continue 'campaign;
                }
            };
            if !loss.is_finite() {
                // A diverged trainer also makes the most recent promotion
                // suspect: fail closed, back to last-good.
                self.roll_back(Instant::now(), &mut report)?;
                if self.config.stop_on_rollback {
                    break 'campaign;
                }
                continue 'campaign;
            }
            match self.gate.observe(loss, self.baseline_loss) {
                GateDecision::Promote => {
                    if self.paused() {
                        // A drain arrived mid-round: never promote into a
                        // draining pipeline.
                        report.paused = true;
                        break 'campaign;
                    }
                    self.handle.promote_params(candidate.clone())?;
                    self.last_good = std::mem::replace(&mut self.live, candidate);
                    self.baseline_loss = loss;
                    report.promotions += 1;
                    report.promote_latency.push(snapshot_at.elapsed());
                }
                GateDecision::Hold | GateDecision::Reject => {
                    // Serving stays on `live`; a failed candidate never
                    // touched the pipeline, so nothing rolls back.
                    if !self.baseline_loss.is_finite() {
                        // First evaluation under an unset baseline: adopt
                        // it so later rounds have a reference even when
                        // the gate is still building its streak.
                        self.baseline_loss = loss;
                    }
                }
            }
        }
        report.baseline_loss = self.baseline_loss;
        report.wall = t0.elapsed();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_promotes_after_hysteresis_consecutive_passes() {
        let mut gate = QualityGate::new(0.10, 3);
        assert_eq!(gate.observe(1.0, 1.0), GateDecision::Hold);
        assert_eq!(gate.observe(1.05, 1.0), GateDecision::Hold);
        assert_eq!(gate.observe(0.9, 1.0), GateDecision::Promote);
        // The streak reset on promotion: the next pass starts over.
        assert_eq!(gate.observe(0.9, 0.9), GateDecision::Hold);
    }

    #[test]
    fn gate_flapping_candidate_never_promotes() {
        let mut gate = QualityGate::new(0.0, 2);
        for _ in 0..32 {
            assert_eq!(gate.observe(0.5, 1.0), GateDecision::Hold, "pass builds the streak");
            assert_eq!(gate.observe(2.0, 1.0), GateDecision::Reject, "fail resets it");
        }
        assert_eq!(gate.streak(), 0);
    }

    #[test]
    fn gate_rejects_non_finite_candidates() {
        let mut gate = QualityGate::new(10.0, 1);
        assert_eq!(gate.observe(f32::NAN, 1.0), GateDecision::Reject);
        assert_eq!(gate.observe(f32::INFINITY, 1.0), GateDecision::Reject);
        // A non-finite baseline (nothing promoted yet) lets a finite
        // candidate through.
        assert_eq!(gate.observe(3.0, f32::NAN), GateDecision::Promote);
    }

    #[test]
    fn gate_negative_threshold_demands_improvement() {
        let mut gate = QualityGate::new(-0.5, 1);
        assert_eq!(gate.observe(0.6, 1.0), GateDecision::Reject);
        assert_eq!(gate.observe(0.4, 1.0), GateDecision::Promote);
    }
}
