//! Experiment harnesses — one function per paper figure/table, shared by
//! the CLI (`anode figures --fig ...`), the examples, and the benches, so
//! every number in EXPERIMENTS.md has exactly one implementation.

mod fig1;
mod gradcheck;
mod memtable;
mod sec3;
mod trainfig;

pub use fig1::{fig1_reversibility, format_rows as format_fig1, Fig1Row};
pub use gradcheck::{format_rows as format_gradcheck, gradient_consistency, GradCheckRow};
pub use memtable::{format_rows as format_memtable, memory_table, MemoryRow};
pub use sec3::{format_rows as format_sec3, sec3_scalar_studies, MatrixReluRhs, Sec3Row};
pub use trainfig::{train_figure, TrainFigOptions, TrainFigRun};
