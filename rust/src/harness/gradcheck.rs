//! §IV gradient-consistency study: DTO vs OTD vs neural-ODE [8] gradients
//! on the tiny ODE block across a dt (=1/Nt) sweep, with finite differences
//! as ground truth for the DTO gradient.
//!
//! Expected shape (paper): OTD error ~ O(dt) relative to DTO; [8] error does
//! NOT vanish with dt (reconstruction instability); DTO matches finite
//! differences to discretization-free accuracy.

use crate::rng::Rng;
use crate::runtime::{ArtifactRegistry, Result};
use crate::tensor::Tensor;

/// One row of the sweep.
#[derive(Debug, Clone)]
pub struct GradCheckRow {
    pub nt: usize,
    pub dt: f32,
    /// ‖g_OTD − g_DTO‖/‖g_DTO‖ over (z-grad).
    pub otd_rel_err: f32,
    /// ‖g_[8] − g_DTO‖/‖g_DTO‖.
    pub node_rel_err: f32,
    /// [8] reconstruction error ρ(z0_rec, z0).
    pub node_recon_err: f32,
    /// DTO vs central finite differences on a few coordinates.
    pub dto_fd_err: f32,
}

/// Run the sweep over the tiny-block artifacts (`tiny_euler_nt{..}_*`).
pub fn gradient_consistency(reg: &ArtifactRegistry, seed: u64) -> Result<Vec<GradCheckRow>> {
    let nts: Vec<usize> = reg
        .config()
        .get("tiny_nts")
        .and_then(|v| v.as_usize_vec())
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]);

    let mut rng = Rng::new(seed);
    let spec = reg.module_spec("tiny_euler_nt1_fwd")?.clone();
    // Shared inputs across all nt (θ scaled for a well-conditioned block).
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            Tensor::from_vec(s.shape.clone(), rng.normal_vec(n).iter().map(|x| 0.25 * x).collect())
                .unwrap()
        })
        .collect();
    let zshape = spec.inputs[0].shape.clone();
    let g = Tensor::from_vec(zshape.clone(), rng.normal_vec(zshape.iter().product())).unwrap();

    let mut rows = Vec::new();
    for nt in nts {
        let mut vjp_in: Vec<&Tensor> = inputs.iter().collect();
        vjp_in.push(&g);

        let dto = reg.call(&format!("tiny_euler_nt{nt}_vjp"), &vjp_in)?;
        let otd = reg.call(&format!("tiny_euler_nt{nt}_otd"), &vjp_in)?;

        // [8] needs z1 (the block output) as its starting point.
        let fwd_in: Vec<&Tensor> = inputs.iter().collect();
        let z1 = reg.call(&format!("tiny_euler_nt{nt}_fwd"), &fwd_in)?.remove(0);
        let mut node_in: Vec<&Tensor> = vec![&z1];
        node_in.extend(inputs.iter().skip(1));
        node_in.push(&g);
        let node = reg.call(&format!("tiny_euler_nt{nt}_node"), &node_in)?;
        let z0_rec = node.last().unwrap();

        // Finite-difference check of the DTO z-gradient on 3 coordinates
        // of the projection L = <g, z1>.
        let fd_err = {
            let name = format!("tiny_euler_nt{nt}_fwd");
            let proj = |t: &Tensor| -> f64 {
                t.data().iter().zip(g.data()).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
            };
            let eps = 1e-3f32;
            let mut max_rel: f32 = 0.0;
            for &idx in &[3usize, 77, 205] {
                let mut plus = inputs.clone();
                plus[0].data_mut()[idx] += eps;
                let mut minus = inputs.clone();
                minus[0].data_mut()[idx] -= eps;
                let fp = proj(&reg.call(&name, &plus.iter().collect::<Vec<_>>())?[0]);
                let fm = proj(&reg.call(&name, &minus.iter().collect::<Vec<_>>())?[0]);
                let fd = ((fp - fm) / (2.0 * eps as f64)) as f32;
                let ad = dto[0].data()[idx];
                max_rel = max_rel.max((fd - ad).abs() / (1.0 + ad.abs()));
            }
            max_rel
        };

        rows.push(GradCheckRow {
            nt,
            dt: 1.0 / nt as f32,
            otd_rel_err: otd[0].rel_err(&dto[0]).unwrap(),
            node_rel_err: node[0].rel_err(&dto[0]).unwrap(),
            node_recon_err: z0_rec.rel_err(&inputs[0]).unwrap(),
            dto_fd_err: fd_err,
        });
    }
    Ok(rows)
}

/// Harness table format.
pub fn format_rows(rows: &[GradCheckRow]) -> String {
    let mut s = String::from(
        "nt      dt     otd_vs_dto   node_vs_dto   node_recon    dto_vs_fd\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<5} {:>6.3} {:>12.4e} {:>13.4e} {:>12.4e} {:>12.4e}\n",
            r.nt, r.dt, r.otd_rel_err, r.node_rel_err, r.node_recon_err, r.dto_fd_err
        ));
    }
    s
}
