//! §III scalar / linear-system reversibility studies:
//!  (a) dz/dt = λz with λ = -100: forward easy, reverse needs ~2·10⁵ steps;
//!  (b) dz/dt = -max(0, 10 z): the ReLU ODE step-count table
//!      (≈11 steps → 1% error, ≈211 → single precision, per ode45);
//!  (c) dz/dt = max(0, W z), W Gaussian: ‖W‖₂ ~ √n makes reversal
//!      impossible for n ≈ 100; normalizing W fixes it.

use crate::ode::{odeint, odeint_rk45, reversibility_error, FixedSolver, Negated, Rhs, Rk45Options};
use crate::rng::Rng;

/// One study row.
#[derive(Debug, Clone)]
pub struct Sec3Row {
    pub study: &'static str,
    pub param: String,
    pub steps: usize,
    pub rho: f32,
    pub converged: bool,
}

struct ReluScalar {
    gain: f32,
}

impl Rhs for ReluScalar {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        for (o, zi) in out.iter_mut().zip(z) {
            *o = -(self.gain * zi).max(0.0);
        }
    }
    fn dim(&self) -> usize {
        1
    }
}

/// dz/dt = max(0, W z) with a dense random W.
pub struct MatrixReluRhs {
    pub n: usize,
    pub w: Vec<f32>,
}

impl MatrixReluRhs {
    /// Gaussian W with entries ~ N(0, scale²/n^0) — paper's raw init has
    /// ‖W‖₂ ≈ scale·√n; pass `normalize=true` to rescale to unit spectral
    /// norm estimate.
    pub fn random(n: usize, rng: &mut Rng, normalize: bool) -> Self {
        let mut w: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect();
        if normalize {
            // Power iteration for the top singular value.
            let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for _ in 0..50 {
                let mut u = vec![0.0f32; n];
                for i in 0..n {
                    for j in 0..n {
                        u[i] += w[i * n + j] * v[j];
                    }
                }
                let mut vt = vec![0.0f32; n];
                for j in 0..n {
                    for i in 0..n {
                        vt[j] += w[i * n + j] * u[i];
                    }
                }
                let norm = vt.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
                for x in vt.iter_mut() {
                    *x /= norm;
                }
                v = vt;
            }
            let mut u = vec![0.0f32; n];
            for i in 0..n {
                for j in 0..n {
                    u[i] += w[i * n + j] * v[j];
                }
            }
            let sigma = u.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            for x in w.iter_mut() {
                *x /= sigma;
            }
        }
        Self { n, w }
    }

    /// ‖W‖₂ estimate via power iteration (for reporting √n growth).
    pub fn spectral_norm(&self, rng: &mut Rng) -> f32 {
        let n = self.n;
        let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut sigma = 0.0;
        for _ in 0..50 {
            let mut u = vec![0.0f32; n];
            for i in 0..n {
                for j in 0..n {
                    u[i] += self.w[i * n + j] * v[j];
                }
            }
            let un = u.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            sigma = un;
            let mut vt = vec![0.0f32; n];
            for j in 0..n {
                for i in 0..n {
                    vt[j] += self.w[i * n + j] * u[i] / un;
                }
            }
            let vn = vt.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            for x in vt.iter_mut() {
                *x /= vn;
            }
            v = vt;
        }
        sigma
    }
}

impl Rhs for MatrixReluRhs {
    fn eval(&self, z: &[f32], out: &mut [f32]) {
        let n = self.n;
        for i in 0..n {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += self.w[i * n + j] * z[j];
            }
            out[i] = acc.max(0.0);
        }
    }
    fn dim(&self) -> usize {
        self.n
    }
}

/// Round-trip ρ for a fixed-step solve with `nt` steps each way.
fn roundtrip_fixed<R: Rhs>(rhs: &R, z0: &[f32], nt: usize) -> f32 {
    let z1 = odeint(rhs, FixedSolver::Rk4, z0, 1.0, nt);
    let zr = odeint(rhs, FixedSolver::Rk4, &z1, -1.0, nt);
    reversibility_error(z0, &zr)
}

/// Run all §III studies; rows mirror the paper's in-text numbers.
pub fn sec3_scalar_studies(seed: u64) -> Vec<Sec3Row> {
    let mut rows = Vec::new();

    // (a) λ = -100: ρ vs step count; the paper reports ~200k steps for 1%.
    // Double precision, like the paper (e^-100 underflows f32).
    for &nt in &[100usize, 1_000, 10_000, 100_000, 200_000] {
        let lam = -100.0f64;
        let h = 1.0 / nt as f64;
        let mut z = 1.0f64;
        for _ in 0..nt {
            z += h * lam * z; // forward Euler
        }
        for _ in 0..nt {
            z -= h * lam * z; // reverse solve: dz/ds = -λz
        }
        let rho = ((z - 1.0).abs()) as f32;
        rows.push(Sec3Row {
            study: "linear_lambda-100",
            param: format!("euler(f64) nt={nt}"),
            steps: nt,
            rho,
            converged: rho.is_finite(),
        });
    }

    // (b) ReLU ODE dz/dt = -max(0, 10z) with adaptive RK45 at varying tol,
    // reporting accepted steps vs round-trip error (paper: 11 steps → 1%).
    for &(rtol, atol) in &[(1e-2f32, 1e-4f32), (1e-3, 1e-6), (1e-6, 1e-9), (1e-9, 1e-12)] {
        let rhs = ReluScalar { gain: 10.0 };
        let opts = Rk45Options { rtol, atol, max_steps: 100_000, ..Default::default() };
        let f = odeint_rk45(&rhs, &[1.0], 1.0, opts);
        let r = odeint_rk45(&Negated(&rhs), &f.z, 1.0, opts);
        rows.push(Sec3Row {
            study: "relu_scalar_gain10",
            param: format!("rk45 rtol={rtol:.0e}"),
            steps: f.steps + r.steps,
            rho: reversibility_error(&[1.0], &r.z),
            converged: f.converged && r.converged,
        });
    }

    // (c) Gaussian W: raw (‖W‖₂ ≈ √n, irreversible) vs normalized (fine).
    let mut rng = Rng::new(seed);
    for &n in &[16usize, 64, 128] {
        for normalize in [false, true] {
            let rhs = MatrixReluRhs::random(n, &mut rng, normalize);
            let z0: Vec<f32> = (0..n).map(|_| rng.uniform() + 0.1).collect();
            let nt = 2048;
            let rho = roundtrip_fixed(&rhs, &z0, nt);
            rows.push(Sec3Row {
                study: if normalize { "gaussian_W_normalized" } else { "gaussian_W_raw" },
                param: format!("n={n} rk4 nt={nt}"),
                steps: nt,
                rho,
                converged: rho.is_finite(),
            });
        }
    }
    rows
}

/// Harness table format.
pub fn format_rows(rows: &[Sec3Row]) -> String {
    let mut s =
        String::from("study                    param                steps      rho         ok\n");
    for r in rows {
        s.push_str(&format!(
            "{:<24} {:<20} {:>6} {:>12.4e}  {}\n",
            r.study, r.param, r.steps, r.rho, r.converged
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stiff_linear_needs_many_steps() {
        let rows = sec3_scalar_studies(0);
        let lin: Vec<_> = rows.iter().filter(|r| r.study == "linear_lambda-100").collect();
        // Coarse reversal fails badly (ρ ≈ 1 means the recovered state is
        // as wrong as returning zero); ~200k steps gets near the 1% regime.
        assert!(lin.first().unwrap().rho > 0.99 || !lin.first().unwrap().rho.is_finite());
        assert!(lin.last().unwrap().rho < 0.05, "rho {}", lin.last().unwrap().rho);
    }

    #[test]
    fn gaussian_w_normalization_restores_reversibility() {
        let rows = sec3_scalar_studies(1);
        for n in [64, 128] {
            let raw = rows
                .iter()
                .find(|r| r.study == "gaussian_W_raw" && r.param.contains(&format!("n={n} ")))
                .unwrap();
            let norm = rows
                .iter()
                .find(|r| {
                    r.study == "gaussian_W_normalized" && r.param.contains(&format!("n={n} "))
                })
                .unwrap();
            assert!(
                !raw.rho.is_finite() || raw.rho > 10.0 * norm.rho.max(1e-9),
                "n={n}: raw {} vs norm {}",
                raw.rho,
                norm.rho
            );
            assert!(norm.rho < 0.05, "n={n}: normalized rho {}", norm.rho);
        }
    }

    #[test]
    fn spectral_norm_grows_like_sqrt_n() {
        let mut rng = Rng::new(7);
        let s16 = MatrixReluRhs::random(16, &mut rng, false).spectral_norm(&mut rng);
        let s128 = MatrixReluRhs::random(128, &mut rng, false).spectral_norm(&mut rng);
        let ratio = s128 / s16;
        let expect = (128.0f32 / 16.0).sqrt();
        assert!((ratio / expect - 1.0).abs() < 0.5, "ratio {ratio} vs sqrt {expect}");
    }
}
