//! §V memory-footprint table: measured peak activation bytes and recompute
//! counts for store-all / ANODE / ANODE+revolve(m) / ANODE+equispaced(m) /
//! neural-ODE [8], over a grid of (L, Nt). The headline O(L·Nt) →
//! O(L)+O(Nt) claim, measured by the ledger models and schedule costs.

use crate::checkpoint::{min_recomputations, plan, Strategy};
use crate::memory::{human_bytes, model_peak_bytes};

/// One (scheme, L, Nt, m) row.
#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub scheme: String,
    pub l: usize,
    pub nt: usize,
    pub m: usize,
    /// Peak activation bytes (model; act = `act_bytes`).
    pub peak_bytes: usize,
    /// Forward-step evaluations per block backward (recomputation measure;
    /// the forward pass itself always costs Nt per block).
    pub fwd_evals_per_block: usize,
}

/// Generate the table for one activation size.
pub fn memory_table(ls: &[usize], nts: &[usize], ms: &[usize], act_bytes: usize) -> Vec<MemoryRow> {
    let mut rows = Vec::new();
    for &l in ls {
        for &nt in nts {
            rows.push(MemoryRow {
                scheme: "store_all (naive)".into(),
                l,
                nt,
                m: 0,
                peak_bytes: model_peak_bytes("store_all", l, nt, 0, act_bytes),
                fwd_evals_per_block: nt,
            });
            rows.push(MemoryRow {
                scheme: "anode".into(),
                l,
                nt,
                m: 0,
                peak_bytes: model_peak_bytes("anode", l, nt, 0, act_bytes),
                fwd_evals_per_block: nt,
            });
            for &m in ms {
                if m >= nt {
                    continue;
                }
                rows.push(MemoryRow {
                    scheme: format!("anode+revolve({m})"),
                    l,
                    nt,
                    m,
                    peak_bytes: model_peak_bytes("anode_revolve", l, nt, m, act_bytes),
                    fwd_evals_per_block: min_recomputations(nt, m) as usize,
                });
                rows.push(MemoryRow {
                    scheme: format!("anode+equispaced({m})"),
                    l,
                    nt,
                    m,
                    peak_bytes: model_peak_bytes("anode_revolve", l, nt, m, act_bytes),
                    fwd_evals_per_block: plan(Strategy::Equispaced(m), nt).forward_evals(),
                });
            }
            rows.push(MemoryRow {
                scheme: "node [8] (unstable grad)".into(),
                l,
                nt,
                m: 0,
                peak_bytes: model_peak_bytes("node", l, nt, 0, act_bytes),
                // Reverse solve costs ~Nt augmented steps (each ~2 forwards:
                // f and its VJP fused in the augmented RHS).
                fwd_evals_per_block: nt,
            });
        }
    }
    rows
}

/// Harness table format.
pub fn format_rows(rows: &[MemoryRow]) -> String {
    let mut s = String::from(
        "scheme                      L   Nt   m   peak_activation   fwd_evals/block\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<26} {:>3} {:>4} {:>3}   {:>14}   {:>8}\n",
            r.scheme,
            r.l,
            r.nt,
            r.m,
            human_bytes(r.peak_bytes),
            r.fwd_evals_per_block
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_reproduces_complexity_ordering() {
        let rows = memory_table(&[8], &[16], &[2, 4], 1 << 20);
        let get = |name: &str| rows.iter().find(|r| r.scheme.starts_with(name)).unwrap();
        let store = get("store_all");
        let anode = get("anode");
        let rev = get("anode+revolve(2)");
        let node = get("node");
        assert!(store.peak_bytes > anode.peak_bytes);
        assert!(anode.peak_bytes > rev.peak_bytes);
        assert!(rev.peak_bytes > node.peak_bytes);
        // Compute cost ordering is the mirror image.
        assert!(rev.fwd_evals_per_block > anode.fwd_evals_per_block);
        // Revolve beats equispaced at equal m.
        let eq = get("anode+equispaced(2)");
        assert!(rev.fwd_evals_per_block <= eq.fwd_evals_per_block);
    }

    #[test]
    fn anode_memory_is_l_plus_nt() {
        let act = 1000;
        for (l, nt) in [(4, 8), (16, 2), (10, 10)] {
            let rows = memory_table(&[l], &[nt], &[], act);
            let anode = rows.iter().find(|r| r.scheme == "anode").unwrap();
            assert_eq!(anode.peak_bytes, (l + nt) * act);
            let store = rows.iter().find(|r| r.scheme.starts_with("store_all")).unwrap();
            assert_eq!(store.peak_bytes, l * nt * act);
        }
    }
}
