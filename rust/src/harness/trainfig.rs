//! Figs. 3/4/5 runner: train one (arch, solver, method) configuration on
//! synthetic CIFAR-10/100 and return its curve — the paper's training-loss /
//! test-accuracy comparison between ANODE and neural-ODE [8].
//!
//! Built on the [`crate::api`] façade: each run is one `Engine` (sharing
//! the caller's artifact registry and compiled-module cache) driving one
//! `Session::fit`.

use std::sync::Arc;

use crate::api::{Engine, FitOptions, SessionConfig};
use crate::data::{make_eval_batches, Batcher, SyntheticCifar};
use crate::metrics::Curve;
use crate::models::{Arch, GradMethod, Solver};
use crate::optim::LrSchedule;
use crate::runtime::{ArtifactRegistry, Result};

/// Options for one figure run.
#[derive(Debug, Clone)]
pub struct TrainFigOptions {
    pub arch: Arch,
    pub solver: Solver,
    pub method: GradMethod,
    pub num_classes: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub steps: usize,
    pub eval_every: usize,
    pub lr: f32,
    pub seed: u64,
    pub verbose: bool,
    /// Worker threads for the periodic evaluation sweeps (`--workers`).
    pub workers: usize,
    /// Micro-batches accumulated per optimizer step (`--grad-accum`);
    /// 1 is the classic single-batch step.
    pub grad_accum: usize,
    /// Worker threads for the data-parallel gradient path
    /// (`--grad-workers`); parameters/losses are bit-identical for every
    /// count.
    pub grad_workers: usize,
    /// Devices to shard the parallel paths over (`--devices`); one
    /// registry/worker-pool per device, results bit-identical for every
    /// count (rust/DESIGN.md §6d).
    pub devices: usize,
}

impl Default for TrainFigOptions {
    fn default() -> Self {
        Self {
            arch: Arch::Resnet,
            solver: Solver::Euler,
            method: GradMethod::Anode,
            num_classes: 10,
            train_size: 2048,
            test_size: 512,
            steps: 200,
            eval_every: 25,
            lr: 0.02,
            seed: 0,
            verbose: true,
            workers: 1,
            grad_accum: 1,
            grad_workers: 1,
            devices: 1,
        }
    }
}

/// Result: the curve plus run metadata.
pub struct TrainFigRun {
    pub curve: Curve,
    pub diverged: bool,
    pub wall_seconds: f64,
    pub sec_per_step: f64,
    pub peak_activation_bytes: usize,
    pub series: String,
}

/// Train one configuration and return its series. The registry handle is
/// shared so multi-series figures reuse one compiled-module cache (and,
/// being `Arc`, series can run on separate threads).
pub fn train_figure(reg: &Arc<ArtifactRegistry>, o: &TrainFigOptions) -> Result<TrainFigRun> {
    let engine = Engine::builder()
        .registry(reg.clone())
        .arch(o.arch)
        .classes(o.num_classes)
        .solver(o.solver)
        .devices(o.devices.max(1))
        .build()?;
    let batch = engine.config().batch;

    let session_cfg = SessionConfig {
        method: o.method.name(),
        lr: LrSchedule::Step {
            base: o.lr,
            gamma: 0.3,
            milestones: vec![o.steps / 2, o.steps * 4 / 5],
        },
        workers: o.workers,
        grad_accum: o.grad_accum,
        grad_workers: o.grad_workers,
        ..SessionConfig::default()
    };
    let mut session = engine.session(session_cfg)?;

    let ds = SyntheticCifar::new(o.num_classes, o.seed ^ 0xDA7A, 0.12);
    let (train_imgs, train_labels) = ds.generate(o.train_size, o.seed + 1);
    let (test_imgs, test_labels) = ds.generate(o.test_size, o.seed + 2);
    let mut train = Batcher::new(train_imgs, train_labels, batch, true, o.seed + 3)?;
    let eval = make_eval_batches(&test_imgs, &test_labels, batch, o.test_size / batch);

    let series = format!(
        "{}-{}-{}-c{}",
        o.method.name(),
        o.arch.name(),
        o.solver.name(),
        o.num_classes
    );
    let opts = FitOptions {
        steps: o.steps,
        eval_every: o.eval_every,
        verbose: o.verbose,
        ..Default::default()
    };
    let res = session.fit(&mut train, &eval, &opts, &series)?;
    Ok(TrainFigRun {
        diverged: res.diverged,
        wall_seconds: res.wall_seconds,
        sec_per_step: res.sec_per_step,
        peak_activation_bytes: res.peak_activation_bytes,
        curve: res.curve,
        series,
    })
}
