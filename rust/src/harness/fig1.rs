//! Figs. 1 & 7: reversibility of a single-conv residual block on an
//! MNIST-like image, across activations and solvers.
//!
//! Paper setup: one residual block (one 3×3 conv, random Gaussian init,
//! activation ∈ {none, ReLU, leaky ReLU, softplus}); solve the block's ODE
//! forward, then solve the forward problem backwards as [8] proposes; the
//! reconstruction is "completely different than the original image".

use crate::data::render_digit;
use crate::ode::{
    odeint, odeint_rk45, reversibility_error, Activation, FixedSolver, Negated, RevBlock,
    Rk45Options,
};
use crate::rng::Rng;

/// One row: activation × solver → reconstruction error ρ (Eq. 6).
#[derive(Debug, Clone)]
pub struct Fig1Row {
    pub activation: &'static str,
    pub solver: String,
    /// ‖forward output‖ (sanity: the forward solve is fine).
    pub forward_norm: f32,
    /// ρ of the round trip (the paper's instability evidence).
    pub rho: f32,
    /// Adaptive solver convergence flag (false = reverse solve stalled).
    pub reverse_converged: bool,
}

/// Run the Fig. 1 (Euler) and Fig. 7 (RK45) study.
///
/// `kernel_std` controls the Lipschitz constant of the conv (paper: random
/// Gaussian). Returns one row per (activation, solver).
pub fn fig1_reversibility(seed: u64, kernel_std: f32, nt_euler: usize) -> Vec<Fig1Row> {
    let mut rng = Rng::new(seed);
    let h = 28;
    let img = render_digit((seed % 10) as u8, h, h, &mut rng);
    let mut rows = Vec::new();

    for act in Activation::all() {
        let block = RevBlock::random(h, h, act, kernel_std, &mut rng.split(act.name().len() as u64));

        // Euler fixed-step round trip (Fig. 1).
        let z1 = odeint(&block, FixedSolver::Euler, &img, 1.0, nt_euler);
        let zr = odeint(&block, FixedSolver::Euler, &z1, -1.0, nt_euler);
        rows.push(Fig1Row {
            activation: act.name(),
            solver: format!("euler(nt={nt_euler})"),
            forward_norm: l2(&z1),
            rho: reversibility_error(&img, &zr),
            reverse_converged: zr.iter().all(|v| v.is_finite()),
        });

        // Adaptive RK45 round trip (Fig. 7): adaptivity does NOT rescue it.
        // Tolerances are MATLAB ode45 defaults (the paper's solver).
        let opts = Rk45Options { rtol: 1e-3, atol: 1e-6, max_steps: 20_000, ..Default::default() };
        let f = odeint_rk45(&block, &img, 1.0, opts);
        // Reverse: solve dz/ds = -f(z) from z(1).
        let r = odeint_rk45(&Negated(&block), &f.z, 1.0, opts);
        rows.push(Fig1Row {
            activation: act.name(),
            solver: "rk45".into(),
            forward_norm: l2(&f.z),
            rho: reversibility_error(&img, &r.z),
            reverse_converged: r.converged && r.z.iter().all(|v| v.is_finite()),
        });
    }
    rows
}

fn l2(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

/// Render rows as the harness table.
pub fn format_rows(rows: &[Fig1Row]) -> String {
    let mut s = String::from(
        "activation   solver          ||z1||      rho(roundtrip)  reverse_converged\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:<15} {:>9.3} {:>15.4e}  {}\n",
            r.activation, r.solver, r.forward_norm, r.rho, r.reverse_converged
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_gaussian_block_is_irreversible() {
        let rows = fig1_reversibility(3, 3.0, 8);
        assert_eq!(rows.len(), 8); // 4 activations × 2 solvers
        // The paper's claim: significant reconstruction error for the
        // random Gaussian block, for BOTH fixed and adaptive solvers. The
        // fixed-step roundtrip error is O(1); the adaptive solver's error
        // still exceeds its own tolerance (rtol=1e-3) — adaptivity does not
        // restore reversibility (Fig. 7).
        for r in &rows {
            let threshold = if r.solver.starts_with("euler") { 1e-2 } else { 1e-3 };
            assert!(
                r.rho > threshold || !r.reverse_converged,
                "{} {} unexpectedly reversible (rho={})",
                r.activation,
                r.solver,
                r.rho
            );
        }
    }

    #[test]
    fn forward_solve_is_well_behaved() {
        let rows = fig1_reversibility(3, 3.0, 8);
        for r in &rows {
            assert!(r.forward_norm.is_finite() && r.forward_norm > 0.0);
        }
    }

    #[test]
    fn small_lipschitz_block_is_reversible() {
        // §III contrast case: tiny kernel std => reversal works.
        let rows = fig1_reversibility(3, 0.02, 64);
        for r in &rows {
            assert!(r.rho < 1e-3, "{} {}: rho {}", r.activation, r.solver, r.rho);
        }
    }
}
