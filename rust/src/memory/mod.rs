//! Byte-exact activation-memory accounting — the instrument that *proves*
//! the paper's headline claim: ANODE needs O(L) + O(Nt) activation memory
//! versus O(L·Nt) for store-everything backprop, and revolve(m) squeezes
//! the O(Nt) term to O(m) at a recomputation cost.
//!
//! The ledger tracks logical allocations/frees of activation tensors during
//! a training step (the PJRT working set of a single fused call is reported
//! separately as `transient`), maintaining current and peak byte counts.

use std::collections::HashMap;

/// Category of a tracked allocation (for per-category peaks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Block-input activations stored across the forward pass (the O(L) term).
    BlockInput,
    /// Per-time-step states materialized during one block's backward
    /// (the O(Nt) term — tape + checkpoint slots).
    StepState,
    /// Parameters and their gradients.
    Param,
    /// Optimizer state (momentum buffers).
    OptState,
    /// Short-lived working buffers inside a fused executable call.
    Transient,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::BlockInput => "block_input",
            Category::StepState => "step_state",
            Category::Param => "param",
            Category::OptState => "opt_state",
            Category::Transient => "transient",
        }
    }
}

/// One live allocation.
#[derive(Debug, Clone)]
struct Alloc {
    bytes: usize,
    category: Category,
}

/// Activation-memory ledger with current/peak tracking.
///
/// Per-thread by design: each session (and each worker in the parallel
/// predict/evaluate paths) owns its own ledger; worker ledgers are folded
/// into an aggregate afterward with [`MemoryLedger::merge`].
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    /// Identity of the *logical* ledger — fresh per [`MemoryLedger::new`],
    /// shared by clones (a clone is a snapshot of the same meter, not a
    /// new one). [`MemoryLedger::absorb_parallel`] keys its idempotence
    /// bookkeeping on this, so absorbing the same worker twice cannot
    /// double-count its contribution.
    uid: u64,
    live: HashMap<u64, Alloc>,
    next_id: u64,
    current: usize,
    peak: usize,
    peak_by_cat: HashMap<Category, usize>,
    current_by_cat: HashMap<Category, usize>,
    /// Cumulative bytes ever allocated (traffic measure).
    total_allocated: u64,
    /// `free` calls whose handle was not live — double frees or frees of
    /// foreign/merged handles. A nonzero count means the accounting (and
    /// therefore the paper's measured memory claim) is suspect, so it is
    /// surfaced in [`MemoryLedger::summary`] instead of silently dropped.
    unknown_frees: u64,
    /// Per worker-uid `(traffic, unknown_frees)` already folded in by
    /// [`MemoryLedger::absorb_parallel`] — the re-absorb delta base.
    absorbed: HashMap<u64, (u64, u64)>,
}

impl Default for MemoryLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryLedger {
    pub fn new() -> Self {
        // Process-wide uid counter: ledger identity must survive cloning
        // (snapshots share the uid), so it cannot be the address.
        static NEXT_UID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        Self {
            uid: NEXT_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            live: HashMap::new(),
            next_id: 0,
            current: 0,
            peak: 0,
            peak_by_cat: HashMap::new(),
            current_by_cat: HashMap::new(),
            total_allocated: 0,
            unknown_frees: 0,
            absorbed: HashMap::new(),
        }
    }

    /// Record an allocation; returns a handle for [`Self::free`].
    pub fn alloc(&mut self, bytes: usize, category: Category) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.live.insert(id, Alloc { bytes, category });
        self.current += bytes;
        self.total_allocated += bytes as u64;
        *self.current_by_cat.entry(category).or_default() += bytes;
        self.peak = self.peak.max(self.current);
        let cat_cur = self.current_by_cat[&category];
        let cat_peak = self.peak_by_cat.entry(category).or_default();
        *cat_peak = (*cat_peak).max(cat_cur);
        id
    }

    /// Release an allocation. Unknown handles (double frees, stale ids)
    /// are counted in [`MemoryLedger::unknown_frees`] rather than ignored.
    pub fn free(&mut self, id: u64) {
        match self.live.remove(&id) {
            Some(a) => {
                self.current -= a.bytes;
                if let Some(c) = self.current_by_cat.get_mut(&a.category) {
                    *c -= a.bytes;
                }
            }
            None => self.unknown_frees += 1,
        }
    }

    /// Free every live allocation in a category (e.g. all step states when a
    /// block's backward completes).
    pub fn free_category(&mut self, category: Category) {
        let ids: Vec<u64> = self
            .live
            .iter()
            .filter(|(_, a)| a.category == category)
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            self.free(id);
        }
    }

    pub fn current_bytes(&self) -> usize {
        self.current
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    pub fn peak_of(&self, category: Category) -> usize {
        self.peak_by_cat.get(&category).copied().unwrap_or(0)
    }

    pub fn current_of(&self, category: Category) -> usize {
        self.current_by_cat.get(&category).copied().unwrap_or(0)
    }

    pub fn total_traffic(&self) -> u64 {
        self.total_allocated
    }

    /// Count of `free` calls whose handle was not live (double/unknown
    /// frees). Zero in a correct run.
    pub fn unknown_frees(&self) -> u64 {
        self.unknown_frees
    }

    /// Fold another ledger's *statistics* into this one — the aggregation
    /// step after a parallel worker fan-out, where each worker metered its
    /// own ledger.
    ///
    /// Semantics (documented in rust/DESIGN.md "Concurrency model"):
    /// - `total_traffic` and `unknown_frees` are additive;
    /// - `current` and the peaks are **summed**, because the workers ran
    ///   concurrently: the sum of per-worker peaks is the upper bound on
    ///   the aggregate working set (per-worker peaks stay available on the
    ///   workers' own ledgers for the O(L)+O(Nt) per-worker claim);
    /// - live allocation *handles* are not transferred — ids are
    ///   per-ledger, so freeing `other`'s allocations through `self` would
    ///   miscount. The merged ledger is a stats aggregate, not an arena.
    pub fn merge(&mut self, other: &MemoryLedger) {
        self.current += other.current;
        self.peak += other.peak;
        for (cat, bytes) in &other.peak_by_cat {
            *self.peak_by_cat.entry(*cat).or_default() += *bytes;
        }
        for (cat, bytes) in &other.current_by_cat {
            *self.current_by_cat.entry(*cat).or_default() += *bytes;
        }
        self.total_allocated += other.total_allocated;
        self.unknown_frees += other.unknown_frees;
    }

    /// Absorb one *parallel phase* (e.g. a data-parallel training step's
    /// worker ledgers) into this long-lived ledger.
    ///
    /// Unlike [`MemoryLedger::merge`] — which sums peaks and is meant for
    /// one-shot fan-out reports — this models repeated phases against a
    /// ledger that outlives them: the phase's aggregate working set is
    /// this ledger's *live* bytes (params, optimizer state) plus the
    /// concurrent **sum** of the worker peaks, and the all-time peak is
    /// the **max** over phases of that candidate, not a sum over steps.
    /// Traffic and `unknown_frees` stay additive, so a multi-step parallel
    /// training run still accounts exactly the serial run's traffic.
    ///
    /// The worker ledgers passed here must share **one memory space**
    /// (threads of one device): summing their peaks is what makes the
    /// candidate an upper bound on that space's working set. For the
    /// nested multi-device case — per-device ledgers that are themselves
    /// folds of per-worker ledgers — use [`MemoryLedger::absorb_sharded`]:
    /// devices own *separate* memories, so the cross-device candidate is
    /// the **max over devices**, not their sum (regression-pinned in the
    /// tests below).
    /// Absorb is **idempotent per worker**: each worker ledger is keyed by
    /// its identity (`uid`, shared by clones), and one that was already
    /// absorbed — earlier in the same round via a duplicate slice entry,
    /// or in a previous round without new activity since — contributes
    /// nothing again. A re-absorbed worker that *did* run more work since
    /// (its traffic grew) re-enters the concurrent sum with its current
    /// peak and adds only its traffic/anomaly delta, so stale round-N
    /// peaks are never double-counted into round N+1's candidate.
    pub fn absorb_parallel(&mut self, workers: &[MemoryLedger]) {
        // Dedupe by identity within the round, then drop workers with no
        // activity beyond what an earlier absorb already folded in.
        let mut seen = std::collections::HashSet::new();
        let contributing: Vec<&MemoryLedger> = workers
            .iter()
            .filter(|w| seen.insert(w.uid))
            .filter(|w| match self.absorbed.get(&w.uid) {
                Some(&(traffic, frees)) => {
                    w.total_allocated > traffic || w.unknown_frees > frees
                }
                None => true,
            })
            .collect();
        let phase_peak: usize = contributing.iter().map(|w| w.peak).sum();
        self.peak = self.peak.max(self.current + phase_peak);
        let cats: std::collections::HashSet<Category> =
            contributing.iter().flat_map(|w| w.peak_by_cat.keys().copied()).collect();
        for cat in cats {
            let phase_cat: usize = contributing.iter().map(|w| w.peak_of(cat)).sum();
            let candidate = self.current_of(cat) + phase_cat;
            let cat_peak = self.peak_by_cat.entry(cat).or_default();
            *cat_peak = (*cat_peak).max(candidate);
        }
        for w in contributing {
            let (traffic, frees) = self.absorbed.get(&w.uid).copied().unwrap_or((0, 0));
            self.total_allocated += w.total_allocated.saturating_sub(traffic);
            self.unknown_frees += w.unknown_frees.saturating_sub(frees);
            self.absorbed.insert(w.uid, (w.total_allocated, w.unknown_frees));
        }
    }

    /// Absorb one **sharded** phase: per-device ledgers — each itself a
    /// fold of that device's concurrent workers ([`MemoryLedger::merge`],
    /// peaks summed within the device) — into this long-lived ledger.
    ///
    /// Devices own separate memory spaces, so the binding constraint for
    /// "does the step fit" is the **worst single device**: the phase
    /// candidate is this ledger's live bytes plus the **max over device
    /// peaks** (per category too), and the all-time peak is the max over
    /// phases of that candidate — *never* a sum across devices or steps.
    /// Traffic and `unknown_frees` stay additive across every device, so
    /// total traffic still equals the serial run over the same work.
    ///
    /// With a single device this is exactly [`MemoryLedger::absorb_parallel`]
    /// applied to that device's fold.
    pub fn absorb_sharded(&mut self, devices: &[MemoryLedger]) {
        let phase_peak: usize = devices.iter().map(|d| d.peak).max().unwrap_or(0);
        self.peak = self.peak.max(self.current + phase_peak);
        let cats: std::collections::HashSet<Category> =
            devices.iter().flat_map(|d| d.peak_by_cat.keys().copied()).collect();
        for cat in cats {
            let phase_cat: usize = devices.iter().map(|d| d.peak_of(cat)).max().unwrap_or(0);
            let candidate = self.current_of(cat) + phase_cat;
            let cat_peak = self.peak_by_cat.entry(cat).or_default();
            *cat_peak = (*cat_peak).max(candidate);
        }
        for d in devices {
            self.total_allocated += d.total_allocated;
            self.unknown_frees += d.unknown_frees;
        }
    }

    /// Reset peaks (keep live allocations) — used between measurement phases.
    pub fn reset_peaks(&mut self) {
        self.peak = self.current;
        self.peak_by_cat = self.current_by_cat.clone();
    }

    /// Human-readable summary line. Accounting anomalies (double/unknown
    /// frees) are appended so they cannot pass unnoticed in logs.
    pub fn summary(&self) -> String {
        let mut cats: Vec<_> = self.peak_by_cat.iter().collect();
        cats.sort_by_key(|(c, _)| c.name());
        let per = cats
            .iter()
            .map(|(c, b)| format!("{}={}", c.name(), human_bytes(**b)))
            .collect::<Vec<_>>()
            .join(" ");
        let mut line = format!("peak={} ({per})", human_bytes(self.peak));
        if self.unknown_frees > 0 {
            line.push_str(&format!(" unknown_frees={}", self.unknown_frees));
        }
        line
    }
}

/// Format bytes human-readably.
pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2}KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

/// Closed-form activation-memory model for the §V comparison table:
/// bytes needed per gradient computation over L ODE blocks of Nt steps with
/// activation size `act_bytes`, under each scheme.
pub fn model_peak_bytes(scheme: &str, l: usize, nt: usize, m: usize, act_bytes: usize) -> usize {
    match scheme {
        // Naive backprop through all blocks and steps.
        "store_all" => l * nt * act_bytes,
        // ANODE: block inputs (L) + one block's trajectory (Nt).
        "anode" => (l + nt) * act_bytes,
        // ANODE + revolve(m) inside the block: block inputs + m slots + tape 1.
        "anode_revolve" => (l + m + 1) * act_bytes,
        // Neural-ODE [8]: only the final state per block; backward
        // reconstructs (no storage, but wrong/unstable gradients — §III).
        "node" => l * act_bytes,
        _ => panic!("unknown scheme {scheme}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut led = MemoryLedger::new();
        let a = led.alloc(100, Category::BlockInput);
        let b = led.alloc(50, Category::StepState);
        assert_eq!(led.current_bytes(), 150);
        led.free(a);
        assert_eq!(led.current_bytes(), 50);
        led.free(b);
        assert_eq!(led.current_bytes(), 0);
        assert_eq!(led.peak_bytes(), 150);
        assert_eq!(led.total_traffic(), 150);
    }

    #[test]
    fn per_category_peaks() {
        let mut led = MemoryLedger::new();
        let ids: Vec<u64> = (0..5).map(|_| led.alloc(10, Category::StepState)).collect();
        assert_eq!(led.peak_of(Category::StepState), 50);
        for id in ids {
            led.free(id);
        }
        led.alloc(20, Category::BlockInput);
        assert_eq!(led.peak_of(Category::StepState), 50);
        assert_eq!(led.peak_of(Category::BlockInput), 20);
        assert_eq!(led.peak_bytes(), 50);
    }

    #[test]
    fn free_category_clears_only_that_category() {
        let mut led = MemoryLedger::new();
        led.alloc(10, Category::StepState);
        led.alloc(10, Category::StepState);
        let keep = led.alloc(7, Category::BlockInput);
        led.free_category(Category::StepState);
        assert_eq!(led.current_bytes(), 7);
        led.free(keep);
        assert_eq!(led.current_bytes(), 0);
    }

    #[test]
    fn double_free_keeps_counts_but_is_surfaced() {
        let mut led = MemoryLedger::new();
        let a = led.alloc(10, Category::Param);
        led.free(a);
        assert_eq!(led.unknown_frees(), 0);
        led.free(a); // double free
        led.free(9999); // never-allocated handle
        assert_eq!(led.current_bytes(), 0);
        assert_eq!(led.unknown_frees(), 2);
        assert!(led.summary().contains("unknown_frees=2"), "{}", led.summary());
        // A clean ledger keeps its summary free of the anomaly marker.
        let clean = MemoryLedger::new();
        assert!(!clean.summary().contains("unknown_frees"), "{}", clean.summary());
    }

    #[test]
    fn merge_adds_traffic_and_sums_concurrent_peaks() {
        let mut a = MemoryLedger::new();
        let ia = a.alloc(100, Category::BlockInput);
        a.free(ia);
        let mut b = MemoryLedger::new();
        let ib = b.alloc(40, Category::StepState);
        b.free(ib);
        b.free(ib); // one anomaly on worker b

        let mut agg = MemoryLedger::new();
        agg.merge(&a);
        agg.merge(&b);
        // Traffic is additive and matches what one serial ledger would see.
        assert_eq!(agg.total_traffic(), 140);
        // Concurrent workers: aggregate peak is the sum of worker peaks.
        assert_eq!(agg.peak_bytes(), 140);
        assert_eq!(agg.peak_of(Category::BlockInput), 100);
        assert_eq!(agg.peak_of(Category::StepState), 40);
        assert_eq!(agg.current_bytes(), 0);
        assert_eq!(agg.unknown_frees(), 1);
    }

    #[test]
    fn absorb_parallel_maxes_phases_and_adds_traffic() {
        // A long-lived session ledger holding 100B of params.
        let mut session = MemoryLedger::new();
        session.alloc(100, Category::Param);

        // Phase 1: two workers peaking at 40B + 60B of step state.
        let worker = |bytes: usize| {
            let mut w = MemoryLedger::new();
            let id = w.alloc(bytes, Category::StepState);
            w.free(id);
            w
        };
        session.absorb_parallel(&[worker(40), worker(60)]);
        assert_eq!(session.peak_bytes(), 200, "live 100 + concurrent 40+60");
        assert_eq!(session.peak_of(Category::StepState), 100);
        assert_eq!(session.total_traffic(), 200);

        // Phase 2 is smaller: the all-time peak must NOT grow (max over
        // phases, not a sum over steps) while traffic keeps adding.
        session.absorb_parallel(&[worker(30)]);
        assert_eq!(session.peak_bytes(), 200);
        assert_eq!(session.peak_of(Category::StepState), 100);
        assert_eq!(session.total_traffic(), 230);

        // Phase 3 is larger: the peak moves up to the new candidate.
        session.absorb_parallel(&[worker(80), worker(80)]);
        assert_eq!(session.peak_bytes(), 260);
        assert_eq!(session.peak_of(Category::StepState), 160);
        assert_eq!(session.total_traffic(), 390);
        assert_eq!(session.unknown_frees(), 0);
    }

    #[test]
    fn absorb_parallel_is_idempotent_per_worker() {
        // Regression: absorbing one worker ledger twice — a duplicate
        // slice entry in one round, or the same (unchanged) worker again
        // in a later round — used to double-count its concurrent-peak
        // term and its traffic. Identity is the ledger uid, which clones
        // share (a clone is a snapshot of the same meter).
        let worker = |bytes: usize| {
            let mut w = MemoryLedger::new();
            let id = w.alloc(bytes, Category::StepState);
            w.free(id);
            w
        };
        let w = worker(40);
        let mut session = MemoryLedger::new();
        session.alloc(100, Category::Param);

        // Duplicate entry within one round counts once.
        session.absorb_parallel(&[w.clone(), w.clone()]);
        assert_eq!(session.peak_bytes(), 140, "duplicate entry must not double the peak");
        assert_eq!(session.peak_of(Category::StepState), 40);
        assert_eq!(session.total_traffic(), 140);

        // Re-absorbing the unchanged worker in a later round is a no-op.
        session.absorb_parallel(std::slice::from_ref(&w));
        assert_eq!(session.peak_bytes(), 140, "unchanged re-absorb must be a no-op");
        assert_eq!(session.total_traffic(), 140);

        // Once the worker runs more work, a re-absorb counts its current
        // peak in the new round's candidate and adds only the delta of
        // its traffic — never the already-folded prefix again.
        let mut grown = w.clone();
        let id = grown.alloc(60, Category::StepState);
        grown.free(id);
        session.absorb_parallel(std::slice::from_ref(&grown));
        assert_eq!(session.peak_bytes(), 160, "live 100 + grown worker peak 60");
        assert_eq!(session.peak_of(Category::StepState), 60);
        assert_eq!(session.total_traffic(), 200, "only the 60B delta adds");

        // Fresh workers are untouched by the bookkeeping.
        session.absorb_parallel(&[worker(80)]);
        assert_eq!(session.peak_bytes(), 180);
        assert_eq!(session.total_traffic(), 280);
        assert_eq!(session.unknown_frees(), 0);
    }

    #[test]
    fn absorb_sharded_pins_max_over_devices_not_sum() {
        // Regression for the nested fold: per-DEVICE ledgers (each a merge
        // of that device's concurrent workers, peaks summed within the
        // device) must combine across devices by MAX — separate memory
        // spaces — while traffic stays additive.
        let worker = |bytes: usize| {
            let mut w = MemoryLedger::new();
            let id = w.alloc(bytes, Category::StepState);
            w.free(id);
            w
        };
        // Device 0: workers peaking 40 + 60 -> device peak 100 (sum: one
        // memory). Device 1: one worker peaking 30 -> device peak 30.
        let mut dev0 = MemoryLedger::new();
        dev0.merge(&worker(40));
        dev0.merge(&worker(60));
        let mut dev1 = MemoryLedger::new();
        dev1.merge(&worker(30));
        assert_eq!(dev0.peak_bytes(), 100);
        assert_eq!(dev1.peak_bytes(), 30);

        let mut session = MemoryLedger::new();
        session.alloc(7, Category::Param);
        session.absorb_sharded(&[dev0.clone(), dev1.clone()]);
        // Max over devices (100), NOT the cross-device sum (130).
        assert_eq!(session.peak_bytes(), 107, "cross-device fold must take the max");
        assert_eq!(session.peak_of(Category::StepState), 100);
        // Traffic is additive across every device and worker (7 of the
        // session's own params + 100 + 30 from the phase).
        assert_eq!(session.total_traffic(), 137);

        // A smaller later phase must not move the all-time peak (max over
        // phases), while traffic keeps adding.
        session.absorb_sharded(&[dev1.clone()]);
        assert_eq!(session.peak_bytes(), 107);
        assert_eq!(session.total_traffic(), 167);

        // Single-device fold degenerates to absorb_parallel of that fold.
        let mut a = MemoryLedger::new();
        a.alloc(7, Category::Param);
        a.absorb_sharded(std::slice::from_ref(&dev0));
        let mut b = MemoryLedger::new();
        b.alloc(7, Category::Param);
        b.absorb_parallel(std::slice::from_ref(&dev0));
        assert_eq!(a.peak_bytes(), b.peak_bytes());
        assert_eq!(a.total_traffic(), b.total_traffic());
    }

    #[test]
    fn model_matches_paper_complexity() {
        let act = 1 << 20; // 1 MiB activation
        let (l, nt) = (8, 16);
        let store_all = model_peak_bytes("store_all", l, nt, 0, act);
        let anode = model_peak_bytes("anode", l, nt, 0, act);
        let revolve = model_peak_bytes("anode_revolve", l, nt, 4, act);
        let node = model_peak_bytes("node", l, nt, 0, act);
        // O(L·Nt) vs O(L)+O(Nt) vs O(L)+O(m) vs O(L).
        assert_eq!(store_all, 128 * act);
        assert_eq!(anode, 24 * act);
        assert_eq!(revolve, 13 * act);
        assert_eq!(node, 8 * act);
        assert!(store_all > anode && anode > revolve && revolve > node);
    }

    #[test]
    fn human_bytes_formatting() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KiB");
        assert_eq!(human_bytes(3 << 20), "3.00MiB");
    }

    #[test]
    fn reset_peaks_keeps_live() {
        let mut led = MemoryLedger::new();
        let _a = led.alloc(100, Category::Param);
        let b = led.alloc(200, Category::StepState);
        led.free(b);
        assert_eq!(led.peak_bytes(), 300);
        led.reset_peaks();
        assert_eq!(led.peak_bytes(), 100);
    }
}
