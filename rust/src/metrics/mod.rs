//! Training metrics: loss/accuracy accumulators and CSV/JSON series
//! writers used by the figure-regeneration harnesses.

use std::io::Write;
use std::path::Path;

/// Streaming mean accumulator.
#[derive(Debug, Default, Clone)]
pub struct Mean {
    sum: f64,
    n: usize,
}

impl Mean {
    pub fn add(&mut self, v: f32) {
        if v.is_finite() {
            self.sum += v as f64;
        } else {
            self.sum = f64::NAN;
        }
        self.n += 1;
    }

    pub fn value(&self) -> f32 {
        if self.n == 0 {
            0.0
        } else {
            (self.sum / self.n as f64) as f32
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// One recorded point of a training/eval curve.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    pub step: usize,
    pub epoch: f32,
    pub train_loss: f32,
    pub test_loss: f32,
    pub test_acc: f32,
}

/// A named series of curve points (one per method/solver combination —
/// i.e. one line of a paper figure).
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Did the run diverge (NaN/inf loss anywhere)?
    pub fn diverged(&self) -> bool {
        self.points.iter().any(|p| !p.train_loss.is_finite())
    }

    /// Final test accuracy (0 if empty).
    pub fn final_acc(&self) -> f32 {
        self.points.last().map(|p| p.test_acc).unwrap_or(0.0)
    }

    /// Best test accuracy seen.
    pub fn best_acc(&self) -> f32 {
        self.points.iter().map(|p| p.test_acc).fold(0.0, f32::max)
    }
}

/// Write curves to CSV: name,step,epoch,train_loss,test_loss,test_acc.
pub fn write_csv(path: &Path, curves: &[Curve]) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "name,step,epoch,train_loss,test_loss,test_acc")?;
    for c in curves {
        for p in &c.points {
            writeln!(
                f,
                "{},{},{:.3},{:.6},{:.6},{:.4}",
                c.name, p.step, p.epoch, p.train_loss, p.test_loss, p.test_acc
            )?;
        }
    }
    Ok(())
}

/// Render curves as a compact fixed-width table (the harness output format).
pub fn format_table(curves: &[Curve]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>6} {:>7} {:>12} {:>12} {:>9}\n",
        "series", "step", "epoch", "train_loss", "test_loss", "test_acc"
    ));
    for c in curves {
        for p in &c.points {
            out.push_str(&format!(
                "{:<28} {:>6} {:>7.2} {:>12.4} {:>12.4} {:>8.2}%\n",
                c.name,
                p.step,
                p.epoch,
                p.train_loss,
                p.test_loss,
                p.test_acc * 100.0
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_accumulates() {
        let mut m = Mean::default();
        m.add(1.0);
        m.add(2.0);
        m.add(3.0);
        assert_eq!(m.value(), 2.0);
        assert_eq!(m.count(), 3);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn mean_propagates_nan() {
        let mut m = Mean::default();
        m.add(1.0);
        m.add(f32::NAN);
        assert!(m.value().is_nan());
    }

    #[test]
    fn curve_divergence_detection() {
        let mut c = Curve::new("node-rk45");
        c.push(CurvePoint { step: 0, epoch: 0.0, train_loss: 2.3, test_loss: 2.3, test_acc: 0.1 });
        assert!(!c.diverged());
        c.push(CurvePoint {
            step: 1,
            epoch: 0.1,
            train_loss: f32::NAN,
            test_loss: f32::NAN,
            test_acc: 0.1,
        });
        assert!(c.diverged());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join("anode_metrics_test");
        let path = dir.join("curves.csv");
        let mut c = Curve::new("anode");
        c.push(CurvePoint { step: 5, epoch: 0.5, train_loss: 1.0, test_loss: 1.1, test_acc: 0.5 });
        write_csv(&path, &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,step"));
        assert!(text.contains("anode,5,0.500"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_formatting() {
        let mut c = Curve::new("anode-euler");
        c.push(CurvePoint { step: 1, epoch: 0.1, train_loss: 2.0, test_loss: 2.1, test_acc: 0.25 });
        let t = format_table(&[c]);
        assert!(t.contains("anode-euler"));
        assert!(t.contains("25.00%"));
    }

    #[test]
    fn best_and_final_acc() {
        let mut c = Curve::new("x");
        for (i, acc) in [0.2f32, 0.5, 0.4].iter().enumerate() {
            c.push(CurvePoint {
                step: i,
                epoch: 0.0,
                train_loss: 1.0,
                test_loss: 1.0,
                test_acc: *acc,
            });
        }
        assert_eq!(c.best_acc(), 0.5);
        assert_eq!(c.final_acc(), 0.4);
    }
}
