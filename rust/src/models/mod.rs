//! Model definitions: the ODE-network families of the paper's experiments
//! (ResNet-18-like and SqueezeNext-like with non-transition blocks replaced
//! by ODE blocks), expressed as *structure over AOT artifacts* — the actual
//! compute graphs live in python/compile/model.py and arrive as HLO.

use crate::runtime::{ArtifactRegistry, ParamSpec, RuntimeError};
use crate::tensor::Tensor;

/// Architecture family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Resnet,
    Sqnxt,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Resnet => "resnet",
            Arch::Sqnxt => "sqnxt",
        }
    }

    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "resnet" => Some(Arch::Resnet),
            "sqnxt" => Some(Arch::Sqnxt),
            _ => None,
        }
    }
}

/// ODE solver baked into the block artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Solver {
    Euler,
    Rk2,
    Rk45,
}

impl Solver {
    pub fn name(&self) -> &'static str {
        match self {
            Solver::Euler => "euler",
            Solver::Rk2 => "rk2",
            Solver::Rk45 => "rk45",
        }
    }

    pub fn parse(s: &str) -> Option<Solver> {
        match s {
            "euler" => Some(Solver::Euler),
            "rk2" => Some(Solver::Rk2),
            "rk45" => Some(Solver::Rk45),
            _ => None,
        }
    }
}

/// Gradient method — the experimental axis of Figs. 3-5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMethod {
    /// ANODE (the paper): checkpoint block inputs, DTO backward per block.
    Anode,
    /// ANODE + revolve(m) within each block (step-level artifacts).
    AnodeRevolve(usize),
    /// ANODE + equispaced(m) checkpoints within each block.
    AnodeEquispaced(usize),
    /// Neural-ODE [8]: reverse-time augmented solve, reconstructing z(t).
    Node,
    /// Optimize-then-discretize adjoint with stored trajectory (§IV).
    Otd,
    /// Symplectic adjoint (Matsubara et al., 2021): exact gradients from
    /// the paired integrator over the stored boundary trajectory.
    Symplectic,
    /// Interpolated adjoint (Daulbaev et al., 2020): store p trajectory
    /// nodes per block, reconstruct step inputs by barycentric
    /// interpolation in the backward sweep.
    InterpAdjoint(usize),
}

impl GradMethod {
    pub fn name(&self) -> String {
        match self {
            GradMethod::Anode => "anode".into(),
            GradMethod::AnodeRevolve(m) => format!("anode-revolve{m}"),
            GradMethod::AnodeEquispaced(m) => format!("anode-equispaced{m}"),
            GradMethod::Node => "node".into(),
            GradMethod::Otd => "otd".into(),
            GradMethod::Symplectic => "symplectic".into(),
            GradMethod::InterpAdjoint(p) => format!("interp-adjoint{p}"),
        }
    }

    /// Parse a method spec. Checkpointed variants validate their budget:
    /// `anode-revolve0` is rejected (a zero-slot schedule cannot hold the
    /// block input), matching the constructors in
    /// [`crate::api::strategy::CheckpointedStrategy`].
    pub fn parse(s: &str) -> Option<GradMethod> {
        if s == "anode" {
            return Some(GradMethod::Anode);
        }
        if s == "node" {
            return Some(GradMethod::Node);
        }
        if s == "otd" {
            return Some(GradMethod::Otd);
        }
        if s == "symplectic" {
            return Some(GradMethod::Symplectic);
        }
        // Budget syntax + validation live in parse_budget (shared with the
        // api strategy registry); a Some(Err) — pattern matched, malformed
        // or degenerate budget — parses to None.
        if let Some(m) = parse_budget(s, "anode-revolve") {
            return m.ok().map(GradMethod::AnodeRevolve);
        }
        if let Some(m) = parse_budget(s, "anode-equispaced") {
            return m.ok().map(GradMethod::AnodeEquispaced);
        }
        if let Some(p) = parse_budget(s, "interp-adjoint") {
            // Interpolation needs both endpoints: p >= 2 nodes.
            return p.ok().filter(|&p| p >= 2).map(GradMethod::InterpAdjoint);
        }
        None
    }
}

/// Parse `"<prefix><m>"` checkpoint-budget specs. `None` if `spec` is not
/// this pattern (no budget digits at all after the prefix); `Some(Err)`
/// if it is but the budget is degenerate (m < 1), malformed (garbage
/// before/after the digits, e.g. `anode-revolve:4x`), or out of range.
/// The single source of truth for budget syntax — both
/// [`GradMethod::parse`] and the `api::strategy` registry delegate here.
pub(crate) fn parse_budget(
    spec: &str,
    prefix: &str,
) -> Option<Result<usize, RuntimeError>> {
    let rest = spec.strip_prefix(prefix)?;
    if rest.is_empty() || !rest.bytes().any(|b| b.is_ascii_digit()) {
        return None;
    }
    // Digits only: `usize::from_str` would accept a leading '+', breaking
    // the spec-name round-trip ("anode-revolve+3" -> "anode-revolve3");
    // and trailing garbage after a valid budget ("4x", ":4") must fail
    // with the same typed error as a degenerate budget rather than be
    // silently dropped (or fall through to an unknown-spec path).
    if !rest.bytes().all(|b| b.is_ascii_digit()) {
        return Some(Err(RuntimeError::Io(format!(
            "{prefix}{rest}: malformed checkpoint budget (want {prefix}<m> with m >= 1)"
        ))));
    }
    match rest.parse::<usize>() {
        Ok(m) if m >= 1 => Some(Ok(m)),
        Ok(m) => Some(Err(RuntimeError::Io(format!(
            "{prefix}{m}: checkpoint budget must be >= 1 slot"
        )))),
        Err(_) => Some(Err(RuntimeError::Io(format!(
            "{prefix}{rest}: checkpoint budget out of range"
        )))),
    }
}

/// Model shape parameters (mirrors python/compile/configs.py; values are
/// read from the artifact manifest so the two sides cannot drift).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub arch: Arch,
    pub num_classes: usize,
    pub batch: usize,
    pub image: usize,
    pub channels: Vec<usize>,
    pub blocks_per_stage: usize,
    pub nt: usize,
}

impl ModelConfig {
    /// Read shape info from the manifest config section.
    pub fn from_registry(
        reg: &ArtifactRegistry,
        arch: Arch,
        num_classes: usize,
    ) -> Result<Self, RuntimeError> {
        let get = |k: &str| {
            reg.config_u64(k)
                .map(|v| v as usize)
                .ok_or_else(|| RuntimeError::Io(format!("manifest config missing {k}")))
        };
        let channels = reg
            .config()
            .get("channels")
            .and_then(|v| v.as_usize_vec())
            .ok_or_else(|| RuntimeError::Io("manifest config missing channels".into()))?;
        Ok(Self {
            arch,
            num_classes,
            batch: get("batch")?,
            image: get("image")?,
            channels,
            blocks_per_stage: get("blocks_per_stage")?,
            nt: get("nt")?,
        })
    }

    pub fn stages(&self) -> usize {
        self.channels.len()
    }

    /// Total ODE blocks L.
    pub fn num_ode_blocks(&self) -> usize {
        self.stages() * self.blocks_per_stage
    }

    /// Spatial side at stage s.
    pub fn stage_hw(&self, s: usize) -> usize {
        self.image >> s
    }

    /// Activation shape entering stage `s`.
    pub fn stage_act_shape(&self, s: usize) -> Vec<usize> {
        vec![self.batch, self.stage_hw(s), self.stage_hw(s), self.channels[s]]
    }

    /// Bytes of one stage-s activation (f32).
    pub fn stage_act_bytes(&self, s: usize) -> usize {
        self.stage_act_shape(s).iter().product::<usize>() * 4
    }

    /// Peak bytes of the single rolling activation held by an inference
    /// forward — the largest stage activation. The memory model shared by
    /// `Session::predict` and `Session::predict_batches`.
    pub fn rolling_act_bytes(&self) -> usize {
        (0..self.stages()).map(|s| self.stage_act_bytes(s)).max().unwrap_or(0)
    }

    /// Artifact name of a block module for this config.
    pub fn block_module(&self, stage: usize, solver: Solver, kind: &str) -> String {
        format!("block_{}_s{}_{}_{}", self.arch.name(), stage, solver.name(), kind)
    }

    /// Key into the manifest params index.
    pub fn params_key(&self) -> String {
        format!("{}{}", self.arch.name(), self.num_classes)
    }
}

/// Index of the flat canonical parameter vector by model structure.
///
/// The canonical order (matching configs.model_param_layout and params.bin):
/// stem, stage0 blocks, trans0, stage1 blocks, trans1, ..., head.
#[derive(Debug, Clone)]
pub struct ParamIndex {
    /// (w, b) indices of the stem conv.
    pub stem: (usize, usize),
    /// blocks[s][b] = ordered parameter indices of that ODE block.
    pub blocks: Vec<Vec<Vec<usize>>>,
    /// trans[s] = (w, b) indices of the transition after stage s.
    pub trans: Vec<(usize, usize)>,
    /// (w, b) indices of the classifier head.
    pub head: (usize, usize),
    /// Total parameter tensors.
    pub len: usize,
}

impl ParamIndex {
    /// Build from the manifest's named layout.
    pub fn from_layout(layout: &[ParamSpec], cfg: &ModelConfig) -> Result<Self, RuntimeError> {
        let find = |name: &str| -> Result<usize, RuntimeError> {
            layout
                .iter()
                .position(|p| p.name == name)
                .ok_or_else(|| RuntimeError::Io(format!("param {name} not in layout")))
        };
        let stem = (find("stem.w")?, find("stem.b")?);
        let mut blocks = Vec::new();
        for s in 0..cfg.stages() {
            let mut stage_blocks = Vec::new();
            for b in 0..cfg.blocks_per_stage {
                let prefix = format!("s{s}.b{b}.");
                let mut idxs: Vec<usize> = layout
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.name.starts_with(&prefix))
                    .map(|(i, _)| i)
                    .collect();
                idxs.sort(); // layout order is canonical execution order
                if idxs.is_empty() {
                    return Err(RuntimeError::Io(format!("no params for block {prefix}")));
                }
                stage_blocks.push(idxs);
            }
            blocks.push(stage_blocks);
        }
        let mut trans = Vec::new();
        for s in 0..cfg.stages() - 1 {
            trans.push((find(&format!("trans{s}.w"))?, find(&format!("trans{s}.b"))?));
        }
        let head = (find("head.w")?, find("head.b")?);
        Ok(Self { stem, blocks, trans, head, len: layout.len() })
    }

    /// Zero-filled gradient tensors matching `params`.
    pub fn zero_grads(params: &[Tensor]) -> Vec<Tensor> {
        params.iter().map(|p| Tensor::zeros(p.shape())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_layout(cfg: &ModelConfig) -> Vec<ParamSpec> {
        // Mirror configs.model_param_layout for a resnet config.
        let mut v = vec![
            ParamSpec { name: "stem.w".into(), shape: vec![3, 3, 3, 16], offset: 0 },
            ParamSpec { name: "stem.b".into(), shape: vec![16], offset: 0 },
        ];
        for s in 0..cfg.stages() {
            for b in 0..cfg.blocks_per_stage {
                for leaf in ["w1", "b1", "w2", "b2"] {
                    v.push(ParamSpec {
                        name: format!("s{s}.b{b}.{leaf}"),
                        shape: vec![1],
                        offset: 0,
                    });
                }
            }
            if s + 1 < cfg.stages() {
                v.push(ParamSpec { name: format!("trans{s}.w"), shape: vec![1], offset: 0 });
                v.push(ParamSpec { name: format!("trans{s}.b"), shape: vec![1], offset: 0 });
            }
        }
        v.push(ParamSpec { name: "head.w".into(), shape: vec![64, 10], offset: 0 });
        v.push(ParamSpec { name: "head.b".into(), shape: vec![10], offset: 0 });
        v
    }

    fn cfg() -> ModelConfig {
        ModelConfig {
            arch: Arch::Resnet,
            num_classes: 10,
            batch: 32,
            image: 32,
            channels: vec![16, 32, 64],
            blocks_per_stage: 2,
            nt: 5,
        }
    }

    #[test]
    fn param_index_structure() {
        let c = cfg();
        let layout = fake_layout(&c);
        let idx = ParamIndex::from_layout(&layout, &c).unwrap();
        assert_eq!(idx.stem, (0, 1));
        assert_eq!(idx.blocks.len(), 3);
        assert_eq!(idx.blocks[0].len(), 2);
        assert_eq!(idx.blocks[0][0], vec![2, 3, 4, 5]);
        assert_eq!(idx.trans.len(), 2);
        assert_eq!(idx.head, (layout.len() - 2, layout.len() - 1));
        assert_eq!(idx.len, layout.len());
    }

    #[test]
    fn shapes_and_names() {
        let c = cfg();
        assert_eq!(c.stages(), 3);
        assert_eq!(c.num_ode_blocks(), 6);
        assert_eq!(c.stage_hw(0), 32);
        assert_eq!(c.stage_hw(2), 8);
        assert_eq!(c.stage_act_shape(1), vec![32, 16, 16, 32]);
        assert_eq!(c.stage_act_bytes(2), 32 * 8 * 8 * 64 * 4);
        // Rolling inference activation = the largest stage (stage 0 here).
        assert_eq!(c.rolling_act_bytes(), c.stage_act_bytes(0));
        assert_eq!(c.block_module(1, Solver::Euler, "vjp"), "block_resnet_s1_euler_vjp");
        assert_eq!(c.params_key(), "resnet10");
    }

    #[test]
    fn parse_helpers() {
        assert_eq!(Arch::parse("sqnxt"), Some(Arch::Sqnxt));
        assert_eq!(Solver::parse("rk45"), Some(Solver::Rk45));
        assert_eq!(GradMethod::parse("anode"), Some(GradMethod::Anode));
        assert_eq!(GradMethod::parse("anode-revolve3"), Some(GradMethod::AnodeRevolve(3)));
        assert_eq!(GradMethod::parse("node"), Some(GradMethod::Node));
        assert_eq!(GradMethod::parse("bogus"), None);
        assert_eq!(GradMethod::AnodeEquispaced(2).name(), "anode-equispaced2");
    }

    #[test]
    fn parse_accepts_valid_checkpoint_budgets() {
        assert_eq!(GradMethod::parse("anode-revolve1"), Some(GradMethod::AnodeRevolve(1)));
        assert_eq!(GradMethod::parse("anode-revolve16"), Some(GradMethod::AnodeRevolve(16)));
        assert_eq!(
            GradMethod::parse("anode-equispaced1"),
            Some(GradMethod::AnodeEquispaced(1))
        );
        assert_eq!(
            GradMethod::parse("anode-equispaced8"),
            Some(GradMethod::AnodeEquispaced(8))
        );
    }

    #[test]
    fn parse_rejects_degenerate_checkpoint_budgets() {
        assert_eq!(GradMethod::parse("anode-revolve0"), None);
        assert_eq!(GradMethod::parse("anode-equispaced0"), None);
        assert_eq!(GradMethod::parse("anode-revolve"), None);
        assert_eq!(GradMethod::parse("anode-equispaced"), None);
        assert_eq!(GradMethod::parse("anode-revolve-3"), None);
        assert_eq!(GradMethod::parse("anode-revolveX"), None);
        assert_eq!(GradMethod::parse("interp-adjoint0"), None);
        assert_eq!(GradMethod::parse("interp-adjoint1"), None); // needs both endpoints
        assert_eq!(GradMethod::parse("interp-adjoint"), None);
        assert_eq!(GradMethod::parse("symplectic2"), None);
    }

    #[test]
    fn parse_round_trips_new_strategy_specs() {
        assert_eq!(GradMethod::parse("symplectic"), Some(GradMethod::Symplectic));
        assert_eq!(GradMethod::parse("interp-adjoint2"), Some(GradMethod::InterpAdjoint(2)));
        assert_eq!(GradMethod::parse("interp-adjoint3"), Some(GradMethod::InterpAdjoint(3)));
        assert_eq!(GradMethod::Symplectic.name(), "symplectic");
        assert_eq!(GradMethod::InterpAdjoint(3).name(), "interp-adjoint3");
        for spec in ["symplectic", "interp-adjoint3", "interp-adjoint16"] {
            assert_eq!(GradMethod::parse(spec).unwrap().name(), spec);
        }
    }

    /// Trailing or embedded garbage around an otherwise-valid budget must
    /// surface the same typed error as a degenerate budget — not parse as
    /// the budget with the garbage silently ignored, and not fall through
    /// to the not-this-pattern `None` arm that unknown-spec callers treat
    /// as "try the next prefix".
    #[test]
    fn parse_budget_rejects_trailing_garbage_with_typed_error() {
        for (spec, prefix) in [
            ("anode-revolve:4x", "anode-revolve"),
            ("anode-revolve4x", "anode-revolve"),
            ("anode-revolve:4", "anode-revolve"),
            ("anode-revolve+3", "anode-revolve"),
            ("anode-equispaced2.5", "anode-equispaced"),
            ("interp-adjoint3x", "interp-adjoint"),
            ("interp-adjoint:3", "interp-adjoint"),
        ] {
            let got = parse_budget(spec, prefix);
            assert!(
                matches!(got, Some(Err(RuntimeError::Io(_)))),
                "{spec}: want typed budget error, got {got:?}"
            );
            assert_eq!(GradMethod::parse(spec), None, "{spec} must not parse");
        }
        // No digits after the prefix at all: genuinely not the pattern.
        assert_eq!(parse_budget("anode-revolveX", "anode-revolve"), None);
        assert!(matches!(parse_budget("anode-revolve0", "anode-revolve"), Some(Err(_))));
        // A budget too large for usize is the pattern, malformed.
        assert!(matches!(
            parse_budget("anode-revolve99999999999999999999999", "anode-revolve"),
            Some(Err(_))
        ));
    }
}
