//! Shape utilities shared across modules.

/// Element count of a shape (empty shape = scalar = 1).
pub fn elem_count(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Convenience alias used in manifests and specs.
pub type Shape = Vec<usize>;

/// True if `a` and `b` are identical shapes (we do not support implicit
/// broadcasting on the host side; the check exists to give good errors).
pub fn broadcastable(a: &[usize], b: &[usize]) -> bool {
    a == b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_count_scalar_is_one() {
        assert_eq!(elem_count(&[]), 1);
        assert_eq!(elem_count(&[2, 3, 4]), 24);
        assert_eq!(elem_count(&[0, 5]), 0);
    }

    #[test]
    fn broadcastable_is_strict_equality() {
        assert!(broadcastable(&[2, 3], &[2, 3]));
        assert!(!broadcastable(&[2, 3], &[3, 2]));
    }
}
