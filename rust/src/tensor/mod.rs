//! Host-side dense f32 tensors.
//!
//! The coordinator stages all activations/parameters/gradients as plain
//! row-major f32 buffers; the runtime converts them to PJRT literals at the
//! call boundary. Deliberately minimal — shape bookkeeping and a few
//! elementwise helpers the optimizer and metrics need, nothing more.

mod shape;

pub use shape::{broadcastable, elem_count, Shape};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Error for shape/data mismatches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorError(pub String);

impl std::fmt::Display for TensorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tensor error: {}", self.0)
    }
}
impl std::error::Error for TensorError {}

impl Tensor {
    /// Build from shape + data; validates element count.
    pub fn from_vec(shape: Vec<usize>, data: Vec<f32>) -> Result<Self, TensorError> {
        let n = elem_count(&shape);
        if n != data.len() {
            return Err(TensorError(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; elem_count(shape)] }
    }

    /// All-`v` tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; elem_count(shape)] }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Rank (number of dims).
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if zero elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (f32).
    pub fn byte_size(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Borrow the flat data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutably borrow the flat data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat data.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Scalar value of a rank-0 / single-element tensor.
    pub fn item(&self) -> Result<f32, TensorError> {
        if self.data.len() == 1 {
            Ok(self.data[0])
        } else {
            Err(TensorError(format!("item() on tensor with {} elems", self.data.len())))
        }
    }

    /// Reshape without copying; element count must match.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self, TensorError> {
        if elem_count(&shape) != self.data.len() {
            return Err(TensorError(format!(
                "cannot reshape {:?} ({} elems) to {:?}",
                self.shape,
                self.data.len(),
                shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Elementwise a += alpha * b (axpy). Shapes must match exactly.
    pub fn axpy(&mut self, alpha: f32, b: &Tensor) -> Result<(), TensorError> {
        if self.shape != b.shape {
            return Err(TensorError(format!("axpy shape {:?} vs {:?}", self.shape, b.shape)));
        }
        for (x, y) in self.data.iter_mut().zip(b.data.iter()) {
            *x += alpha * y;
        }
        Ok(())
    }

    /// Elementwise scale in place.
    pub fn scale(&mut self, alpha: f32) {
        for x in self.data.iter_mut() {
            *x *= alpha;
        }
    }

    /// L2 norm of the flattened tensor.
    pub fn norm2(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Max |x|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Mean of elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Relative L2 error vs a reference: ‖a-b‖₂/‖b‖₂ (Eq. 6 metric ρ).
    pub fn rel_err(&self, reference: &Tensor) -> Result<f32, TensorError> {
        if self.shape != reference.shape {
            return Err(TensorError(format!(
                "rel_err shape {:?} vs {:?}",
                self.shape, reference.shape
            )));
        }
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(reference.data.iter()) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        Ok(if den == 0.0 { num.sqrt() as f32 } else { (num.sqrt() / den.sqrt()) as f32 })
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_full_scalar() {
        let z = Tensor::zeros(&[2, 2]);
        assert_eq!(z.data(), &[0.0; 4]);
        let f = Tensor::full(&[3], 2.5);
        assert_eq!(f.data(), &[2.5, 2.5, 2.5]);
        let s = Tensor::scalar(7.0);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.item().unwrap(), 7.0);
    }

    #[test]
    fn item_rejects_multi() {
        assert!(Tensor::zeros(&[2]).item().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.clone().reshape(vec![6]).unwrap().shape(), &[6]);
        assert!(t.reshape(vec![7]).is_err());
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::full(&[4], 1.0);
        let b = Tensor::full(&[4], 2.0);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.data(), &[2.0; 4]);
        a.scale(2.0);
        assert_eq!(a.data(), &[4.0; 4]);
        let c = Tensor::full(&[5], 1.0);
        assert!(a.axpy(1.0, &c).is_err());
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(vec![2], vec![3.0, 4.0]).unwrap();
        assert!((t.norm2() - 5.0).abs() < 1e-6);
        assert_eq!(t.max_abs(), 4.0);
        assert!((t.mean() - 3.5).abs() < 1e-6);
    }

    #[test]
    fn rel_err_metric() {
        let a = Tensor::from_vec(vec![2], vec![1.0, 0.0]).unwrap();
        let b = Tensor::from_vec(vec![2], vec![0.0, 1.0]).unwrap();
        let e = a.rel_err(&b).unwrap();
        assert!((e - (2.0f32).sqrt()).abs() < 1e-6);
        assert_eq!(a.rel_err(&a).unwrap(), 0.0);
    }

    #[test]
    fn finite_check() {
        let mut t = Tensor::zeros(&[2]);
        assert!(t.all_finite());
        t.data_mut()[0] = f32::NAN;
        assert!(!t.all_finite());
    }

    #[test]
    fn byte_size() {
        assert_eq!(Tensor::zeros(&[2, 2]).byte_size(), 16);
    }
}
