//! Adaptive flush-window control for the admission queue.
//!
//! The fixed `max_delay` deadline trades tail latency against batch fill:
//! too long and a sparse stream pays the whole window on every request,
//! too short and a dense stream flushes half-empty batches ahead of the
//! fill it would have gotten for free. [`DelayController`] resolves the
//! tension from the observed arrival rate: it keeps an EWMA of the
//! inter-arrival gap and sets the interactive flush window to the time a
//! *full* batch is expected to take to assemble —
//! `(batch_size − 1) · ewma_gap` — clamped into a configured
//! `[floor, ceiling]`. Dense traffic ⇒ the window shrinks toward the
//! floor (the batch fills before any deadline matters, so don't promise
//! more latency than needed); sparse traffic ⇒ it grows toward the
//! ceiling (waiting is the only way to fill). Batch-class requests keep
//! their own fixed, longer window — their SLO is throughput, not p99.
//!
//! Deadlines are resolved *at admission* ([`DelayController::on_arrival`]
//! records the arrival and returns the class's current window), so a
//! window change never retroactively moves already-admitted deadlines.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::SloClass;

/// EWMA smoothing factor for the inter-arrival gap (higher = more
/// reactive). 0.2 settles within ~10 requests without chasing jitter.
const ALPHA: f64 = 0.2;

/// Gaps longer than this are clamped before entering the EWMA: a long
/// idle period means "no information", not "traffic is 60 s apart", and
/// must not pin the window at the ceiling for the next burst's duration.
const MAX_GAP: Duration = Duration::from_secs(1);

struct DelayState {
    last_arrival: Option<Instant>,
    /// Smoothed inter-arrival gap in seconds (None until two arrivals).
    ewma_gap: Option<f64>,
    /// Current interactive flush window.
    current: Duration,
}

/// Resolves the per-class flush window at admission; adaptive when
/// configured with a `[floor, ceiling]`, otherwise fixed.
pub(crate) struct DelayController {
    /// Fixed interactive window (`ServeConfig::max_delay`); also the
    /// adaptive mode's initial window before any rate estimate exists.
    base: Duration,
    /// Fixed window for [`SloClass::Batch`] requests.
    batch_delay: Duration,
    /// `(floor, ceiling)` for the adaptive interactive window; `None`
    /// pins the window at `base`.
    adaptive: Option<(Duration, Duration)>,
    batch_size: usize,
    state: Mutex<DelayState>,
}

impl DelayController {
    pub fn new(
        base: Duration,
        batch_delay: Duration,
        adaptive: Option<(Duration, Duration)>,
        batch_size: usize,
    ) -> Self {
        // Normalize a floor above its ceiling instead of erroring: clamp
        // semantics stay total and the window simply degenerates to fixed.
        let adaptive = adaptive.map(|(f, c)| (f.min(c), f.max(c)));
        let initial = match adaptive {
            Some((floor, ceiling)) => base.clamp(floor, ceiling),
            None => base,
        };
        Self {
            base,
            batch_delay,
            adaptive,
            batch_size: batch_size.max(1),
            state: Mutex::new(DelayState {
                last_arrival: None,
                ewma_gap: None,
                current: initial,
            }),
        }
    }

    /// Record one admission at `now` and return the flush window the
    /// request's deadline should be built from.
    pub fn on_arrival(&self, now: Instant, class: SloClass) -> Duration {
        let mut st = match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some((floor, ceiling)) = self.adaptive {
            if let Some(last) = st.last_arrival {
                let gap = now.saturating_duration_since(last).min(MAX_GAP).as_secs_f64();
                let ewma = match st.ewma_gap {
                    Some(prev) => ALPHA * gap + (1.0 - ALPHA) * prev,
                    None => gap,
                };
                st.ewma_gap = Some(ewma);
                // Expected time for the batch's remaining (batch−1) slots
                // to fill at the observed rate.
                let fill = Duration::from_secs_f64(ewma * (self.batch_size - 1) as f64);
                st.current = fill.clamp(floor, ceiling);
            }
            st.last_arrival = Some(now);
        }
        match class {
            SloClass::Interactive => st.current,
            SloClass::Batch => self.batch_delay,
        }
    }

    /// The current interactive flush window (for stats/metrics export).
    pub fn current_window(&self) -> Duration {
        match self.state.lock() {
            Ok(guard) => guard.current,
            Err(poisoned) => poisoned.into_inner().current,
        }
    }

    /// Is the window adaptive (vs pinned at `max_delay`)?
    pub fn is_adaptive(&self) -> bool {
        self.adaptive.is_some()
    }

    /// The fixed interactive window the controller was built from.
    pub fn base(&self) -> Duration {
        self.base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mode_returns_base_and_batch_windows() {
        let c = DelayController::new(
            Duration::from_millis(5),
            Duration::from_millis(40),
            None,
            8,
        );
        let t = Instant::now();
        assert_eq!(c.on_arrival(t, SloClass::Interactive), Duration::from_millis(5));
        assert_eq!(c.on_arrival(t, SloClass::Batch), Duration::from_millis(40));
        assert_eq!(c.current_window(), Duration::from_millis(5));
        assert!(!c.is_adaptive());
    }

    #[test]
    fn dense_arrivals_shrink_toward_floor() {
        let floor = Duration::from_micros(500);
        let ceiling = Duration::from_millis(50);
        let c = DelayController::new(
            Duration::from_millis(5),
            Duration::from_millis(40),
            Some((floor, ceiling)),
            8,
        );
        let t0 = Instant::now();
        // 10 µs gaps: a batch fills in ~70 µs, far below the floor.
        for i in 0..64u64 {
            c.on_arrival(t0 + Duration::from_micros(10 * i), SloClass::Interactive);
        }
        assert_eq!(c.current_window(), floor);
        assert!(c.is_adaptive());
    }

    #[test]
    fn sparse_arrivals_grow_toward_ceiling() {
        let floor = Duration::from_micros(500);
        let ceiling = Duration::from_millis(20);
        let c = DelayController::new(
            Duration::from_millis(1),
            Duration::from_millis(40),
            Some((floor, ceiling)),
            8,
        );
        let t0 = Instant::now();
        // 30 ms gaps: filling 7 more slots would take ~210 ms >> ceiling.
        for i in 0..32u64 {
            c.on_arrival(t0 + Duration::from_millis(30 * i), SloClass::Interactive);
        }
        assert_eq!(c.current_window(), ceiling);
    }

    #[test]
    fn batch_class_window_is_unaffected_by_rate() {
        let c = DelayController::new(
            Duration::from_millis(5),
            Duration::from_millis(40),
            Some((Duration::from_millis(1), Duration::from_millis(20))),
            8,
        );
        let t0 = Instant::now();
        for i in 0..16u64 {
            assert_eq!(
                c.on_arrival(t0 + Duration::from_micros(i), SloClass::Batch),
                Duration::from_millis(40)
            );
        }
    }

    #[test]
    fn idle_gap_does_not_pin_the_ceiling_forever() {
        let floor = Duration::from_millis(1);
        let ceiling = Duration::from_millis(20);
        let c = DelayController::new(
            Duration::from_millis(5),
            Duration::from_millis(40),
            Some((floor, ceiling)),
            8,
        );
        let mut t = Instant::now();
        c.on_arrival(t, SloClass::Interactive);
        // An hour of idleness, then a dense burst: the clamped gap decays
        // under the burst instead of holding the ceiling for hours.
        t += Duration::from_secs(3600);
        for i in 0..256u64 {
            c.on_arrival(t + Duration::from_micros(5 * i), SloClass::Interactive);
        }
        assert_eq!(c.current_window(), floor);
    }
}
