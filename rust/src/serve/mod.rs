//! # `anode::serve` — deadline-batched single-request serving
//!
//! The serving front end over the inference path: callers submit *single*
//! examples; the runtime coalesces them into the AOT-compiled batch size,
//! executes filled batches on a **persistent** worker pool, and
//! demultiplexes per-request replies back to each caller with per-request
//! latency (queue wait + execute) layered on the per-batch stats.
//!
//! ```text
//! submit(example) ──▶ AdmissionQueue ──▶ batcher ──▶ WorkerPool ──▶ reply
//!    (bounded, cap)    flush on:          assemble     long-lived     per
//!    backpressure      batch full OR      (B, ...)     pinned threads request
//!                      max_delay OR       padded       per-worker     channel
//!                      shutdown           tensor       MemoryLedger
//! ```
//!
//! * **Deadline flush** — a batch leaves the queue when it fills to the
//!   AOT batch size *or* when the earliest admitted *deadline* arrives,
//!   whichever comes first; shutdown drains the remainder. Partial
//!   batches are zero-padded to the compiled shape (per-example
//!   computation makes row values independent of the padding).
//! * **SLO classes & adaptive window** — each request carries a
//!   [`SloClass`]: `Interactive` requests use the `max_delay` flush
//!   window (optionally *adaptive* — an arrival-rate tracker shrinks or
//!   grows it between a configured floor and ceiling, see
//!   [`ServeConfig::adaptive_delay_ms`]), while `Batch` requests hold a
//!   longer fixed window ([`ServeConfig::batch_delay_ms`]) so background
//!   traffic coalesces into fuller batches without dragging interactive
//!   p99. Deadlines are absolute and fixed at admission.
//! * **Persistent workers** — the pool's threads (a serving-flavored
//!   [`crate::util::pool::PersistentPool`]) are spawned once and live
//!   until shutdown, each metering a private
//!   [`MemoryLedger`](crate::memory::MemoryLedger) for its lifetime; the
//!   merged aggregate is returned by [`ServeHandle::shutdown`].
//! * **Parameter hot-swap** — [`ServeHandle::swap_params`] atomically
//!   replaces the runner's weight snapshot between batches, so a
//!   checkpoint trained elsewhere rolls out with no queue drain and no
//!   downtime (shape-validated; in-flight batches finish on the old
//!   snapshot).
//! * **Backpressure** — the admission queue is bounded at `queue_cap`
//!   ([`ServeHandle::submit`] blocks, [`ServeHandle::try_submit`] reports
//!   full) and the pool queues at most one spare batch per worker, so a
//!   slow model slows admission instead of buffering without bound.
//! * **Pool-per-device sharding** — [`ServeHandle::spawn_sharded`] runs
//!   one worker pool per device runner behind the single admission queue;
//!   the batcher routes each filled batch to the least-loaded device
//!   (a [`crate::util::pool::ShardRouter`]), per-device ledgers fold into
//!   one report, and a broken device degrades to error replies for its
//!   batches while the others keep serving (rust/DESIGN.md §6d).
//! * **Bit-identical values** — the session-backed runner executes exactly
//!   the per-batch computation of
//!   [`Session::predict_batches`](crate::api::Session::predict_batches),
//!   so served logits are bit-identical to the pre-batched path
//!   (asserted in `rust/tests/serve.rs`).
//!
//! Entry points: [`Session::serve`](crate::api::Session::serve) for the
//! engine-backed path, or [`ServeHandle::spawn`] with a custom
//! [`BatchRunner`] (the [`HostTailRunner`] demo model works on the
//! vendored xla stub, so the serving path is exercisable offline).
//! Semantics are documented in rust/DESIGN.md §6b.

mod delay;
mod pool;
mod queue;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::session::{argmax_rows, head_logits, infer_batch, PredictStats, Prediction};
use crate::compile::CompileStatsSnapshot;
use crate::coordinator::ExecutionCore;
use crate::memory::{Category, MemoryLedger};
use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;
use crate::util::pool::ShardRouter;

use delay::DelayController;
use pool::{BatchJob, WorkerPool};
use queue::{AdmissionQueue, FlushReason, PendingRequest};

/// Service-level-objective class of a submitted request: which flush
/// window its admission deadline is derived from.
///
/// `Interactive` is the latency class (the — possibly adaptive —
/// `max_delay` window); `Batch` is the throughput class (a longer fixed
/// window that lets background traffic coalesce into fuller batches).
/// Classes share the FIFO admission queue — the class decides *when* a
/// partial flush fires, never request order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SloClass {
    /// Latency-sensitive: flush by the (possibly adaptive) `max_delay`.
    #[default]
    Interactive,
    /// Throughput-oriented: flush by the longer fixed `batch_delay`.
    Batch,
}

impl SloClass {
    /// Stable lowercase name (wire tags, CLI flags, metrics labels).
    pub fn as_str(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Parse the stable name back (`"interactive"` / `"batch"`).
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// Executes one assembled batch for the serving pipeline.
///
/// Implementations must be thread-safe: the persistent pool calls `run`
/// from several worker threads concurrently (each with its own ledger).
/// The session-backed implementation is wired by
/// [`Session::serve`](crate::api::Session::serve); [`HostTailRunner`] is a
/// host-only stand-in for offline builds and tests.
pub trait BatchRunner: Send + Sync + 'static {
    /// The AOT-compiled batch capacity the queue coalesces toward.
    fn batch_size(&self) -> usize;

    /// Shape of one example (a single request's tensor, without the
    /// leading batch dimension).
    fn example_shape(&self) -> Vec<usize>;

    /// Execute one full `(batch_size, ...)` tensor, metering transient
    /// working memory on `ledger`. Rows past the real fill are zero
    /// padding; per-example models may ignore them.
    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction>;

    /// Atomically replace the parameter snapshot used by *subsequent*
    /// batches (a batch already executing finishes on the snapshot it
    /// started with). The snapshot arrives as an `Arc` so a sharded
    /// rollout shares **one** tensor set across all device runners
    /// (cloning the `Arc`, never the tensors). Runners without swappable
    /// weights keep this default, which reports the capability as
    /// unsupported.
    fn swap_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        let _ = params;
        Err(RuntimeError::Io("serve: this runner does not support parameter hot-swap".into()))
    }

    /// Validate a prospective hot-swap **without applying it** — the same
    /// count/shape check [`BatchRunner::swap_params`] performs. A sharded
    /// [`ServeHandle`] validates every device's runner first and only then
    /// applies, so a rejected swap leaves no device on mixed weights.
    /// Override this alongside `swap_params` (the default mirrors the
    /// unsupported default above).
    fn validate_swap(&self, params: &[Tensor]) -> Result<()> {
        let _ = params;
        Err(RuntimeError::Io("serve: this runner does not support parameter hot-swap".into()))
    }

    /// Snapshot of this runner's compiled-backend counters (plan cache,
    /// fusion, arena activity), when it executes through
    /// [`crate::runtime::Backend::Compiled`]. Runners on other backends
    /// keep this default `None`; the metrics endpoint sums the rest.
    fn compile_stats(&self) -> Option<CompileStatsSnapshot> {
        None
    }
}

/// Configuration for the serving front end.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Flush window for [`SloClass::Interactive`] requests: an admitted
    /// request waits at most this long before a partial-batch flush
    /// (default 5 ms). The *initial* window when `adaptive_delay` is set.
    pub max_delay: Duration,
    /// Flush window for [`SloClass::Batch`] requests — longer, so
    /// background traffic coalesces into fuller batches (default 40 ms).
    pub batch_delay: Duration,
    /// Adaptive interactive window as `(floor, ceiling)`: when set, an
    /// EWMA arrival-rate tracker retargets the window each admission to
    /// the expected batch fill time, clamped into this range. `None`
    /// (default) pins the window at `max_delay`.
    pub adaptive_delay: Option<(Duration, Duration)>,
    /// Persistent worker threads executing batches (default 2, min 1).
    pub workers: usize,
    /// Admission-queue capacity in *requests*; `submit` blocks and
    /// `try_submit` reports full beyond it (default 256, min 1).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_delay: Duration::from_millis(5),
            batch_delay: Duration::from_millis(40),
            adaptive_delay: None,
            workers: 2,
            queue_cap: 256,
        }
    }
}

impl ServeConfig {
    /// Set the interactive deadline flush in milliseconds.
    pub fn max_delay_ms(mut self, ms: u64) -> Self {
        self.max_delay = Duration::from_millis(ms);
        self
    }

    /// Set the batch-class deadline flush in milliseconds.
    pub fn batch_delay_ms(mut self, ms: u64) -> Self {
        self.batch_delay = Duration::from_millis(ms);
        self
    }

    /// Enable the adaptive interactive window, clamped to
    /// `[floor_ms, ceiling_ms]` (order-normalized if swapped).
    pub fn adaptive_delay_ms(mut self, floor_ms: u64, ceiling_ms: u64) -> Self {
        self.adaptive_delay =
            Some((Duration::from_millis(floor_ms), Duration::from_millis(ceiling_ms)));
        self
    }

    /// Set the persistent worker count.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Set the admission-queue capacity.
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }
}

/// Per-request latency accounting, layered on the per-batch stats.
#[derive(Debug, Clone, Copy)]
pub struct RequestStats {
    /// Admission to execution start: time spent in the admission queue,
    /// batch assembly, and the pool's job queue.
    pub queue_wait: Duration,
    /// Wall-clock of the batch execution this request rode in.
    pub execute: Duration,
    /// Real requests in the executed batch (< `batch_size` on a deadline
    /// or shutdown flush; the rest was zero padding).
    pub batch_fill: usize,
    /// AOT-compiled batch capacity.
    pub batch_size: usize,
}

impl RequestStats {
    /// End-to-end latency: queue wait + batch execution.
    pub fn total(&self) -> Duration {
        self.queue_wait + self.execute
    }
}

/// One served reply: the predicted class, this request's logits row, and
/// its latency stats.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Predicted class for the submitted example.
    pub class: usize,
    /// Raw logits for this example, shape `(num_classes,)` — the row this
    /// request occupied in the executed batch.
    pub logits: Tensor,
    /// Per-request latency accounting.
    pub stats: RequestStats,
}

/// A submitted request's pending reply (one-shot).
pub struct Pending {
    rx: mpsc::Receiver<Result<ServeReply>>,
}

impl Pending {
    /// Block until the reply arrives (or the pipeline fails the request).
    pub fn wait(self) -> Result<ServeReply> {
        match self.rx.recv() {
            Ok(reply) => reply,
            Err(_) => Err(dropped_reply()),
        }
    }

    /// Block up to `timeout`: `Ok(None)` if no reply arrived in time (the
    /// request is still in flight and can be waited on again).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<Option<ServeReply>> {
        match self.rx.recv_timeout(timeout) {
            Ok(reply) => reply.map(Some),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(dropped_reply()),
        }
    }
}

fn dropped_reply() -> RuntimeError {
    RuntimeError::Io("serve: request dropped before a reply was produced".into())
}

/// Live counters shared by the handle, the batcher, and the pool.
#[derive(Default)]
pub(crate) struct Counters {
    pub submitted: AtomicU64,
    pub submitted_interactive: AtomicU64,
    pub submitted_batch: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub full_flushes: AtomicU64,
    pub deadline_flushes: AtomicU64,
    pub drain_flushes: AtomicU64,
    /// Cumulative ledger traffic (alloc'd bytes) across all worker
    /// batches — the live view of the per-worker ledgers, which are
    /// thread-owned until shutdown folds them.
    pub mem_traffic: AtomicU64,
    /// Max single-worker ledger peak observed so far (bytes).
    pub mem_worker_peak: AtomicU64,
    /// Rollout candidates shadow-evaluated against this pipeline.
    pub rollout_candidates: AtomicU64,
    /// Candidates promoted to the live snapshot
    /// ([`ServeHandle::promote_params`]).
    pub rollout_promotions: AtomicU64,
    /// Regressions rolled back to the last-good snapshot
    /// ([`ServeHandle::rollback_params`]).
    pub rollout_rollbacks: AtomicU64,
    /// True while a promote/rollback swap is applying — workers record
    /// the latency of requests completing inside the window into
    /// `swap_lat_us`, so "serving p99 during swap" is measurable.
    pub swap_window: AtomicBool,
    /// End-to-end request latencies (µs) completed during swap windows
    /// (bounded ring; see [`SWAP_LATENCY_WINDOW`]).
    pub swap_lat_us: Mutex<VecDeque<u64>>,
}

/// Capacity of the during-swap latency ring: enough for p99 resolution,
/// bounded so a long-lived pipeline with many rollouts cannot grow it.
pub(crate) const SWAP_LATENCY_WINDOW: usize = 4096;

impl Counters {
    /// Record one request's end-to-end latency if a parameter swap is in
    /// flight right now (called by pool workers at reply time).
    pub(crate) fn note_swap_latency(&self, total: Duration) {
        if !self.swap_window.load(Ordering::Relaxed) {
            return;
        }
        let us = total.as_micros().min(u64::MAX as u128) as u64;
        let mut ring = match self.swap_lat_us.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if ring.len() == SWAP_LATENCY_WINDOW {
            ring.pop_front();
        }
        ring.push_back(us);
    }
}

/// Point-in-time serving statistics (see [`ServeHandle::stats`]).
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Admitted requests in the interactive SLO class.
    pub submitted_interactive: u64,
    /// Admitted requests in the batch SLO class.
    pub submitted_batch: u64,
    /// `try_submit` calls bounced by a full queue (the shed count).
    pub rejected: u64,
    /// Requests whose reply (success or error) has been sent.
    pub completed: u64,
    /// Batches dispatched to the pool.
    pub batches: u64,
    /// Batches flushed because they filled to the AOT size.
    pub full_flushes: u64,
    /// Partial batches flushed by the `max_delay` deadline.
    pub deadline_flushes: u64,
    /// Partial batches flushed by the shutdown drain.
    pub drain_flushes: u64,
    /// Requests currently waiting for batch assembly.
    pub queue_depth: usize,
    /// Batches currently outstanding per device (the router's live load
    /// view — what the least-loaded dispatch decides on).
    pub device_loads: Vec<u64>,
    /// The interactive flush window in force right now (= `max_delay`
    /// when the adaptive controller is off).
    pub current_max_delay: Duration,
    /// Is the interactive window adaptive?
    pub adaptive_delay: bool,
    /// Cumulative worker-ledger traffic so far, in bytes (live view; the
    /// authoritative fold is [`ServeReport::memory`] at shutdown).
    pub memory_traffic: u64,
    /// Max single-worker ledger peak observed so far, in bytes.
    pub memory_worker_peak: u64,
    /// Rollout candidates shadow-evaluated against this pipeline
    /// ([`ServeHandle::note_candidate`]).
    pub rollout_candidates: u64,
    /// Candidates promoted to the live snapshot.
    pub rollout_promotions: u64,
    /// Regressions rolled back to the last-good snapshot.
    pub rollout_rollbacks: u64,
    /// p99 end-to-end latency (µs) of requests that completed while a
    /// promote/rollback swap was applying — 0 until a swap window has
    /// seen traffic.
    pub rollout_swap_p99_us: u64,
    /// Has shutdown been initiated?
    pub closed: bool,
}

/// Final report returned by [`ServeHandle::shutdown`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Total requests that received a reply.
    pub requests: u64,
    /// Total batches executed.
    pub batches: u64,
    /// Batches flushed full.
    pub full_flushes: u64,
    /// Partial batches flushed by the deadline.
    pub deadline_flushes: u64,
    /// Partial batches flushed by the shutdown drain.
    pub drain_flushes: u64,
    /// Persistent workers the pipeline ran, summed across device pools.
    pub workers: usize,
    /// Device pools the pipeline routed over (1 for a plain
    /// [`ServeHandle::spawn`]).
    pub devices: usize,
    /// The aggregate ledger: per-worker ledgers merge **within** each
    /// device ([`MemoryLedger::merge`](crate::memory::MemoryLedger::merge)
    /// — one memory space, peaks summed), then devices fold with
    /// [`MemoryLedger::absorb_sharded`](crate::memory::MemoryLedger::absorb_sharded)
    /// (separate memories, peak = max over devices). Traffic is additive
    /// throughout and equal to a serial run over the same batches.
    pub memory: MemoryLedger,
    /// The per-device folds behind `memory`, device-id order.
    pub per_device_memory: Vec<MemoryLedger>,
}

struct Lifecycle {
    batcher: Option<thread::JoinHandle<()>>,
    report: Option<ServeReport>,
}

struct ServeInner {
    queue: Arc<AdmissionQueue>,
    /// One worker pool per device; the batcher routes filled batches to
    /// the least-loaded device via `router`.
    pools: Vec<Arc<WorkerPool>>,
    router: Arc<ShardRouter>,
    /// Kept on the handle for parameter hot-swap (applied to every
    /// device's runner); the pools hold their own clones for execution.
    runners: Vec<Arc<dyn BatchRunner>>,
    counters: Arc<Counters>,
    /// Per-class flush-window source; deadlines resolve at admission.
    delay: DelayController,
    example_shape: Vec<usize>,
    batch: usize,
    /// Serializes cross-device rollouts: without it, two concurrent
    /// `swap_params` calls could interleave their per-device apply loops
    /// and leave devices on different snapshots for good.
    swap_lock: Mutex<()>,
    lifecycle: Mutex<Lifecycle>,
}

impl ServeInner {
    /// Close every device pool, join all of them, and fold their ledgers:
    /// merged per device, devices folded cross-memory (max peaks). The
    /// first panic payload from any pool is returned only after **every**
    /// pool has been joined, so a panicking device cannot leak threads on
    /// the others.
    fn join_pools(
        &self,
    ) -> (MemoryLedger, Vec<MemoryLedger>, Option<Box<dyn std::any::Any + Send>>) {
        for pool in &self.pools {
            pool.close();
        }
        let mut per_device = Vec::with_capacity(self.pools.len());
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for pool in &self.pools {
            let (ledger, payload) = pool.join_collect();
            per_device.push(ledger);
            if panic.is_none() {
                panic = payload;
            }
        }
        let mut memory = MemoryLedger::new();
        memory.absorb_sharded(&per_device);
        (memory, per_device, panic)
    }
}

impl Drop for ServeInner {
    fn drop(&mut self) {
        // Last handle gone without an explicit shutdown: tear the pipeline
        // down quietly (no panic propagation from a Drop).
        self.queue.close();
        let mut lc = match self.lifecycle.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(batcher) = lc.batcher.take() {
            let _ = batcher.join();
            let _ = self.join_pools();
        }
    }
}

/// Cloneable handle to a running serving pipeline.
///
/// All clones feed the same admission queue, batcher, and worker pool;
/// [`ServeHandle::shutdown`] (any clone) stops admission, drains in-flight
/// requests, joins the threads, and returns the final [`ServeReport`].
/// Dropping the last clone tears the pipeline down without a report.
#[derive(Clone)]
pub struct ServeHandle {
    inner: Arc<ServeInner>,
}

impl ServeHandle {
    /// Start a serving pipeline over a custom [`BatchRunner`]:
    /// spawn `config.workers` persistent workers plus the batcher thread.
    ///
    /// [`Session::serve`](crate::api::Session::serve) is the engine-backed
    /// entry point; call this directly to serve a different model (or the
    /// [`HostTailRunner`] demo on artifact-less builds). For multi-device
    /// serving, [`ServeHandle::spawn_sharded`] takes one runner per
    /// device.
    pub fn spawn(runner: Arc<dyn BatchRunner>, config: ServeConfig) -> Result<ServeHandle> {
        Self::spawn_sharded(vec![runner], config)
    }

    /// Start a **sharded** serving pipeline: one persistent worker pool of
    /// `config.workers` threads per runner (= per device), a single
    /// deadline-batched admission queue in front, and a load-aware
    /// [`ShardRouter`] in between — every filled batch dispatches to the
    /// device with the least outstanding work. Per-request replies and
    /// their values are independent of the routing (each runner must
    /// compute the same function, as the per-device [`SessionRunner`]s of
    /// one session do), so served logits stay bit-identical to the
    /// single-device pipeline. See rust/DESIGN.md §6d.
    ///
    /// All runners must agree on the batch size and example shape;
    /// [`ServeHandle::swap_params`] applies to every device's runner.
    pub fn spawn_sharded(
        runners: Vec<Arc<dyn BatchRunner>>,
        config: ServeConfig,
    ) -> Result<ServeHandle> {
        let Some(first) = runners.first() else {
            return Err(RuntimeError::Shape("serve: need at least one device runner".into()));
        };
        let batch = first.batch_size();
        if batch == 0 {
            return Err(RuntimeError::Shape("serve: runner batch size must be >= 1".into()));
        }
        let example_shape = first.example_shape();
        if example_shape.iter().product::<usize>() == 0 {
            return Err(RuntimeError::Shape(format!(
                "serve: runner example shape {example_shape:?} has zero elements"
            )));
        }
        for (d, runner) in runners.iter().enumerate().skip(1) {
            if runner.batch_size() != batch || runner.example_shape() != example_shape {
                return Err(RuntimeError::Shape(format!(
                    "serve: device {d} runner disagrees with device 0 on batch size or \
                     example shape ({} vs {batch}, {:?} vs {example_shape:?}) — sharded \
                     serving needs one model replicated per device",
                    runner.batch_size(),
                    runner.example_shape(),
                )));
            }
        }
        let delay = DelayController::new(
            config.max_delay,
            config.batch_delay,
            config.adaptive_delay,
            batch,
        );
        let queue = Arc::new(AdmissionQueue::new(config.queue_cap));
        let counters = Arc::new(Counters::default());
        let workers = config.workers.max(1);
        let router = Arc::new(ShardRouter::new(&vec![workers; runners.len()]));
        let mut pools = Vec::with_capacity(runners.len());
        for (d, runner) in runners.iter().enumerate() {
            let pool = WorkerPool::new(runner.clone(), workers, counters.clone(), d)
                .map_err(|e| RuntimeError::Io(format!("serve: worker spawn failed: {e}")));
            match pool {
                Ok(pool) => pools.push(Arc::new(pool)),
                Err(e) => {
                    // Unwind the devices already spawned before reporting.
                    for pool in &pools {
                        pool.close();
                        let _ = pool.join_collect();
                    }
                    return Err(e);
                }
            }
        }
        let spawned = {
            let queue = queue.clone();
            let pools = pools.clone();
            let router = router.clone();
            let counters = counters.clone();
            let example_shape = example_shape.clone();
            thread::Builder::new().name("anode-serve-batcher".into()).spawn(move || {
                batcher_loop(&queue, &pools, &router, &counters, batch, &example_shape)
            })
        };
        let batcher = match spawned {
            Ok(handle) => handle,
            Err(e) => {
                // Without a batcher the workers would wait forever: tear
                // the pools down before reporting the failure.
                for pool in &pools {
                    pool.close();
                    let _ = pool.join_collect();
                }
                return Err(RuntimeError::Io(format!("serve: batcher spawn failed: {e}")));
            }
        };
        Ok(ServeHandle {
            inner: Arc::new(ServeInner {
                queue,
                pools,
                router,
                runners,
                counters,
                delay,
                example_shape,
                batch,
                swap_lock: Mutex::new(()),
                lifecycle: Mutex::new(Lifecycle { batcher: Some(batcher), report: None }),
            }),
        })
    }

    /// Hot-swap the model parameters on the running pipeline: an atomic
    /// swap of each device runner's weight-snapshot `Arc`, applied
    /// **between batches** — no queue drain, no downtime. Requests already
    /// executing finish on the old snapshot; every later batch uses the
    /// new one.
    ///
    /// Two-phase across devices: every runner first **validates** the
    /// swap ([`BatchRunner::validate_swap`] — tensor count/shapes, or
    /// unsupported), and only if all accept is the swap applied — so a
    /// rejected rollout leaves no device serving mixed weights. Rollouts
    /// are serialized (concurrent `swap_params` calls from handle clones
    /// apply one after the other, never interleaved per device). See
    /// [`Session::push_params`](crate::api::Session::push_params) for the
    /// trained-checkpoint rollout path.
    ///
    /// The snapshot is an `Arc`: all device runners share the **same**
    /// tensor set (N `Arc` clones, zero tensor copies), so a rollout's
    /// memory cost is one snapshot regardless of device count.
    pub fn swap_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        let _rollout = match self.inner.swap_lock.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Requests completing while the swap applies are the
        // "serving during swap" population (rollout_swap_p99_us); the
        // window closes before the lock releases.
        self.inner.counters.swap_window.store(true, Ordering::Relaxed);
        let outcome = (|| {
            for (d, runner) in self.inner.runners.iter().enumerate() {
                runner.validate_swap(&params).map_err(|e| {
                    RuntimeError::Shape(format!("serve: hot-swap rejected on device {d}: {e}"))
                })?;
            }
            for (d, runner) in self.inner.runners.iter().enumerate() {
                // Validated above; a failure here (a runner whose validate
                // and swap disagree) is surfaced, not swallowed.
                runner.swap_params(params.clone()).map_err(|e| {
                    RuntimeError::Shape(format!("serve: hot-swap failed on device {d}: {e}"))
                })?;
            }
            Ok(())
        })();
        self.inner.counters.swap_window.store(false, Ordering::Relaxed);
        outcome
    }

    /// Count one rollout candidate shadow-evaluated against this pipeline
    /// (exported as `anode_rollout_candidates_total`). Evaluation itself
    /// happens off-pipeline (the orchestrator's held-out stream); serving
    /// traffic is untouched.
    pub fn note_candidate(&self) {
        self.inner.counters.rollout_candidates.fetch_add(1, Ordering::Relaxed);
    }

    /// [`ServeHandle::swap_params`] plus promotion accounting: a rollout
    /// candidate that passed its quality gate becomes the live snapshot.
    /// The counter only moves on a *successful* swap.
    pub fn promote_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        self.swap_params(params)?;
        self.inner.counters.rollout_promotions.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// [`ServeHandle::swap_params`] plus rollback accounting: serving
    /// returns to the last-good snapshot after a detected regression.
    /// In-flight batches finish on the regressed snapshot (between-batches
    /// swap semantics); every batch dispatched after this returns uses the
    /// last-good weights.
    pub fn rollback_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        self.swap_params(params)?;
        self.inner.counters.rollout_rollbacks.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Device pools this pipeline routes over.
    pub fn device_count(&self) -> usize {
        self.inner.pools.len()
    }

    /// Aggregate compiled-backend counters across every device runner
    /// (summed via [`CompileStatsSnapshot::absorb`]), or `None` when no
    /// runner executes through the compiled backend — what the
    /// `net::metrics` endpoint exports as `anode_compile_*`.
    pub fn compile_stats(&self) -> Option<CompileStatsSnapshot> {
        let mut total: Option<CompileStatsSnapshot> = None;
        for runner in &self.inner.runners {
            if let Some(snap) = runner.compile_stats() {
                total.get_or_insert_with(CompileStatsSnapshot::default).absorb(&snap);
            }
        }
        total
    }

    /// The AOT batch capacity the queue coalesces toward.
    pub fn batch_size(&self) -> usize {
        self.inner.batch
    }

    /// Shape of one submitted example.
    pub fn example_shape(&self) -> &[usize] {
        &self.inner.example_shape
    }

    fn check_example(&self, image: &Tensor) -> Result<()> {
        if image.shape() != self.inner.example_shape.as_slice() {
            return Err(RuntimeError::Shape(format!(
                "serve: example shape {:?} does not match the model's per-request shape {:?} \
                 (submit one example, not a batch; `serve::split_examples` splits pre-batched \
                 tensors)",
                image.shape(),
                self.inner.example_shape
            )));
        }
        Ok(())
    }

    /// Submit one [`SloClass::Interactive`] example, blocking while the
    /// admission queue is at `queue_cap` (backpressure). Errors after
    /// shutdown. The flush clock (and `RequestStats::queue_wait`) starts
    /// at *admission*, not at the start of a blocked `submit` call.
    pub fn submit(&self, image: Tensor) -> Result<Pending> {
        self.submit_class(image, SloClass::Interactive)
    }

    /// [`ServeHandle::submit`] with an explicit SLO class: the class's
    /// flush window (interactive — possibly adaptive — vs the longer
    /// batch window) fixes the request's absolute deadline at admission.
    pub fn submit_class(&self, image: Tensor, class: SloClass) -> Result<Pending> {
        self.check_example(&image)?;
        let delay = self.inner.delay.on_arrival(Instant::now(), class);
        let (tx, rx) = mpsc::channel();
        self.inner.queue.push(image, class, delay, tx)?;
        self.count_submit(class);
        Ok(Pending { rx })
    }

    /// Non-blocking [`SloClass::Interactive`] submit: `Ok(None)` when the
    /// queue is full (the backpressure signal; the caller keeps `image`),
    /// `Err` after shutdown. The example is cloned only when it is
    /// actually admitted — a bounced call costs no tensor copy.
    pub fn try_submit(&self, image: &Tensor) -> Result<Option<Pending>> {
        self.try_submit_class(image, SloClass::Interactive)
    }

    /// [`ServeHandle::try_submit`] with an explicit SLO class — the load
    /// shed point for `net::server`: `Ok(None)` is the signal a
    /// `RetryAfter` frame answers.
    pub fn try_submit_class(&self, image: &Tensor, class: SloClass) -> Result<Option<Pending>> {
        self.check_example(image)?;
        let mut rx_slot = None;
        let admitted = self.inner.queue.try_push_with(|| {
            // The arrival is recorded only for admitted requests: a shed
            // burst must not drag the adaptive window toward its floor.
            let now = Instant::now();
            let delay = self.inner.delay.on_arrival(now, class);
            let (tx, rx) = mpsc::channel();
            rx_slot = Some(rx);
            PendingRequest {
                image: image.clone(),
                class,
                enqueued_at: now,
                deadline: now + delay,
                tx,
            }
        })?;
        if admitted {
            self.count_submit(class);
            Ok(rx_slot.map(|rx| Pending { rx }))
        } else {
            self.inner.counters.rejected.fetch_add(1, Ordering::Relaxed);
            Ok(None)
        }
    }

    fn count_submit(&self, class: SloClass) {
        let c = &self.inner.counters;
        c.submitted.fetch_add(1, Ordering::Relaxed);
        match class {
            SloClass::Interactive => c.submitted_interactive.fetch_add(1, Ordering::Relaxed),
            SloClass::Batch => c.submitted_batch.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Point-in-time counters (cheap; safe from any thread).
    ///
    /// The snapshot is **coherent with respect to parameter swaps**: it
    /// holds the swap serialization lock, so `device_loads`, the queue
    /// depth, and the rollout counters are never sampled in the middle of
    /// a multi-device promote/rollback apply loop (previously each field
    /// was read under its own lock, so a mid-swap scrape could pair a
    /// pre-swap load vector with post-swap counters).
    pub fn stats(&self) -> ServeStats {
        let _coherent = match self.inner.swap_lock.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let c = &self.inner.counters;
        let rollout_swap_p99_us = {
            let ring = match c.swap_lat_us.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            let mut lat: Vec<u64> = ring.iter().copied().collect();
            lat.sort_unstable();
            match lat.len() {
                0 => 0,
                n => lat[((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1],
            }
        };
        ServeStats {
            submitted: c.submitted.load(Ordering::Relaxed),
            submitted_interactive: c.submitted_interactive.load(Ordering::Relaxed),
            submitted_batch: c.submitted_batch.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            batches: c.batches.load(Ordering::Relaxed),
            full_flushes: c.full_flushes.load(Ordering::Relaxed),
            deadline_flushes: c.deadline_flushes.load(Ordering::Relaxed),
            drain_flushes: c.drain_flushes.load(Ordering::Relaxed),
            queue_depth: self.inner.queue.depth(),
            device_loads: self.inner.router.loads(),
            current_max_delay: self.inner.delay.current_window(),
            adaptive_delay: self.inner.delay.is_adaptive(),
            memory_traffic: c.mem_traffic.load(Ordering::Relaxed),
            memory_worker_peak: c.mem_worker_peak.load(Ordering::Relaxed),
            rollout_candidates: c.rollout_candidates.load(Ordering::Relaxed),
            rollout_promotions: c.rollout_promotions.load(Ordering::Relaxed),
            rollout_rollbacks: c.rollout_rollbacks.load(Ordering::Relaxed),
            rollout_swap_p99_us,
            closed: self.inner.queue.is_closed(),
        }
    }

    /// Clean shutdown: stop admission (subsequent submits error), flush
    /// and execute everything already admitted (in-flight requests still
    /// get replies), join the batcher and the workers, and return the
    /// final report with the merged per-worker ledger. Subsequent calls
    /// (from any clone) return the same report.
    pub fn shutdown(&self) -> Result<ServeReport> {
        self.inner.queue.close();
        // Tolerate a poisoned lock: a batcher panic re-raised by another
        // clone's shutdown poisons the mutex mid-unwind, and this call must
        // still return a result rather than panic on PoisonError.
        let mut lc = match self.inner.lifecycle.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(batcher) = lc.batcher.take() {
            let batcher_outcome = batcher.join();
            // The batcher closes the pools on exit; join_pools repeats the
            // close in case it died, joins EVERY device pool, and folds
            // the per-device ledgers (merge within a device, max across).
            let (memory, per_device_memory, pool_panic) = self.inner.join_pools();
            if let Err(payload) = batcher_outcome {
                std::panic::resume_unwind(payload);
            }
            if let Some(payload) = pool_panic {
                std::panic::resume_unwind(payload);
            }
            let c = &self.inner.counters;
            lc.report = Some(ServeReport {
                requests: c.completed.load(Ordering::Relaxed),
                batches: c.batches.load(Ordering::Relaxed),
                full_flushes: c.full_flushes.load(Ordering::Relaxed),
                deadline_flushes: c.deadline_flushes.load(Ordering::Relaxed),
                drain_flushes: c.drain_flushes.load(Ordering::Relaxed),
                workers: self.inner.pools.iter().map(|p| p.workers()).sum(),
                devices: self.inner.pools.len(),
                memory,
                per_device_memory,
            });
        }
        lc.report.clone().ok_or_else(|| {
            RuntimeError::Io("serve: shutdown produced no report (prior teardown failed?)".into())
        })
    }
}

/// The batcher thread: drain deadline-coalesced request groups, assemble
/// the padded batch tensor, route it to the **least-loaded device pool**
/// (load = outstanding batches, tracked by the router and drained as each
/// batch finishes); close every pool on exit. Routing never reorders
/// replies — demultiplexing is per-request over each request's own
/// channel, and values are device-independent.
fn batcher_loop(
    queue: &AdmissionQueue,
    pools: &[Arc<WorkerPool>],
    router: &ShardRouter,
    counters: &Counters,
    batch: usize,
    example_shape: &[usize],
) {
    while let Some((requests, reason)) = queue.next_batch(batch) {
        debug_assert!(!requests.is_empty(), "queue flushed an empty batch");
        counters.batches.fetch_add(1, Ordering::Relaxed);
        let flush_counter = match reason {
            FlushReason::Full => &counters.full_flushes,
            FlushReason::Deadline => &counters.deadline_flushes,
            FlushReason::Drain => &counters.drain_flushes,
        };
        flush_counter.fetch_add(1, Ordering::Relaxed);
        let images = assemble(&requests, batch, example_shape);
        let device = router.acquire(1);
        let load = router.ticket(device, 1);
        pools[device].submit(BatchJob { images, requests }, load);
    }
    for pool in pools {
        pool.close();
    }
}

/// Stack request examples into a zero-padded `(batch, ...)` tensor,
/// submission order preserved as row order.
fn assemble(requests: &[PendingRequest], batch: usize, example_shape: &[usize]) -> Tensor {
    let ex_len: usize = example_shape.iter().product();
    let mut shape = Vec::with_capacity(example_shape.len() + 1);
    shape.push(batch);
    shape.extend_from_slice(example_shape);
    let mut images = Tensor::zeros(&shape);
    let data = images.data_mut();
    for (i, req) in requests.iter().enumerate() {
        debug_assert_eq!(req.image.data().len(), ex_len, "example validated at submit");
        data[i * ex_len..(i + 1) * ex_len].copy_from_slice(req.image.data());
    }
    images
}

/// Split a pre-batched `(B, ...)` tensor into its B per-example tensors —
/// the adapter from the batch-shaped datasets to the single-request
/// serving API.
pub fn split_examples(batch: &Tensor) -> Result<Vec<Tensor>> {
    if batch.rank() < 2 {
        return Err(RuntimeError::Shape(format!(
            "split_examples wants a rank >= 2 batch tensor, got {:?}",
            batch.shape()
        )));
    }
    let ex_shape: Vec<usize> = batch.shape()[1..].to_vec();
    let ex_len: usize = ex_shape.iter().product::<usize>().max(1);
    batch
        .data()
        .chunks(ex_len)
        .map(|chunk| {
            Tensor::from_vec(ex_shape.clone(), chunk.to_vec())
                .map_err(|e| RuntimeError::Shape(e.to_string()))
        })
        .collect()
}

/// The engine-backed runner behind
/// [`Session::serve`](crate::api::Session::serve): a snapshot of the
/// session's parameters over the shared [`ExecutionCore`], executing
/// exactly the per-batch computation of
/// [`Session::predict_batches`](crate::api::Session::predict_batches)
/// (inference forward + host-side head), so served values are
/// bit-identical to the pre-batched path.
pub struct SessionRunner {
    core: Arc<ExecutionCore>,
    /// The swappable weight snapshot: readers clone the `Arc` once per
    /// batch, so a concurrent [`BatchRunner::swap_params`] never tears a
    /// batch mid-execution and costs no per-batch tensor copies.
    params: RwLock<Arc<Vec<Tensor>>>,
}

impl SessionRunner {
    /// Adopt a shared `params` snapshot (serving is read-only; later
    /// training steps on the originating session do not affect a running
    /// pipeline unless explicitly rolled out via
    /// [`ServeHandle::swap_params`]). All device runners of one session
    /// hold the **same** `Arc` — one snapshot, N pointers.
    pub fn new(core: Arc<ExecutionCore>, params: Arc<Vec<Tensor>>) -> Self {
        Self { core, params: RwLock::new(params) }
    }

    /// The current snapshot (an `Arc` clone; cheap, lock held briefly).
    fn snapshot(&self) -> Arc<Vec<Tensor>> {
        match self.params.read() {
            Ok(guard) => guard.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }
}

impl BatchRunner for SessionRunner {
    fn batch_size(&self) -> usize {
        self.core.cfg.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        let cfg = &self.core.cfg;
        vec![cfg.image, cfg.image, 3]
    }

    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction> {
        // One snapshot per batch (hot-swap applies between batches). The
        // shared per-batch inference unit (api::session::infer_batch)
        // keeps the bit-identity contract with `predict_batches`
        // structural, not a convention kept in sync by hand.
        let params = self.snapshot();
        infer_batch(&self.core, &params, images, ledger)
    }

    fn swap_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        let current = self.snapshot();
        check_swap_shapes(&params, &current)?;
        let mut guard = match self.params.write() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        *guard = params;
        Ok(())
    }

    fn validate_swap(&self, params: &[Tensor]) -> Result<()> {
        check_swap_shapes(params, &self.snapshot())
    }

    fn compile_stats(&self) -> Option<CompileStatsSnapshot> {
        self.core.reg.compile_stats()
    }
}

/// Shared hot-swap validation: the replacement must match the current
/// snapshot tensor-for-tensor in count and shape.
fn check_swap_shapes(new: &[Tensor], current: &[Tensor]) -> Result<()> {
    if new.len() != current.len() {
        return Err(RuntimeError::Shape(format!(
            "serve: hot-swap expects {} parameter tensors, got {}",
            current.len(),
            new.len()
        )));
    }
    for (i, (n, c)) in new.iter().zip(current.iter()).enumerate() {
        if n.shape() != c.shape() {
            return Err(RuntimeError::Shape(format!(
                "serve: hot-swap parameter {i} has shape {:?}, expected {:?}",
                n.shape(),
                c.shape()
            )));
        }
    }
    Ok(())
}

/// Host-only demo model: global-average-pool + dense head over activation
/// shaped inputs — the post-XLA tail of every predict call, with fixed
/// deterministic weights. Works on the vendored xla stub (no artifacts),
/// so the serving pipeline, the `serve` CLI subcommand, and the
/// `serve_throughput` bench are exercisable on every build.
pub struct HostTailRunner {
    batch: usize,
    shape: Vec<usize>,
    /// `(w, bias)` behind one lock so a hot-swap can never tear the pair.
    head: RwLock<Arc<(Tensor, Tensor)>>,
}

impl HostTailRunner {
    /// `batch` examples of shape `(h, h, c)` through a `k`-class head.
    pub fn new(batch: usize, h: usize, c: usize, k: usize) -> Self {
        let (batch, h, c, k) = (batch.max(1), h.max(1), c.max(1), k.max(1));
        // Fixed, deterministic head weights: varied per entry so distinct
        // activations map to distinct classes.
        let wdata: Vec<f32> = (0..c * k).map(|i| ((i % 7) as f32 - 3.0) * 0.05).collect();
        let bdata: Vec<f32> = (0..k).map(|j| j as f32 * 0.01).collect();
        let w = Tensor::from_vec(vec![c, k], wdata).expect("head weight shape");
        let bias = Tensor::from_vec(vec![k], bdata).expect("head bias shape");
        Self { batch, shape: vec![h, h, c], head: RwLock::new(Arc::new((w, bias))) }
    }

    fn head(&self) -> Arc<(Tensor, Tensor)> {
        match self.head.read() {
            Ok(guard) => guard.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }
}

impl BatchRunner for HostTailRunner {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn example_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn run(&self, images: &Tensor, ledger: &mut MemoryLedger) -> Result<Prediction> {
        let head = self.head();
        let id = ledger.alloc(images.byte_size(), Category::Transient);
        let t = Instant::now();
        let out = head_logits(images, &head.0, &head.1);
        ledger.free(id);
        let logits = out?;
        let classes = argmax_rows(&logits);
        let seconds = t.elapsed().as_secs_f64();
        Ok(Prediction {
            classes,
            logits,
            stats: PredictStats {
                batch: self.batch,
                seconds,
                examples_per_sec: self.batch as f64 / seconds.max(1e-12),
                peak_activation_bytes: images.byte_size(),
            },
        })
    }

    /// The demo model's swappable state is its head: expects exactly
    /// `[w (c, k), bias (k)]` matching the current shapes. Clones the two
    /// (small) tensors out of the shared snapshot into the head pair.
    fn swap_params(&self, params: Arc<Vec<Tensor>>) -> Result<()> {
        self.validate_swap(&params)?;
        let (w, bias) = (params[0].clone(), params[1].clone());
        let mut guard = match self.head.write() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        *guard = Arc::new((w, bias));
        Ok(())
    }

    fn validate_swap(&self, params: &[Tensor]) -> Result<()> {
        let current = self.head();
        let current_pair = [current.0.clone(), current.1.clone()];
        check_swap_shapes(params, &current_pair)
    }
}

// The handle is the unit shared across client threads; a regression to
// non-Sync internals must fail the build here, not at a call site.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ServeHandle>();
    assert_send_sync::<ServeReply>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_examples_round_trips_rows() {
        let batch = Tensor::from_vec(vec![3, 2], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let rows = split_examples(&batch).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].shape(), &[2]);
        assert_eq!(rows[2].data(), &[4.0, 5.0]);
        assert!(split_examples(&Tensor::zeros(&[4])).is_err());
    }

    #[test]
    fn host_tail_serve_matches_direct_run() {
        let runner = HostTailRunner::new(4, 2, 3, 5);
        let examples: Vec<Tensor> = (0..4)
            .map(|i| {
                let len = 2 * 2 * 3;
                let data = (0..len).map(|j| ((i * 31 + j) as f32) * 0.01).collect();
                Tensor::from_vec(vec![2, 2, 3], data).unwrap()
            })
            .collect();
        // Direct: stack the 4 examples and run the batch once.
        let mut stacked = Tensor::zeros(&[4, 2, 2, 3]);
        for (i, ex) in examples.iter().enumerate() {
            stacked.data_mut()[i * 12..(i + 1) * 12].copy_from_slice(ex.data());
        }
        let mut ledger = MemoryLedger::new();
        let direct = runner.run(&stacked, &mut ledger).unwrap();

        let runner = Arc::new(HostTailRunner::new(4, 2, 3, 5));
        let handle = ServeHandle::spawn(runner, ServeConfig::default().workers(2)).unwrap();
        let pendings: Vec<Pending> =
            examples.iter().map(|ex| handle.submit(ex.clone()).unwrap()).collect();
        for (i, pending) in pendings.into_iter().enumerate() {
            let reply = pending.wait().unwrap();
            assert_eq!(reply.class, direct.classes[i], "request {i}");
            assert_eq!(reply.logits.data(), &direct.logits.data()[i * 5..(i + 1) * 5]);
            assert!((1..=4).contains(&reply.stats.batch_fill));
            assert_eq!(reply.stats.batch_size, 4);
        }
        let report = handle.shutdown().unwrap();
        assert_eq!(report.requests, 4);
        assert!(report.batches >= 1);
    }

    #[test]
    fn slo_classes_are_counted_and_batch_class_gets_replies() {
        let runner = Arc::new(HostTailRunner::new(4, 2, 3, 5));
        let handle =
            ServeHandle::spawn(runner, ServeConfig::default().batch_delay_ms(10)).unwrap();
        let ex = Tensor::full(&[2, 2, 3], 0.25);
        let a = handle.submit_class(ex.clone(), SloClass::Batch).unwrap();
        let b = handle.try_submit_class(&ex, SloClass::Interactive).unwrap().unwrap();
        assert!(a.wait().is_ok());
        assert!(b.wait().is_ok());
        let stats = handle.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.submitted_batch, 1);
        assert_eq!(stats.submitted_interactive, 1);
        assert!(!stats.adaptive_delay);
        assert_eq!(stats.current_max_delay, Duration::from_millis(5));
        handle.shutdown().unwrap();
    }

    #[test]
    fn submit_rejects_wrong_shapes_and_post_shutdown() {
        let runner = Arc::new(HostTailRunner::new(2, 2, 2, 3));
        let handle = ServeHandle::spawn(runner, ServeConfig::default()).unwrap();
        assert!(handle.submit(Tensor::zeros(&[3, 3, 3])).is_err());
        assert!(handle.submit(Tensor::zeros(&[2, 2, 2, 2])).is_err());
        handle.shutdown().unwrap();
        assert!(handle.submit(Tensor::zeros(&[2, 2, 2])).is_err());
        // A second shutdown returns the cached report.
        assert_eq!(handle.shutdown().unwrap().requests, 0);
    }
}
