//! Persistent worker pool for the serving path — since PR 4 a thin
//! serving-specific skin over the generalized
//! [`crate::util::pool::PersistentPool`], which carries the long-lived
//! pinned threads, the bounded job queue (at most `workers` jobs waiting
//! beyond those executing — the second stage of the serve path's
//! end-to-end backpressure), per-worker state and the drain-on-close,
//! panic-safe join protocol.
//!
//! What stays here is the serving semantics: each worker owns a private
//! [`MemoryLedger`] for its whole lifetime (merged at
//! [`WorkerPool::join`]), an assembled batch executes through the shared
//! [`BatchRunner`], and the batch's replies demultiplex back to the
//! per-request channels in submission order. A *panicking* runner is
//! contained to error replies for that batch; a job submitted after
//! shutdown is dropped cleanly, which disconnects its reply channels so
//! every waiter sees an error instead of a hang.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::memory::{Category, MemoryLedger};
use crate::runtime::RuntimeError;
use crate::tensor::Tensor;
use crate::util::pool::{Job, LoadTicket, PersistentPool};

use super::queue::PendingRequest;
use super::{BatchRunner, Counters, RequestStats, ServeReply};

/// One assembled batch: the padded `(B, ...)` tensor plus the admitted
/// requests occupying its leading rows, in submission order.
pub(crate) struct BatchJob {
    pub images: Tensor,
    pub requests: Vec<PendingRequest>,
}

/// Long-lived worker threads executing [`BatchJob`]s via **one device's**
/// [`BatchRunner`], on the generalized persistent pool with one
/// [`MemoryLedger`] per worker. A multi-device pipeline runs one
/// `WorkerPool` per device; the batcher routes filled batches across them
/// by load (rust/DESIGN.md §6d).
pub(crate) struct WorkerPool {
    pool: PersistentPool<MemoryLedger>,
    runner: Arc<dyn BatchRunner>,
    counters: Arc<Counters>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads for device `device`, each owning
    /// a fresh ledger for its whole lifetime.
    pub fn new(
        runner: Arc<dyn BatchRunner>,
        workers: usize,
        counters: Arc<Counters>,
        device: usize,
    ) -> std::io::Result<Self> {
        let pool =
            PersistentPool::new(workers, &format!("anode-serve-d{device}"), MemoryLedger::new)?;
        Ok(Self { pool, runner, counters })
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Hand a job to the pool, blocking while `workers` jobs already wait
    /// (backpressure toward the batcher and, through the admission queue,
    /// toward submitters). The router `load` ticket drops — draining this
    /// batch's load from the device — when the batch finishes executing.
    /// If the pool is already closed the job is dropped, which disconnects
    /// its per-request reply channels (every waiter gets a clean "dropped
    /// before a reply" error, never a hang) and releases the load ticket.
    pub fn submit(&self, job: BatchJob, load: LoadTicket) {
        let runner = self.runner.clone();
        let counters = self.counters.clone();
        let work: Job<MemoryLedger> = Box::new(move |ledger| {
            execute(runner.as_ref(), job, ledger, &counters);
            drop(load);
        });
        let _ = self.pool.submit(work);
    }

    /// Close the job queue: workers finish what is queued, then exit.
    /// Idempotent.
    pub fn close(&self) {
        self.pool.close();
    }

    /// Join every worker and merge their ledgers. Panics from workers are
    /// re-raised *after* all threads have been joined.
    pub fn join(&self) -> MemoryLedger {
        let (merged, panic) = self.join_collect();
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        merged
    }

    /// Non-propagating join for teardown paths that must not panic (Drop):
    /// returns the merged ledger plus the first panic payload, if any.
    pub fn join_collect(&self) -> (MemoryLedger, Option<Box<dyn std::any::Any + Send>>) {
        let (ledgers, panic) = self.pool.join_collect();
        let mut merged = MemoryLedger::new();
        for ledger in &ledgers {
            merged.merge(ledger);
        }
        (merged, panic)
    }
}

/// Run one batch and demultiplex per-request replies (submission order)
/// with queue-wait + execute latency attached. A *panicking* runner is
/// contained: the panic becomes an error reply for every request in the
/// batch and the worker stays alive — a dead worker with queued jobs would
/// stall the whole admission pipeline.
fn execute(runner: &dyn BatchRunner, job: BatchJob, ledger: &mut MemoryLedger, c: &Counters) {
    let fill = job.requests.len();
    let capacity = runner.batch_size();
    let started = Instant::now();
    let traffic_before = ledger.total_traffic();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        runner.run(&job.images, ledger)
    }));
    let execute = started.elapsed();
    // Live ledger view for the metrics endpoint: per-worker ledgers are
    // thread-owned until shutdown folds them, so publish this batch's
    // traffic delta and the worker's running peak through the shared
    // counters instead.
    let traffic = ledger.total_traffic().saturating_sub(traffic_before);
    c.mem_traffic.fetch_add(traffic, Ordering::Relaxed);
    c.mem_worker_peak.fetch_max(ledger.peak_bytes() as u64, Ordering::Relaxed);
    let result = caught.unwrap_or_else(|payload| {
        // The runner unwound mid-batch, skipping its transient free(s).
        // Release the leaked live transients so this worker's ledger keeps
        // accurate current/peak accounting for every later batch (between
        // batches a healthy worker holds no live transient allocations).
        ledger.free_category(Category::Transient);
        Err(RuntimeError::Io(format!(
            "serve: batch runner panicked: {}",
            panic_message(payload.as_ref())
        )))
    });
    match result {
        Ok(pred) => {
            let k = *pred.logits.shape().last().unwrap_or(&1);
            let data = pred.logits.data();
            if pred.classes.len() < fill || data.len() < fill * k.max(1) {
                let msg = format!(
                    "serve: runner returned {} classes / {} logit rows for a batch of {fill}",
                    pred.classes.len(),
                    data.len() / k.max(1)
                );
                c.completed.fetch_add(fill as u64, Ordering::Relaxed);
                for req in job.requests {
                    let _ = req.tx.send(Err(RuntimeError::Shape(msg.clone())));
                }
                return;
            }
            for (i, req) in job.requests.into_iter().enumerate() {
                let stats = RequestStats {
                    queue_wait: started.saturating_duration_since(req.enqueued_at),
                    execute,
                    batch_fill: fill,
                    batch_size: capacity,
                };
                c.note_swap_latency(stats.total());
                let reply = Tensor::from_vec(vec![k], data[i * k..(i + 1) * k].to_vec())
                    .map(|logits| ServeReply { class: pred.classes[i], logits, stats })
                    .map_err(|e| RuntimeError::Shape(e.to_string()));
                c.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.tx.send(reply);
            }
        }
        Err(e) => {
            c.completed.fetch_add(fill as u64, Ordering::Relaxed);
            for req in job.requests {
                let _ = req.tx.send(Err(e.clone()));
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}
