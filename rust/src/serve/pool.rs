//! Persistent worker pool for the serving path.
//!
//! Unlike [`crate::util::pool`], which spawns scoped threads per call,
//! these workers are **long-lived**: spawned once when the
//! [`crate::serve::ServeHandle`] starts, pinned to the pool until
//! shutdown, each owning a private [`MemoryLedger`] for its whole
//! lifetime. Assembled batches arrive on a bounded job queue (at most
//! `workers` jobs waiting beyond those executing — the second stage of the
//! serve path's end-to-end backpressure), and each worker demultiplexes
//! its batch's replies back to the per-request channels in submission
//! order.
//!
//! Shutdown protocol: [`WorkerPool::close`] marks the queue closed and
//! wakes everyone; workers finish the jobs already queued (drain, never
//! drop), then return their ledgers; [`WorkerPool::join`] collects and
//! merges them, re-raising any worker panic *after* all remaining workers
//! have been joined so a panicking batch cannot leak threads.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::memory::{Category, MemoryLedger};
use crate::runtime::RuntimeError;
use crate::tensor::Tensor;

use super::queue::PendingRequest;
use super::{BatchRunner, Counters, RequestStats, ServeReply};

/// One assembled batch: the padded `(B, ...)` tensor plus the admitted
/// requests occupying its leading rows, in submission order.
pub(crate) struct BatchJob {
    pub images: Tensor,
    pub requests: Vec<PendingRequest>,
}

struct JobState {
    queue: VecDeque<BatchJob>,
    closed: bool,
}

struct PoolInner {
    runner: Arc<dyn BatchRunner>,
    counters: Arc<Counters>,
    jobs: Mutex<JobState>,
    job_ready: Condvar,
    job_space: Condvar,
    /// Bound on *waiting* jobs (executing jobs are not counted): one spare
    /// batch per worker keeps workers fed without unbounded buffering.
    cap: usize,
}

/// Long-lived worker threads executing [`BatchJob`]s via the shared
/// [`BatchRunner`].
pub(crate) struct WorkerPool {
    inner: Arc<PoolInner>,
    handles: Mutex<Vec<JoinHandle<MemoryLedger>>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads.
    pub fn new(
        runner: Arc<dyn BatchRunner>,
        workers: usize,
        counters: Arc<Counters>,
    ) -> std::io::Result<Self> {
        let workers = workers.max(1);
        let inner = Arc::new(PoolInner {
            runner,
            counters,
            jobs: Mutex::new(JobState { queue: VecDeque::new(), closed: false }),
            job_ready: Condvar::new(),
            job_space: Condvar::new(),
            cap: workers,
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let worker_inner = inner.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("anode-serve-worker-{i}"))
                .spawn(move || worker_loop(&worker_inner));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partially spawned pool before propagating:
                    // without a close, the earlier workers would block on
                    // job_ready forever — a thread leak per failed spawn.
                    inner.jobs.lock().unwrap().closed = true;
                    inner.job_ready.notify_all();
                    inner.job_space.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Self { inner, handles: Mutex::new(handles), workers })
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Hand a job to the pool, blocking while `cap` jobs already wait
    /// (backpressure toward the batcher and, through the admission queue,
    /// toward submitters). If the pool is closed the job's requests are
    /// failed cleanly instead of being dropped silently.
    pub fn submit(&self, job: BatchJob) {
        let mut st = self.inner.jobs.lock().unwrap();
        loop {
            if st.closed {
                drop(st);
                fail_requests(job.requests, "serve: worker pool is shut down");
                return;
            }
            if st.queue.len() < self.inner.cap {
                st.queue.push_back(job);
                self.inner.job_ready.notify_one();
                return;
            }
            st = self.inner.job_space.wait(st).unwrap();
        }
    }

    /// Close the job queue: workers finish what is queued, then exit.
    /// Idempotent.
    pub fn close(&self) {
        let mut st = self.inner.jobs.lock().unwrap();
        st.closed = true;
        self.inner.job_ready.notify_all();
        self.inner.job_space.notify_all();
    }

    /// Join every worker and merge their ledgers. Panics from workers are
    /// re-raised *after* all threads have been joined.
    pub fn join(&self) -> MemoryLedger {
        let (merged, panic) = self.join_collect();
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
        merged
    }

    /// Non-propagating join for teardown paths that must not panic (Drop):
    /// returns the merged ledger plus the first panic payload, if any.
    pub fn join_collect(&self) -> (MemoryLedger, Option<Box<dyn std::any::Any + Send>>) {
        let handles: Vec<JoinHandle<MemoryLedger>> = {
            let mut guard = match self.handles.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            guard.drain(..).collect()
        };
        let mut merged = MemoryLedger::new();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            match h.join() {
                Ok(ledger) => merged.merge(&ledger),
                Err(p) => {
                    if panic.is_none() {
                        panic = Some(p);
                    }
                }
            }
        }
        (merged, panic)
    }
}

fn worker_loop(inner: &PoolInner) -> MemoryLedger {
    let mut ledger = MemoryLedger::new();
    loop {
        let job = {
            let mut st = inner.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.queue.pop_front() {
                    inner.job_space.notify_one();
                    break job;
                }
                if st.closed {
                    return ledger;
                }
                st = inner.job_ready.wait(st).unwrap();
            }
        };
        execute(inner.runner.as_ref(), job, &mut ledger, &inner.counters);
    }
}

/// Run one batch and demultiplex per-request replies (submission order)
/// with queue-wait + execute latency attached. A *panicking* runner is
/// contained: the panic becomes an error reply for every request in the
/// batch and the worker stays alive — a dead worker with queued jobs would
/// stall the whole admission pipeline.
fn execute(runner: &dyn BatchRunner, job: BatchJob, ledger: &mut MemoryLedger, c: &Counters) {
    let fill = job.requests.len();
    let capacity = runner.batch_size();
    let started = Instant::now();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        runner.run(&job.images, ledger)
    }));
    let execute = started.elapsed();
    let result = caught.unwrap_or_else(|payload| {
        // The runner unwound mid-batch, skipping its transient free(s).
        // Release the leaked live transients so this worker's ledger keeps
        // accurate current/peak accounting for every later batch (between
        // batches a healthy worker holds no live transient allocations).
        ledger.free_category(Category::Transient);
        Err(RuntimeError::Io(format!(
            "serve: batch runner panicked: {}",
            panic_message(payload.as_ref())
        )))
    });
    match result {
        Ok(pred) => {
            let k = *pred.logits.shape().last().unwrap_or(&1);
            let data = pred.logits.data();
            if pred.classes.len() < fill || data.len() < fill * k.max(1) {
                let msg = format!(
                    "serve: runner returned {} classes / {} logit rows for a batch of {fill}",
                    pred.classes.len(),
                    data.len() / k.max(1)
                );
                c.completed.fetch_add(fill as u64, Ordering::Relaxed);
                for req in job.requests {
                    let _ = req.tx.send(Err(RuntimeError::Shape(msg.clone())));
                }
                return;
            }
            for (i, req) in job.requests.into_iter().enumerate() {
                let stats = RequestStats {
                    queue_wait: started.saturating_duration_since(req.enqueued_at),
                    execute,
                    batch_fill: fill,
                    batch_size: capacity,
                };
                let reply = Tensor::from_vec(vec![k], data[i * k..(i + 1) * k].to_vec())
                    .map(|logits| ServeReply { class: pred.classes[i], logits, stats })
                    .map_err(|e| RuntimeError::Shape(e.to_string()));
                c.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.tx.send(reply);
            }
        }
        Err(e) => {
            c.completed.fetch_add(fill as u64, Ordering::Relaxed);
            for req in job.requests {
                let _ = req.tx.send(Err(e.clone()));
            }
        }
    }
}

fn fail_requests(requests: Vec<PendingRequest>, msg: &str) {
    for req in requests {
        let _ = req.tx.send(Err(RuntimeError::Io(msg.into())));
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}
