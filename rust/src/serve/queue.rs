//! Bounded admission queue with deadline-batched draining.
//!
//! Single-producer-*many* (any number of [`crate::serve::ServeHandle`]
//! clones submit), single-consumer (the batcher thread): requests enter
//! FIFO through [`AdmissionQueue::push`]/[`AdmissionQueue::try_push_with`]
//! and leave in batches through [`AdmissionQueue::next_batch`], which
//! flushes on whichever comes first — the batch filling up, the oldest
//! request reaching `max_delay`, or shutdown (which drains the remainder).
//!
//! The queue is bounded at `cap` pending requests: `push` blocks (and
//! `try_push_with` declines without even constructing the request) while
//! it is full, which is the backpressure mechanism — a slow pool
//! propagates to slow admission instead of unbounded buffering.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::ServeReply;

/// One admitted request waiting for batch assembly: the example tensor,
/// its admission timestamp (the deadline clock and the queue-wait origin),
/// and the channel its reply is demultiplexed onto.
pub(crate) struct PendingRequest {
    pub image: Tensor,
    pub enqueued_at: Instant,
    pub tx: mpsc::Sender<Result<ServeReply>>,
}

/// Why a batch left the queue (per-flush accounting on the serve handle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum FlushReason {
    /// The batch filled to the AOT-compiled size.
    Full,
    /// The oldest request reached `max_delay`; a partial batch flushed.
    Deadline,
    /// Shutdown drained the remaining requests.
    Drain,
}

struct QueueState {
    pending: VecDeque<PendingRequest>,
    closed: bool,
}

/// The bounded request queue between submitters and the batcher thread.
pub(crate) struct AdmissionQueue {
    cap: usize,
    state: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            state: Mutex::new(QueueState { pending: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Admit a request, blocking while the queue is at capacity. Errors if
    /// the queue has been closed (shutdown), including while blocked.
    ///
    /// The request (and its `enqueued_at` deadline anchor) is constructed
    /// only once capacity is granted: time a caller spends *blocked* here
    /// must not burn the `max_delay` window, or a saturated pipeline with
    /// `cap < batch` would degenerate into immediate near-empty deadline
    /// flushes.
    pub fn push(&self, image: Tensor, tx: mpsc::Sender<Result<ServeReply>>) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(RuntimeError::Io("serve: handle is shut down".into()));
            }
            if st.pending.len() < self.cap {
                st.pending.push_back(PendingRequest { image, enqueued_at: Instant::now(), tx });
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking admission: `Ok(true)` on success, `Ok(false)` when the
    /// queue is full (backpressure), `Err` when closed. The request is
    /// built by `make` only once capacity is confirmed, so a bounced
    /// submission never pays for constructing (cloning) it.
    pub fn try_push_with(&self, make: impl FnOnce() -> PendingRequest) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(RuntimeError::Io("serve: handle is shut down".into()));
        }
        if st.pending.len() >= self.cap {
            return Ok(false);
        }
        st.pending.push_back(make());
        self.not_empty.notify_one();
        Ok(true)
    }

    /// Batcher side: block until a batch is ready and drain it. Returns up
    /// to `batch` requests in submission order, with the reason the flush
    /// fired, or `None` once the queue is closed *and* empty (terminate).
    pub fn next_batch(
        &self,
        batch: usize,
        max_delay: Duration,
    ) -> Option<(Vec<PendingRequest>, FlushReason)> {
        let batch = batch.max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            while st.pending.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
            // The deadline is anchored on the *oldest* request: no admitted
            // request waits in the queue longer than `max_delay`.
            let deadline = st.pending.front().expect("non-empty queue").enqueued_at + max_delay;
            loop {
                if st.pending.len() >= batch {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Full));
                }
                if st.closed {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Drain));
                }
                let now = Instant::now();
                if now >= deadline {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Deadline));
                }
                let (guard, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if st.pending.is_empty() {
                    // Defensive (single consumer): re-anchor the deadline.
                    break;
                }
            }
        }
    }

    fn drain_locked(&self, st: &mut QueueState, batch: usize) -> Vec<PendingRequest> {
        let n = batch.min(st.pending.len());
        let out: Vec<PendingRequest> = st.pending.drain(..n).collect();
        // Space freed: wake every blocked submitter (more than one slot may
        // have opened).
        self.not_full.notify_all();
        out
    }

    /// Close the queue: subsequent `push`/`try_push_with` error, blocked pushers
    /// wake with an error, and the batcher drains what remains. Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Requests currently waiting for batch assembly.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// Has [`AdmissionQueue::close`] been called?
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(v: f32) -> (PendingRequest, mpsc::Receiver<Result<ServeReply>>) {
        let (tx, rx) = mpsc::channel();
        let image = Tensor::full(&[2], v);
        (PendingRequest { image, enqueued_at: Instant::now(), tx }, rx)
    }

    fn push(q: &AdmissionQueue, v: f32) -> Result<()> {
        let (tx, _rx) = mpsc::channel();
        q.push(Tensor::full(&[2], v), tx)
    }

    #[test]
    fn full_batch_drains_in_fifo_order() {
        let q = AdmissionQueue::new(8);
        for v in 0..4 {
            push(&q, v as f32).unwrap();
        }
        let (batch, reason) = q.next_batch(4, Duration::from_secs(10)).unwrap();
        assert_eq!(reason, FlushReason::Full);
        let values: Vec<f32> = batch.iter().map(|r| r.image.data()[0]).collect();
        assert_eq!(values, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q = AdmissionQueue::new(8);
        push(&q, 7.0).unwrap();
        let t0 = Instant::now();
        let (batch, reason) = q.next_batch(4, Duration::from_millis(30)).unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(20), "flushed before the deadline");
    }

    #[test]
    fn try_push_reports_full_and_close_drains() {
        let q = AdmissionQueue::new(2);
        let (a, _arx) = req(1.0);
        let (b, _brx) = req(2.0);
        assert!(q.try_push_with(|| a).unwrap());
        assert!(q.try_push_with(|| b).unwrap());
        // Full: the constructor must not even run.
        let accepted = q.try_push_with(|| unreachable!("constructed despite a full queue"));
        assert!(!accepted.unwrap());
        q.close();
        assert!(push(&q, 4.0).is_err());
        let (batch, reason) = q.next_batch(4, Duration::from_secs(10)).unwrap();
        assert_eq!(reason, FlushReason::Drain);
        assert_eq!(batch.len(), 2);
        assert!(q.next_batch(4, Duration::from_secs(10)).is_none());
    }
}
