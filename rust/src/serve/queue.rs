//! Bounded admission queue with deadline-batched draining.
//!
//! Single-producer-*many* (any number of [`crate::serve::ServeHandle`]
//! clones submit), single-consumer (the batcher thread): requests enter
//! FIFO through [`AdmissionQueue::push`]/[`AdmissionQueue::try_push_with`]
//! and leave in batches through [`AdmissionQueue::next_batch`], which
//! flushes on whichever comes first — the batch filling up, the earliest
//! pending *deadline* arriving, or shutdown (which drains the remainder).
//!
//! Deadlines are **per request** (SLO-aware since PR 6): every admitted
//! request carries the absolute instant by which it must be flushed,
//! computed at admission from its [`SloClass`](crate::serve::SloClass)'s
//! delay window (interactive requests carry the — possibly adaptive —
//! flush window; batch-class requests a longer one). The batcher flushes
//! when the *minimum* pending deadline arrives, so a late-arriving
//! interactive request can pull a partial batch out from under older
//! batch-class requests, while a queue of only batch-class work coalesces
//! for longer. Draining stays strictly FIFO: deadlines decide *when* a
//! flush fires, never which requests ride in it.
//!
//! The queue is bounded at `cap` pending requests: `push` blocks (and
//! `try_push_with` declines without even constructing the request) while
//! it is full, which is the backpressure mechanism — a slow pool
//! propagates to slow admission instead of unbounded buffering.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::{Result, RuntimeError};
use crate::tensor::Tensor;

use super::{ServeReply, SloClass};

/// One admitted request waiting for batch assembly: the example tensor,
/// its admission timestamp (the queue-wait origin), the absolute flush
/// deadline derived from its SLO class at admission, and the channel its
/// reply is demultiplexed onto.
pub(crate) struct PendingRequest {
    pub image: Tensor,
    pub class: SloClass,
    pub enqueued_at: Instant,
    /// Flush-by instant: `enqueued_at + delay(class)`, resolved at
    /// admission (so an adaptive window change never retroactively moves
    /// already-admitted deadlines).
    pub deadline: Instant,
    pub tx: mpsc::Sender<Result<ServeReply>>,
}

/// Why a batch left the queue (per-flush accounting on the serve handle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum FlushReason {
    /// The batch filled to the AOT-compiled size.
    Full,
    /// The earliest pending deadline arrived; a partial batch flushed.
    Deadline,
    /// Shutdown drained the remaining requests.
    Drain,
}

struct QueueState {
    pending: VecDeque<PendingRequest>,
    closed: bool,
}

/// The bounded request queue between submitters and the batcher thread.
pub(crate) struct AdmissionQueue {
    cap: usize,
    state: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            state: Mutex::new(QueueState { pending: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Admit a request, blocking while the queue is at capacity. Errors if
    /// the queue has been closed (shutdown), including while blocked.
    ///
    /// The request (and its deadline anchor) is constructed only once
    /// capacity is granted: time a caller spends *blocked* here must not
    /// burn the flush window, or a saturated pipeline with `cap < batch`
    /// would degenerate into immediate near-empty deadline flushes.
    pub fn push(
        &self,
        image: Tensor,
        class: SloClass,
        delay: Duration,
        tx: mpsc::Sender<Result<ServeReply>>,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(RuntimeError::Io("serve: handle is shut down".into()));
            }
            if st.pending.len() < self.cap {
                let now = Instant::now();
                st.pending.push_back(PendingRequest {
                    image,
                    class,
                    enqueued_at: now,
                    deadline: now + delay,
                    tx,
                });
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking admission: `Ok(true)` on success, `Ok(false)` when the
    /// queue is full (backpressure), `Err` when closed. The request is
    /// built by `make` only once capacity is confirmed, so a bounced
    /// submission never pays for constructing (cloning) it.
    pub fn try_push_with(&self, make: impl FnOnce() -> PendingRequest) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(RuntimeError::Io("serve: handle is shut down".into()));
        }
        if st.pending.len() >= self.cap {
            return Ok(false);
        }
        st.pending.push_back(make());
        self.not_empty.notify_one();
        Ok(true)
    }

    /// Batcher side: block until a batch is ready and drain it. Returns up
    /// to `batch` requests in submission order, with the reason the flush
    /// fired, or `None` once the queue is closed *and* empty (terminate).
    pub fn next_batch(&self, batch: usize) -> Option<(Vec<PendingRequest>, FlushReason)> {
        let batch = batch.max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            while st.pending.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
            loop {
                if st.pending.len() >= batch {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Full));
                }
                if st.closed {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Drain));
                }
                // Flush at the *earliest* pending deadline: no admitted
                // request waits past its own SLO window. The scan is
                // O(pending) under the lock, bounded by `cap` — and a new
                // admission wakes this wait, so a tighter deadline arriving
                // mid-wait re-shortens the timeout below.
                let deadline = min_deadline(&st.pending).expect("non-empty queue");
                let now = Instant::now();
                if now >= deadline {
                    return Some((self.drain_locked(&mut st, batch), FlushReason::Deadline));
                }
                let (guard, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if st.pending.is_empty() {
                    // Defensive (single consumer): re-anchor the deadline.
                    break;
                }
            }
        }
    }

    fn drain_locked(&self, st: &mut QueueState, batch: usize) -> Vec<PendingRequest> {
        let n = batch.min(st.pending.len());
        let out: Vec<PendingRequest> = st.pending.drain(..n).collect();
        // Space freed: wake every blocked submitter (more than one slot may
        // have opened).
        self.not_full.notify_all();
        out
    }

    /// Close the queue: subsequent `push`/`try_push_with` error, blocked pushers
    /// wake with an error, and the batcher drains what remains. Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Requests currently waiting for batch assembly.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// Has [`AdmissionQueue::close`] been called?
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// Earliest deadline over the pending requests (`None` when empty). Not
/// simply the front's: a short-window interactive request admitted behind
/// a long-window batch request owns the earlier deadline.
fn min_deadline(pending: &VecDeque<PendingRequest>) -> Option<Instant> {
    pending.iter().map(|r| r.deadline).min()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(
        v: f32,
        class: SloClass,
        delay: Duration,
    ) -> (PendingRequest, mpsc::Receiver<Result<ServeReply>>) {
        let (tx, rx) = mpsc::channel();
        let image = Tensor::full(&[2], v);
        let now = Instant::now();
        (
            PendingRequest { image, class, enqueued_at: now, deadline: now + delay, tx },
            rx,
        )
    }

    fn push(q: &AdmissionQueue, v: f32, delay: Duration) -> Result<()> {
        let (tx, _rx) = mpsc::channel();
        q.push(Tensor::full(&[2], v), SloClass::Interactive, delay, tx)
    }

    #[test]
    fn full_batch_drains_in_fifo_order() {
        let q = AdmissionQueue::new(8);
        for v in 0..4 {
            push(&q, v as f32, Duration::from_secs(10)).unwrap();
        }
        let (batch, reason) = q.next_batch(4).unwrap();
        assert_eq!(reason, FlushReason::Full);
        let values: Vec<f32> = batch.iter().map(|r| r.image.data()[0]).collect();
        assert_eq!(values, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q = AdmissionQueue::new(8);
        push(&q, 7.0, Duration::from_millis(30)).unwrap();
        let t0 = Instant::now();
        let (batch, reason) = q.next_batch(4).unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(20), "flushed before the deadline");
    }

    #[test]
    fn interactive_deadline_preempts_batch_class_window() {
        let q = AdmissionQueue::new(8);
        // An old batch-class request with a distant deadline...
        let (slow, _srx) = req(1.0, SloClass::Batch, Duration::from_secs(10));
        assert!(q.try_push_with(|| slow).unwrap());
        // ...must be flushed by the interactive request arriving behind it.
        let (fast, _frx) = req(2.0, SloClass::Interactive, Duration::from_millis(25));
        assert!(q.try_push_with(|| fast).unwrap());
        let t0 = Instant::now();
        let (batch, reason) = q.next_batch(4).unwrap();
        assert_eq!(reason, FlushReason::Deadline);
        assert_eq!(batch.len(), 2, "the flush carries the whole FIFO prefix");
        assert_eq!(batch[0].image.data()[0], 1.0, "FIFO order survives the deadline preempt");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "flush must fire on the interactive window, not the batch one"
        );
    }

    #[test]
    fn try_push_reports_full_and_close_drains() {
        let q = AdmissionQueue::new(2);
        let (a, _arx) = req(1.0, SloClass::Interactive, Duration::from_secs(10));
        let (b, _brx) = req(2.0, SloClass::Batch, Duration::from_secs(10));
        assert!(q.try_push_with(|| a).unwrap());
        assert!(q.try_push_with(|| b).unwrap());
        // Full: the constructor must not even run.
        let accepted = q.try_push_with(|| unreachable!("constructed despite a full queue"));
        assert!(!accepted.unwrap());
        q.close();
        assert!(push(&q, 4.0, Duration::from_secs(10)).is_err());
        let (batch, reason) = q.next_batch(4).unwrap();
        assert_eq!(reason, FlushReason::Drain);
        assert_eq!(batch.len(), 2);
        assert!(q.next_batch(4).is_none());
    }
}
