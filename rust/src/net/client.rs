//! `net::client` — a small blocking protocol client.
//!
//! The loopback counterpart to [`net::server`](super::server): the CLI
//! driver and the integration tests speak the wire format through this
//! instead of hand-rolling sockets. One connection, blocking I/O,
//! requests either one-at-a-time ([`NetClient::request`]) or pipelined
//! ([`NetClient::pipeline`] — the server answers in submission order).

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::runtime::{Result, RuntimeError};
use crate::serve::SloClass;
use crate::tensor::Tensor;

use super::proto::{self, Frame};

/// What the server said to one request.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientReply {
    /// Classified: predicted class, logits row, and latency accounting.
    Reply {
        class: usize,
        logits: Tensor,
        queue_wait: Duration,
        execute: Duration,
        batch_fill: usize,
        batch_size: usize,
    },
    /// Shed: the admission queue was saturated; retry after the hint.
    RetryAfter(Duration),
}

/// A blocking connection to an `anode::net` server.
pub struct NetClient {
    stream: TcpStream,
    buf: Vec<u8>,
    next_id: u64,
}

impl NetClient {
    /// Connect to `addr` (e.g. the server's [`local_addr`]).
    ///
    /// [`local_addr`]: super::server::NetServer::local_addr
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| RuntimeError::Io(format!("net: connect {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, buf: Vec::new(), next_id: 1 })
    }

    /// Submit one example and block for the server's answer (a reply or
    /// a typed shed). A server-side failure surfaces as `Err`.
    pub fn request(&mut self, image: &Tensor, class: SloClass) -> Result<ClientReply> {
        let id = self.send_request(image, class)?;
        self.read_reply(id)
    }

    /// Submit one example, transparently retrying after each shed (up to
    /// `max_retries` times, sleeping the server's hint in between).
    /// Returns the reply, or the final `RetryAfter` if retries ran out.
    pub fn request_with_retry(
        &mut self,
        image: &Tensor,
        class: SloClass,
        max_retries: usize,
    ) -> Result<ClientReply> {
        let mut attempts = 0;
        loop {
            match self.request(image, class)? {
                ClientReply::RetryAfter(hint) if attempts < max_retries => {
                    attempts += 1;
                    std::thread::sleep(hint.min(Duration::from_millis(100)));
                }
                reply => return Ok(reply),
            }
        }
    }

    /// Pipeline a batch of examples: send them all, then read the
    /// answers. The server replies strictly in submission order, so the
    /// returned vector lines up with `images` (asserted via request ids).
    pub fn pipeline(&mut self, images: &[Tensor], class: SloClass) -> Result<Vec<ClientReply>> {
        let mut ids = Vec::with_capacity(images.len());
        for image in images {
            ids.push(self.send_request(image, class)?);
        }
        ids.into_iter().map(|id| self.read_reply(id)).collect()
    }

    /// Fetch the metrics text over the binary frame path.
    pub fn metrics(&mut self) -> Result<String> {
        let id = self.fresh_id();
        self.send_frame(&Frame::MetricsRequest { id })?;
        match self.read_frame()? {
            Frame::MetricsReply { id: got, text } if got == id => Ok(text),
            Frame::Error { message, .. } => {
                Err(RuntimeError::Io(format!("net: server error: {message}")))
            }
            other => Err(RuntimeError::Io(format!(
                "net: expected a metrics reply, got frame id {}",
                other.id()
            ))),
        }
    }

    /// Ask the server to drain gracefully (the `Drain` admin frame — the
    /// std-only SIGTERM stand-in) and block for the echoed acknowledgement.
    /// The ack only means the server *recorded* the request; the owning
    /// driver performs the actual shutdown, so replies to requests already
    /// admitted still arrive (in order) before the socket closes.
    pub fn drain(&mut self) -> Result<()> {
        let id = self.fresh_id();
        self.send_frame(&Frame::Drain { id })?;
        match self.read_frame()? {
            Frame::Drain { id: got } if got == id => Ok(()),
            Frame::Error { message, .. } => {
                Err(RuntimeError::Io(format!("net: server error: {message}")))
            }
            other => Err(RuntimeError::Io(format!(
                "net: expected a drain ack, got frame id {}",
                other.id()
            ))),
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send_request(&mut self, image: &Tensor, class: SloClass) -> Result<u64> {
        let id = self.fresh_id();
        self.send_frame(&Frame::Request { id, class, image: image.clone() })?;
        Ok(id)
    }

    fn send_frame(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame.encode_vec();
        self.stream
            .write_all(&bytes)
            .map_err(|e| RuntimeError::Io(format!("net: send: {e}")))
    }

    fn read_reply(&mut self, id: u64) -> Result<ClientReply> {
        match self.read_frame()? {
            Frame::Reply {
                id: got,
                class,
                queue_wait_us,
                execute_us,
                batch_fill,
                batch_size,
                logits,
            } if got == id => Ok(ClientReply::Reply {
                class: class as usize,
                logits,
                queue_wait: Duration::from_micros(queue_wait_us),
                execute: Duration::from_micros(execute_us),
                batch_fill: batch_fill as usize,
                batch_size: batch_size as usize,
            }),
            Frame::RetryAfter { id: got, retry_after_us } if got == id => {
                Ok(ClientReply::RetryAfter(Duration::from_micros(retry_after_us)))
            }
            Frame::Error { message, .. } => {
                Err(RuntimeError::Io(format!("net: server error: {message}")))
            }
            other => Err(RuntimeError::Io(format!(
                "net: out-of-order reply: expected id {id}, got frame id {}",
                other.id()
            ))),
        }
    }

    /// Read (blocking) until one complete frame decodes.
    fn read_frame(&mut self) -> Result<Frame> {
        loop {
            match proto::decode(&self.buf) {
                Ok(Some((frame, n))) => {
                    self.buf.drain(..n);
                    return Ok(frame);
                }
                Ok(None) => {}
                Err(e) => return Err(RuntimeError::Io(format!("net: bad server frame: {e}"))),
            }
            let mut chunk = [0u8; 8192];
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(RuntimeError::Io(
                        "net: connection closed before a reply".to_string(),
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(RuntimeError::Io(format!("net: recv: {e}"))),
            }
        }
    }
}
