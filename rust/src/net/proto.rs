//! `net::proto` — the length-prefixed binary wire format.
//!
//! Every frame is a fixed 20-byte header followed by `payload_len` bytes
//! of payload. All integers are little-endian; the tensor payload is the
//! raw f32 data prefixed by its shape. The format is versioned and
//! self-delimiting, so a reader can (a) decode frames from a byte stream
//! incrementally ([`decode`] returns `Ok(None)` for "need more bytes")
//! and (b) reject garbage without panicking ([`ProtoError`]).
//!
//! ```text
//! offset  size  field
//!      0     4  magic   = b"ANOD"
//!      4     1  version = 1
//!      5     1  frame type (FrameType)
//!      6     1  SLO class tag (0 interactive, 1 batch; requests only)
//!      7     1  reserved (0 on write, ignored on read)
//!      8     8  request id (u64, client-chosen, echoed in replies)
//!     16     4  payload length (u32, <= MAX_PAYLOAD)
//!     20     -  payload (frame-type specific)
//! ```
//!
//! Payloads:
//! * `Request`       — tensor: `rank:u32, dims:[u32; rank], data:[f32]`
//! * `Reply`         — `class:u32, queue_wait_us:u64, execute_us:u64,
//!   batch_fill:u32, batch_size:u32`, then the logits tensor
//! * `Error`         — UTF-8 message
//! * `RetryAfter`    — `retry_after_us:u64` (the shed reply: the queue is
//!   saturated; retry after the hint)
//! * `MetricsRequest`— empty
//! * `MetricsReply`  — UTF-8 metrics text (same body the HTTP/1.0 path
//!   serves)
//! * `Drain`         — empty (admin: request a graceful server drain; the
//!   server echoes the frame as the acknowledgement)
//!
//! The wire format is documented in rust/DESIGN.md §6e and fuzzed (hand-
//! rolled property loop) in rust/tests/net.rs.

use crate::serve::{RequestStats, SloClass};
use crate::tensor::Tensor;
use std::time::Duration;

/// Frame magic: the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"ANOD";

/// Wire-format version this build speaks.
pub const VERSION: u8 = 1;

/// Header size in bytes.
pub const HEADER_LEN: usize = 20;

/// Hard cap on a frame payload (16 MiB): anything larger is rejected at
/// the header, before buffering — a garbage length cannot balloon memory.
pub const MAX_PAYLOAD: usize = 16 * 1024 * 1024;

/// Maximum tensor rank accepted over the wire.
pub const MAX_RANK: usize = 8;

/// Typed decode/encode failure. Wire errors never panic: a malformed
/// frame surfaces here and the server drops the connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// First four bytes are not [`MAGIC`] — not our protocol.
    BadMagic([u8; 4]),
    /// Version byte this build does not speak.
    BadVersion(u8),
    /// Unknown frame-type byte.
    BadFrameType(u8),
    /// Unknown SLO class tag on a request.
    BadClass(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized(usize),
    /// Payload did not parse as its frame type's layout.
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadMagic(m) => write!(f, "net: bad frame magic {m:02x?}"),
            ProtoError::BadVersion(v) => write!(f, "net: unsupported protocol version {v}"),
            ProtoError::BadFrameType(t) => write!(f, "net: unknown frame type {t}"),
            ProtoError::BadClass(c) => write!(f, "net: unknown SLO class tag {c}"),
            ProtoError::Oversized(n) => {
                write!(f, "net: payload of {n} bytes exceeds the {MAX_PAYLOAD}-byte cap")
            }
            ProtoError::Malformed(what) => write!(f, "net: malformed frame payload: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// One protocol frame. `id` is client-chosen and echoed verbatim in the
/// server's answer, so a client may pipeline requests and match replies
/// by id.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// One example tensor to classify under the given SLO class.
    Request { id: u64, class: SloClass, image: Tensor },
    /// Successful reply: predicted class, latency accounting, logits row.
    Reply {
        id: u64,
        class: u32,
        queue_wait_us: u64,
        execute_us: u64,
        batch_fill: u32,
        batch_size: u32,
        logits: Tensor,
    },
    /// The request failed (shape mismatch, runner failure, shutdown).
    Error { id: u64, message: String },
    /// Load shed: the admission queue is saturated; the request was NOT
    /// accepted and may be retried after the hint.
    RetryAfter { id: u64, retry_after_us: u64 },
    /// Ask for the metrics text (binary alternative to the HTTP path).
    MetricsRequest { id: u64 },
    /// The metrics text.
    MetricsReply { id: u64, text: String },
    /// Admin: ask the server to drain gracefully (stop accepting new
    /// connections, answer everything in flight, then shut down) — the
    /// std-only stand-in for SIGTERM. The server echoes the frame back as
    /// the acknowledgement and raises its drain flag for the owning
    /// driver, which also pauses any rollout promotion loop.
    Drain { id: u64 },
}

impl Frame {
    /// Build a `Reply` from a serve-layer reply.
    pub fn from_reply(id: u64, reply: &crate::serve::ServeReply) -> Frame {
        let s: &RequestStats = &reply.stats;
        Frame::Reply {
            id,
            class: reply.class as u32,
            queue_wait_us: s.queue_wait.as_micros().min(u64::MAX as u128) as u64,
            execute_us: s.execute.as_micros().min(u64::MAX as u128) as u64,
            batch_fill: s.batch_fill as u32,
            batch_size: s.batch_size as u32,
            logits: reply.logits.clone(),
        }
    }

    /// Build a `RetryAfter` from a duration hint.
    pub fn retry_after(id: u64, hint: Duration) -> Frame {
        Frame::RetryAfter { id, retry_after_us: hint.as_micros().min(u64::MAX as u128) as u64 }
    }

    /// The frame's request id.
    pub fn id(&self) -> u64 {
        match self {
            Frame::Request { id, .. }
            | Frame::Reply { id, .. }
            | Frame::Error { id, .. }
            | Frame::RetryAfter { id, .. }
            | Frame::MetricsRequest { id }
            | Frame::MetricsReply { id, .. }
            | Frame::Drain { id } => *id,
        }
    }

    fn frame_type(&self) -> u8 {
        match self {
            Frame::Request { .. } => 1,
            Frame::Reply { .. } => 2,
            Frame::Error { .. } => 3,
            Frame::RetryAfter { .. } => 4,
            Frame::MetricsRequest { .. } => 5,
            Frame::MetricsReply { .. } => 6,
            Frame::Drain { .. } => 7,
        }
    }

    fn class_tag(&self) -> u8 {
        match self {
            Frame::Request { class, .. } => match class {
                SloClass::Interactive => 0,
                SloClass::Batch => 1,
            },
            _ => 0,
        }
    }

    /// Append the encoded frame to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let mut payload = Vec::new();
        match self {
            Frame::Request { image, .. } => put_tensor(&mut payload, image),
            Frame::Reply {
                class,
                queue_wait_us,
                execute_us,
                batch_fill,
                batch_size,
                logits,
                ..
            } => {
                payload.extend_from_slice(&class.to_le_bytes());
                payload.extend_from_slice(&queue_wait_us.to_le_bytes());
                payload.extend_from_slice(&execute_us.to_le_bytes());
                payload.extend_from_slice(&batch_fill.to_le_bytes());
                payload.extend_from_slice(&batch_size.to_le_bytes());
                put_tensor(&mut payload, logits);
            }
            Frame::Error { message, .. } => payload.extend_from_slice(message.as_bytes()),
            Frame::RetryAfter { retry_after_us, .. } => {
                payload.extend_from_slice(&retry_after_us.to_le_bytes());
            }
            Frame::MetricsRequest { .. } => {}
            Frame::MetricsReply { text, .. } => payload.extend_from_slice(text.as_bytes()),
            Frame::Drain { .. } => {}
        }
        debug_assert!(payload.len() <= MAX_PAYLOAD, "encoder produced an oversized payload");
        out.reserve(HEADER_LEN + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.frame_type());
        out.push(self.class_tag());
        out.push(0); // reserved
        out.extend_from_slice(&self.id().to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
    }

    /// Convenience: encode into a fresh buffer.
    pub fn encode_vec(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }
}

/// Incremental decode from the front of `buf`.
///
/// * `Ok(None)` — `buf` holds a frame prefix; read more bytes.
/// * `Ok(Some((frame, consumed)))` — one frame decoded from
///   `buf[..consumed]`; the caller drops those bytes and may call again.
/// * `Err(_)` — the stream is not (or no longer) speaking this protocol;
///   the connection should be closed. Never panics, whatever the bytes.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, ProtoError> {
    if buf.len() < HEADER_LEN {
        // An already-poisoned prefix fails fast (don't wait on bytes that
        // can never become a frame).
        let n = buf.len().min(4);
        if n > 0 && buf[..n] != MAGIC[..n] {
            let mut m = [0u8; 4];
            m[..n].copy_from_slice(&buf[..n]);
            return Err(ProtoError::BadMagic(m));
        }
        return Ok(None);
    }
    if buf[0..4] != MAGIC {
        return Err(ProtoError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
    }
    if buf[4] != VERSION {
        return Err(ProtoError::BadVersion(buf[4]));
    }
    let ftype = buf[5];
    let class_tag = buf[6];
    let id = u64::from_le_bytes(buf[8..16].try_into().expect("8 header bytes"));
    let payload_len = u32::from_le_bytes(buf[16..20].try_into().expect("4 header bytes")) as usize;
    if payload_len > MAX_PAYLOAD {
        return Err(ProtoError::Oversized(payload_len));
    }
    let total = HEADER_LEN + payload_len;
    if buf.len() < total {
        return Ok(None);
    }
    let p = &buf[HEADER_LEN..total];
    let frame = match ftype {
        1 => {
            let class = match class_tag {
                0 => SloClass::Interactive,
                1 => SloClass::Batch,
                c => return Err(ProtoError::BadClass(c)),
            };
            let mut cur = Cursor { p, off: 0 };
            let image = get_tensor(&mut cur)?;
            cur.finish()?;
            Frame::Request { id, class, image }
        }
        2 => {
            let mut cur = Cursor { p, off: 0 };
            let class = cur.u32()?;
            let queue_wait_us = cur.u64()?;
            let execute_us = cur.u64()?;
            let batch_fill = cur.u32()?;
            let batch_size = cur.u32()?;
            let logits = get_tensor(&mut cur)?;
            cur.finish()?;
            Frame::Reply { id, class, queue_wait_us, execute_us, batch_fill, batch_size, logits }
        }
        3 => Frame::Error { id, message: get_text(p)? },
        4 => {
            let mut cur = Cursor { p, off: 0 };
            let retry_after_us = cur.u64()?;
            cur.finish()?;
            Frame::RetryAfter { id, retry_after_us }
        }
        5 => {
            if !p.is_empty() {
                return Err(ProtoError::Malformed("metrics request carries a payload"));
            }
            Frame::MetricsRequest { id }
        }
        6 => Frame::MetricsReply { id, text: get_text(p)? },
        7 => {
            if !p.is_empty() {
                return Err(ProtoError::Malformed("drain request carries a payload"));
            }
            Frame::Drain { id }
        }
        t => return Err(ProtoError::BadFrameType(t)),
    };
    Ok(Some((frame, total)))
}

/// Does the buffer look like the start of an HTTP request (the metrics
/// scrape path: `GET /metrics HTTP/1.0`)? Checked before frame decode so
/// a curl probe gets text instead of a BadMagic drop.
pub fn looks_like_http(buf: &[u8]) -> bool {
    const GET: &[u8] = b"GET ";
    let n = buf.len().min(GET.len());
    n > 0 && buf[..n] == GET[..n]
}

struct Cursor<'a> {
    p: &'a [u8],
    off: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], ProtoError> {
        if self.p.len() - self.off < n {
            return Err(ProtoError::Malformed("payload shorter than its layout"));
        }
        let s = &self.p[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    /// Trailing junk after the declared layout is malformed, not ignored:
    /// a length-prefixed format with slack would hide encoder bugs.
    fn finish(&self) -> Result<(), ProtoError> {
        if self.off != self.p.len() {
            return Err(ProtoError::Malformed("payload longer than its layout"));
        }
        Ok(())
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
    for &d in t.shape() {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn get_tensor(cur: &mut Cursor<'_>) -> Result<Tensor, ProtoError> {
    let rank = cur.u32()? as usize;
    if rank > MAX_RANK {
        return Err(ProtoError::Malformed("tensor rank exceeds the wire cap"));
    }
    let mut dims = Vec::with_capacity(rank);
    let mut len: usize = 1;
    for _ in 0..rank {
        let d = cur.u32()? as usize;
        len = len
            .checked_mul(d)
            .filter(|&n| n <= MAX_PAYLOAD / 4)
            .ok_or(ProtoError::Malformed("tensor element count overflows the payload cap"))?;
        dims.push(d);
    }
    let bytes = cur.take(len * 4)?;
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect();
    Tensor::from_vec(dims, data).map_err(|_| ProtoError::Malformed("tensor shape/data mismatch"))
}

fn get_text(p: &[u8]) -> Result<String, ProtoError> {
    String::from_utf8(p.to_vec()).map_err(|_| ProtoError::Malformed("text payload is not UTF-8"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(frame: &Frame) {
        let bytes = frame.encode_vec();
        let (decoded, consumed) = decode(&bytes).expect("decode").expect("complete frame");
        assert_eq!(consumed, bytes.len());
        assert_eq!(&decoded, frame);
    }

    #[test]
    fn frames_round_trip() {
        let image = Tensor::from_vec(vec![2, 2], vec![1.0, -2.5, 0.0, 3.25]).unwrap();
        round_trip(&Frame::Request { id: 7, class: SloClass::Interactive, image: image.clone() });
        round_trip(&Frame::Request { id: 8, class: SloClass::Batch, image });
        round_trip(&Frame::Reply {
            id: 9,
            class: 3,
            queue_wait_us: 1200,
            execute_us: 88,
            batch_fill: 3,
            batch_size: 4,
            logits: Tensor::from_vec(vec![4], vec![0.1, 0.2, 0.3, 0.4]).unwrap(),
        });
        round_trip(&Frame::Error { id: 10, message: "nope".into() });
        round_trip(&Frame::RetryAfter { id: 11, retry_after_us: 5000 });
        round_trip(&Frame::MetricsRequest { id: 12 });
        round_trip(&Frame::MetricsReply { id: 13, text: "anode_submitted 4\n".into() });
        round_trip(&Frame::Drain { id: 14 });
    }

    #[test]
    fn drain_with_payload_is_malformed() {
        let mut bytes = Frame::Drain { id: 3 }.encode_vec();
        bytes[16..20].copy_from_slice(&1u32.to_le_bytes());
        bytes.push(0xFF);
        assert!(matches!(decode(&bytes), Err(ProtoError::Malformed(_))));
    }

    #[test]
    fn incremental_decode_waits_for_full_frame() {
        let frame = Frame::Error { id: 1, message: "partial".into() };
        let bytes = frame.encode_vec();
        for cut in 0..bytes.len() {
            assert_eq!(decode(&bytes[..cut]).expect("prefix is not an error"), None, "cut={cut}");
        }
        assert!(decode(&bytes).unwrap().is_some());
    }

    #[test]
    fn garbage_and_oversize_are_typed_errors_not_panics() {
        assert!(matches!(decode(b"HELLO world, not a frame"), Err(ProtoError::BadMagic(_))));
        // Bad version.
        let mut bytes = Frame::MetricsRequest { id: 0 }.encode_vec();
        bytes[4] = 9;
        assert!(matches!(decode(&bytes), Err(ProtoError::BadVersion(9))));
        // Unknown frame type.
        let mut bytes = Frame::MetricsRequest { id: 0 }.encode_vec();
        bytes[5] = 77;
        assert!(matches!(decode(&bytes), Err(ProtoError::BadFrameType(77))));
        // Oversized declared payload.
        let mut bytes = Frame::MetricsRequest { id: 0 }.encode_vec();
        bytes[16..20].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(matches!(decode(&bytes), Err(ProtoError::Oversized(_))));
    }

    #[test]
    fn http_sniff_matches_prefixes_only() {
        assert!(looks_like_http(b"GET /metrics HTTP/1.0\r\n\r\n"));
        assert!(looks_like_http(b"GE"));
        assert!(!looks_like_http(b"ANOD"));
        assert!(!looks_like_http(b""));
    }
}
