//! # `anode::net` — the socket front end for `anode::serve`
//!
//! Serving over the wire, with the same guarantees the in-process path
//! gives: admission control, typed load shedding, and bit-identical
//! results. The stack is std-only (no async runtime, no protocol
//! crates — the offline build adds no dependencies):
//!
//! ```text
//! client ──frames──▶ TcpListener ──▶ reactor (poll-driven, 1 thread)
//!                                      │ decode → try_submit_class
//!                                      │ shed → RetryAfter frame
//!                                      ▼
//!                                 anode::serve (queue → batcher → pools)
//!                                      │ replies (FIFO per connection)
//!                                      ▼
//!                    write-buffered frames back down the same socket
//! ```
//!
//! * [`proto`] — the versioned, length-prefixed binary frame format
//!   (requests, replies, typed errors, `RetryAfter` sheds, metrics).
//! * [`server`] — the non-blocking connection reactor over a
//!   [`ServeHandle`](crate::serve::ServeHandle): per-connection
//!   in-flight windows, write high-water backpressure, graceful drain.
//! * [`client`] — a small blocking client (CLI driver, tests, tools).
//! * [`metrics`] — the scrapeable metrics text, served both as a binary
//!   frame and as a plain HTTP/1.0 `GET` response on the same port.
//!
//! Entry point: [`Session::serve_net`](crate::api::Session::serve_net),
//! or [`NetServer::bind`] over any [`ServeHandle`]. Wire format and
//! lifecycle are documented in rust/DESIGN.md §6e.
//!
//! [`ServeHandle`]: crate::serve::ServeHandle

pub mod client;
pub mod metrics;
pub mod proto;
pub mod server;

pub use client::{ClientReply, NetClient};
pub use metrics::NetStats;
pub use server::{NetConfig, NetReport, NetServer};
